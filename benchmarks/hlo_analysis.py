"""HLO post-processing: collective-traffic accounting from compiled modules.

collective_bytes is NOT in cost_analysis(), so we parse the (post-SPMD,
per-device) optimized HLO text and sum the payload bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute /
ragged-all-to-all op.  Shapes in the partitioned module are per-shard, so the
totals are bytes *per device* -- exactly the numerator of the collective
roofline term (bytes / link_bw).

Caveat (documented in EXPERIMENTS.md): ops inside while-loop bodies (layer
scans, GAMP iterations) appear ONCE in the text; benchmarks/roofline.py
corrects by compiling shallow unrolled probes and extrapolating per-layer.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\(?[a-z0-9]+\[[0-9,]*\][^=]*?\)?\s+)?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind payload bytes (per device) + 'total'.  Uses each op's RESULT
    shape(s) (for all-gather that is the post-gather size = bytes received;
    for all-reduce the reduced size; reduce-scatter the scattered shard)."""
    out: Dict[str, int] = defaultdict(int)
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # async pairs: count the -start only (the -done aliases the buffer)
        if f"{kind}-done(" in line:
            continue
        head = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(kind)[0]
        bytes_ = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        out[kind] += bytes_
        out["total"] += bytes_
    return dict(out)


def count_ops(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m and f"{m.group(1)}-done(" not in line:
            out[m.group(1)] += 1
    return dict(out)


# ---------------------------------------------------------------------------
# Mesh-axis attribution: which link does each collective cross?
# ---------------------------------------------------------------------------

_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*[,)]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _line_groups(line):
    """Parses replica_groups into a list of device-id groups (or None)."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, per = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        total = 1
        for d in dims:
            total *= d
        import numpy as _np

        arr = _np.arange(total).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            arr = arr.transpose(perm)
        return arr.reshape(ng, per).tolist()
    m = _GROUPS_RE.search(line)
    if not m:
        return None
    groups = []
    for grp in re.findall(r"\{([0-9, ]*)\}", "{" + m.group(1) + "}"):
        ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
        if ids:
            groups.append(ids)
    return groups or None


def collective_bytes_by_link(hlo_text: str, pod_size: int = 256) -> Dict[str, int]:
    """Splits per-device collective payload bytes into 'dcn' (the group spans
    devices in different pods, i.e. ids differing by >= pod_size) vs 'ici'."""
    out = {"dcn": 0, "ici": 0, "unknown": 0}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or f"{m.group(1)}-done(" in line:
            continue
        head = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(m.group(1))[0]
        bytes_ = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        groups = _line_groups(line)
        if groups is None:
            out["unknown"] += bytes_
            continue
        crosses = any(
            (min(g) // pod_size) != (max(g) // pod_size) for g in groups if g
        )
        out["dcn" if crosses else "ici"] += bytes_
    return out
