"""Benchmark harness: one function per paper table/figure + kernel micros.

    PYTHONPATH=src python -m benchmarks.run            # fast mode
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale horizons
    PYTHONPATH=src python -m benchmarks.run --only fig3,table1

Prints ``name,us_per_call,derived`` CSV; full traces land in runs/bench/.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

BENCH_GAMP_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), os.pardir, "runs", "bench",
    "BENCH_gamp.json",
)


def kernel_micro(fast=True):
    """Microbench the Pallas kernel entry points (interpret mode on CPU:
    validates the call path and gives relative-cost numbers, not TPU wall
    times)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import sensing
    from repro.core.quantizer import design_lloyd_max
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    nb, n, r = (128, 1024, 4)
    m = n // r
    blocks = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    a = sensing.sensing_matrix(jax.random.PRNGKey(0), m, n)
    quant = design_lloyd_max(4)
    rows = []

    def timed(name, fn, derived=""):
        jax.block_until_ready(fn())
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(fn())
        rows.append(f"{name},{1e6 * (time.time() - t0) / reps:.1f},{derived}")

    timed("kernel[bqcs_encode]", lambda: ops.bqcs_encode(blocks, a, quant),
          f"nb={nb};N={n};M={m}")
    timed("kernel[block_topk]", lambda: ops.block_sparsify(blocks, 102), "s=102")
    y = jnp.asarray(rng.normal(0, 1, (nb, m)), jnp.float32)
    nu = jnp.full((nb,), 0.05)
    en = jnp.full((nb,), 1.0)
    timed("kernel[gamp_ae_run]", lambda: ops.gamp_ae_run(y, nu, a, en, iters=10),
          "iters=10")
    codes = jnp.asarray(rng.integers(0, 2**4, (nb, m)), jnp.uint8)
    alpha = jnp.asarray(rng.uniform(0.5, 2.0, (nb,)), jnp.float32)
    timed("kernel[qgamp_ea_run]",
          lambda: ops.qgamp_ea_run(codes, alpha, a, quant.jnp_thresholds(), iters=10),
          "iters=10")
    return rows


def gamp_ea_vs_ae(fast=True):
    """EA vs AE reconstruction micro: fused kernel vs pure-XLA scalar-variance
    GAMP on identical seeded Bernoulli-GM payloads.  Records every entry in
    runs/bench/BENCH_gamp.json (consumed by EXPERIMENTS.md #Perf)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import bussgang
    from repro.core.compression import BQCSCodec, FedQCSConfig
    from repro.core.gamp import GampConfig, em_gamp, qem_gamp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    nb, n, iters = (32 if fast else 128), 512, (10 if fast else 25)
    cfg = FedQCSConfig(block_size=n, reduction_ratio=4, bits=3, s_ratio=0.1)
    codec = BQCSCodec(cfg)
    g = np.zeros((nb, n), np.float32)
    for i in range(nb):
        idx = rng.choice(n, cfg.s, replace=False)
        g[i, idx] = rng.normal(0, 0.1, cfg.s)
    codes, alpha, _ = codec.compress_blocks(jnp.asarray(g), jnp.zeros((nb, n), jnp.float32))
    rhos = jnp.ones((1,))
    y = bussgang.aggregate_codes(codes[None], alpha[None], rhos, codec.quantizer)
    nu = bussgang.effective_noise_var(alpha[None], rhos, codec.quantizer)
    en = bussgang.signal_energy(alpha[None], rhos, cfg.m, n)
    gcfg = GampConfig(iters=iters, variance_mode="scalar", tol=0.0)
    taus = codec.quantizer.jnp_thresholds()
    # jit the pure-XLA paths once so the comparison measures execution, not
    # per-call retracing (the kernel drivers are already jitted).
    ea_xla = jax.jit(lambda c, al: qem_gamp(c, al, codec.a, codec.quantizer, gcfg))
    ae_xla = jax.jit(lambda yy, nn, ee: em_gamp(yy, nn, codec.a, gcfg, init_var=ee))

    cases = {
        "ea_kernel[qgamp_ea_run]": lambda: ops.qgamp_ea_run(
            codes, alpha, codec.a, taus, iters=iters),
        "ea_xla[qem_gamp]": lambda: ea_xla(codes, alpha),
        "ae_kernel[gamp_ae_run]": lambda: ops.gamp_ae_run(
            y, nu, codec.a, en, iters=iters),
        "ae_xla[em_gamp]": lambda: ae_xla(y, nu, en),
    }
    rows, entries = [], []
    for name, fn in cases.items():
        jax.block_until_ready(fn())  # compile
        t0 = time.time()
        reps = 3
        for _ in range(reps):
            jax.block_until_ready(fn())
        us = 1e6 * (time.time() - t0) / reps
        derived = f"nb={nb};N={n};M={cfg.m};iters={iters}"
        rows.append(f"gamp[{name}],{us:.1f},{derived}")
        entries.append({
            "name": name, "us_per_call": round(us, 1), "nb": nb, "n": n,
            "m": cfg.m, "iters": iters, "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
        })
    os.makedirs(os.path.dirname(BENCH_GAMP_JSON), exist_ok=True)
    with open(BENCH_GAMP_JSON, "w") as f:
        json.dump({"bench": "gamp_ea_vs_ae", "entries": entries}, f, indent=2)
    rows.append(f"gamp[json],0,{os.path.relpath(BENCH_GAMP_JSON)}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    fast = not args.full

    from benchmarks import paper_figs

    benches = {
        "fig2": paper_figs.fig2_prior_fit,
        "fig3": paper_figs.fig3_accuracy_nmse,
        "fig4": paper_figs.fig4_overhead,
        "fig5": paper_figs.fig5_rq_grid,
        "fig6": paper_figs.fig6_sparsity,
        "table1": paper_figs.table1_complexity,
        "kernels": kernel_micro,
        "gamp": gamp_ea_vs_ae,
    }
    selected = [s for s in args.only.split(",") if s] or list(benches)
    print("name,us_per_call,derived")
    failed = 0
    for name in selected:
        try:
            for row in benches[name](fast=fast):
                print(row, flush=True)
        except Exception as e:
            failed += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
