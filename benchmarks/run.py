"""Benchmark harness: one function per paper table/figure + kernel micros.

    PYTHONPATH=src python -m benchmarks.run            # fast mode
    PYTHONPATH=src python benchmarks/run.py --fast     # same, script form (CI)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale horizons
    PYTHONPATH=src python -m benchmarks.run --only fig3,table1

Prints ``name,us_per_call,derived`` CSV; full traces land in runs/bench/ as
``BENCH_*.json`` files whose entries all carry the ``name`` / ``wall_ms`` /
``derived`` keys (the schema CI's bench-smoke job validates).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

_ROOT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir)
_BENCH_DIR = os.path.join(_ROOT_DIR, "runs", "bench")
BENCH_SCHEMA_VERSION = 1


def write_bench(name: str, bench: str, entries: list) -> str:
    """Writes runs/bench/BENCH_<name>.json; every entry must already carry
    the schema keys (name / wall_ms / derived).  The doc is stamped with the
    bench-file schema version plus the backend and jax version it was
    recorded on, so cross-machine comparisons of the checked-in trajectory
    are interpretable.  Every file is mirrored to the repo root (same
    basename) so the per-PR perf trajectory lives where the acceptance
    tooling and reviewers look first; runs/bench/ keeps the canonical copy
    CI uploads.  Returns the canonical path."""
    import jax

    for e in entries:
        assert {"name", "wall_ms", "derived"} <= set(e), e
    doc = {
        "bench": bench,
        "schema_version": BENCH_SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "entries": entries,
    }
    path = os.path.join(_BENCH_DIR, f"BENCH_{name}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    with open(os.path.join(_ROOT_DIR, os.path.basename(path)), "w") as f:
        json.dump(doc, f, indent=2)
    return path


def kernel_micro(fast=True):
    """Microbench the Pallas kernel entry points (interpret mode on CPU:
    validates the call path and gives relative-cost numbers, not TPU wall
    times)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import sensing
    from repro.core.quantizer import design_lloyd_max
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    nb, n, r = (128, 1024, 4)
    m = n // r
    blocks = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    a = sensing.sensing_matrix(jax.random.PRNGKey(0), m, n)
    quant = design_lloyd_max(4)
    rows = []

    def timed(name, fn, derived=""):
        jax.block_until_ready(fn())
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(fn())
        rows.append(f"{name},{1e6 * (time.time() - t0) / reps:.1f},{derived}")

    timed("kernel[bqcs_encode]", lambda: ops.bqcs_encode(blocks, a, quant),
          f"nb={nb};N={n};M={m}")
    timed("kernel[block_topk]", lambda: ops.block_sparsify(blocks, 102), "s=102")
    y = jnp.asarray(rng.normal(0, 1, (nb, m)), jnp.float32)
    nu = jnp.full((nb,), 0.05)
    en = jnp.full((nb,), 1.0)
    timed("kernel[gamp_ae_run]", lambda: ops.gamp_ae_run(y, nu, a, en, iters=10),
          "iters=10")
    codes = jnp.asarray(rng.integers(0, 2**4, (nb, m)), jnp.uint8)
    alpha = jnp.asarray(rng.uniform(0.5, 2.0, (nb,)), jnp.float32)
    timed("kernel[qgamp_ea_run]",
          lambda: ops.qgamp_ea_run(codes, alpha, a, quant.jnp_thresholds(), iters=10),
          "iters=10")
    return rows


def gamp_ea_vs_ae(fast=True):
    """EA vs AE reconstruction micro: fused kernel vs pure-XLA scalar-variance
    GAMP on identical seeded Bernoulli-GM payloads.  Records every entry in
    runs/bench/BENCH_gamp.json (consumed by EXPERIMENTS.md #Perf)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import bussgang
    from repro.core.compression import BQCSCodec, FedQCSConfig
    from repro.core.gamp import GampConfig, em_gamp, qem_gamp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    nb, n, iters = (32 if fast else 128), 512, (10 if fast else 25)
    cfg = FedQCSConfig(block_size=n, reduction_ratio=4, bits=3, s_ratio=0.1)
    codec = BQCSCodec(cfg)
    g = np.zeros((nb, n), np.float32)
    for i in range(nb):
        idx = rng.choice(n, cfg.s, replace=False)
        g[i, idx] = rng.normal(0, 0.1, cfg.s)
    codes, alpha, _ = codec.compress_blocks(jnp.asarray(g), jnp.zeros((nb, n), jnp.float32))
    rhos = jnp.ones((1,))
    y = bussgang.aggregate_codes(codes[None], alpha[None], rhos, codec.quantizer)
    nu = bussgang.effective_noise_var(alpha[None], rhos, codec.quantizer)
    en = bussgang.signal_energy(alpha[None], rhos, cfg.m, n)
    gcfg = GampConfig(iters=iters, variance_mode="scalar", tol=0.0)
    taus = codec.quantizer.jnp_thresholds()
    # jit the pure-XLA paths once so the comparison measures execution, not
    # per-call retracing (the kernel drivers are already jitted).
    ea_xla = jax.jit(lambda c, al: qem_gamp(c, al, codec.a, codec.quantizer, gcfg))
    ae_xla = jax.jit(lambda yy, nn, ee: em_gamp(yy, nn, codec.a, gcfg, init_var=ee))

    cases = {
        "ea_kernel[qgamp_ea_run]": lambda: ops.qgamp_ea_run(
            codes, alpha, codec.a, taus, iters=iters),
        "ea_xla[qem_gamp]": lambda: ea_xla(codes, alpha),
        "ae_kernel[gamp_ae_run]": lambda: ops.gamp_ae_run(
            y, nu, codec.a, en, iters=iters),
        "ae_xla[em_gamp]": lambda: ae_xla(y, nu, en),
    }
    rows, entries = [], []
    for name, fn in cases.items():
        jax.block_until_ready(fn())  # compile
        t0 = time.time()
        reps = 3
        for _ in range(reps):
            jax.block_until_ready(fn())
        us = 1e6 * (time.time() - t0) / reps
        derived = f"nb={nb};N={n};M={cfg.m};iters={iters}"
        rows.append(f"gamp[{name}],{us:.1f},{derived}")
        entries.append({
            "name": name, "wall_ms": round(us / 1e3, 3), "us_per_call": round(us, 1),
            "derived": derived, "nb": nb, "n": n, "m": cfg.m, "iters": iters,
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
        })
    path = write_bench("gamp", "gamp_ea_vs_ae", entries)
    rows.append(f"gamp[json],0,{os.path.relpath(path)}")
    return rows


def encode_fused_vs_unfused(fast=True):
    """Worker-side encode path: the single-pass fused kernel (EF add + top-S
    + project + quantize + uint32 pack, one VMEM residency) vs the unfused
    two-kernel + XLA-pack pipeline it replaces, vs the pure-XLA stage
    composition.  Records wire accounting (packed words vs the int32 codes
    the pre-packed wire shipped) in runs/bench/BENCH_encode.json
    (EXPERIMENTS.md #Perf)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import sensing, sparsify
    from repro.core.compression import pack_codes
    from repro.core.quantizer import design_lloyd_max, encode
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    nb, n, r, q = (128 if fast else 1024), 1024, 4, 2
    m = n // r
    s = n // 10
    blocks = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    resid = jnp.asarray(rng.normal(0, 0.1, (nb, n)), jnp.float32)
    a = sensing.sensing_matrix(jax.random.PRNGKey(0), m, n)
    a_t = a.T
    quant = design_lloyd_max(q)

    # jit all three cases so the comparison is end-to-end traced computations
    # (the fused driver's transpose/pad/trim plumbing must not be timed as
    # eager per-call dispatch while the baselines are fully jitted).
    @jax.jit
    def fused(b, res):
        return ops.bqcs_encode_fused(b, res, a, quant, s)

    @jax.jit
    def unfused_kernels(b, res):
        sparse, new_res = ops.block_sparsify(b + res, s)
        codes, alpha = ops.bqcs_encode(sparse, a, quant)
        return pack_codes(codes, q), alpha, new_res

    @jax.jit
    def unfused_xla(b, res):
        sparse, new_res = sparsify.block_sparsify_threshold(b + res, s)
        x, alpha = sensing.project_blocks(sparse, a_t)
        return pack_codes(encode(x, quant), q), alpha, new_res

    words, _, _ = jax.block_until_ready(fused(blocks, resid))
    packed_bytes = words.size * 4 + nb * 4  # words + alphas: the actual wire
    int32_bytes = nb * m * 4 + nb * 4  # what the pre-packed wire shipped
    cases = {
        "encode_fused[bqcs_encode_fused]": fused,
        "encode_unfused[block_topk+bqcs_encode+pack]": unfused_kernels,
        "encode_unfused_xla[sparsify+project+encode+pack]": unfused_xla,
    }
    rows, entries = [], []
    for name, fn in cases.items():
        jax.block_until_ready(fn(blocks, resid))  # compile
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(fn(blocks, resid))
        us = 1e6 * (time.time() - t0) / reps
        derived = (
            f"nb={nb};N={n};M={m};Q={q};S={s};"
            f"wire_bytes={packed_bytes};int32_wire_bytes={int32_bytes}"
        )
        rows.append(f"encode[{name}],{us:.1f},{derived}")
        entries.append({
            "name": name, "wall_ms": round(us / 1e3, 3), "us_per_call": round(us, 1),
            "derived": derived, "nb": nb, "n": n, "m": m, "q": q, "s": s,
            "wire_bytes": packed_bytes, "int32_wire_bytes": int32_bytes,
            "wire_ratio": round(int32_bytes / packed_bytes, 2),
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
        })
    # -- streamed (per-tensor layout) vs monolithic whole-model encode -------
    # Peak live encoder memory (blocks + EF residual in/out, f32) is the
    # whole block grid for the monolithic one-pass encode but only the
    # LARGEST segment for the per-tensor streamed encode
    # (core/layout.py GradientLayout.encoder_live_bytes; DESIGN.md #Layout).
    # bench-smoke (ci.yml) pins two invariants off these entries: the
    # streamed bound is strictly below the monolithic one, and the streamed
    # wire is bit-identical to the one-pass encode of the same layout.
    from repro.core.compression import BQCSCodec, FedQCSConfig
    from repro.core.layout import GradientLayout

    sizes = ([4096 * 8, 4096, 512, 4096 * 8, 64] if fast
             else [4096 * 64, 4096 * 8, 4096, 4096 * 64, 512])
    tree = {
        f"layer{i}": jnp.asarray(rng.normal(0, 1, (sz,)), jnp.float32)
        for i, sz in enumerate(sizes)
    }
    codec = BQCSCodec(FedQCSConfig(block_size=n, reduction_ratio=r, bits=q))
    mono = GradientLayout.monolithic(tree, n)
    pt = GradientLayout.per_tensor(tree, n)
    res_mono = codec.zero_residual(tree, mono)
    res_pt = codec.zero_residual(tree, pt)
    one_pass = codec.compress_blocks_packed(pt.to_blocks(tree), res_pt)
    stream_cases = {
        "encode_stream[monolithic_one_pass]": (
            mono, lambda: codec.compress_tree(tree, res_mono, mono), False,
        ),
        "encode_stream[per_tensor_streamed]": (
            pt, lambda: codec.compress_tree_streamed(tree, res_pt, pt), True,
        ),
    }
    for name, (layout, fn, streamed) in stream_cases.items():
        payload, _, _ = jax.block_until_ready(fn())  # compile
        t0 = time.time()
        reps = 3
        for _ in range(reps):
            jax.block_until_ready(fn())
        us = 1e6 * (time.time() - t0) / reps
        live = layout.encoder_live_bytes(streamed=streamed)
        derived = (
            f"rows={layout.rows};max_segment_rows={layout.max_segment_rows};"
            f"segments={len(layout.segments)};peak_live_encoder_bytes={live}"
        )
        entry = {
            "name": name, "wall_ms": round(us / 1e3, 3), "us_per_call": round(us, 1),
            "derived": derived, "n": n, "q": q,
            "rows": layout.rows, "max_segment_rows": layout.max_segment_rows,
            "segments": len(layout.segments), "streamed": streamed,
            "peak_live_encoder_bytes": live,
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
        }
        if streamed:
            # streamed wire must be bit-identical to the one-pass encode of
            # the same layout (every codec stage is per-block)
            wire_identical = bool(
                jnp.array_equal(payload.codes, one_pass[0])
                and jnp.array_equal(payload.alpha, one_pass[1])
            )
            entry["wire_identical"] = wire_identical
            entry["derived"] = derived + f";wire_identical={wire_identical}"
        rows.append(f"encode[{name}],{us:.1f},{entry['derived']}")
        entries.append(entry)

    path = write_bench("encode", "encode_fused_vs_unfused", entries)
    rows.append(f"encode[json],0,{os.path.relpath(path)}")
    return rows


def interleave_producer(fast=True):
    """Backward-interleaved segment producer vs the one-pass gradient tree
    (DESIGN.md #Interleave): same streamed per-segment encode, but the
    interleaved producer yields each layout segment as its layer chunk
    backprops, so the full gradient pytree never materializes.  Two rows in
    runs/bench/BENCH_interleave.json:

    * ``one_pass_tree`` -- the engine's default hook (batched jax.grad tree,
      then slice segments out of it).
    * ``backward_interleaved`` -- the segment-tap producer.

    Each row records client-pass wall-clock (timed non-blocking pass) and
    the MEASURED peak of live device bytes over a blocking sampled pass
    (jax.live_arrays delta vs the pre-pass baseline: gradients + encoder
    state + the wire/residual accumulation both paths share).  The
    interleaved row also records the ANALYTIC bound
    (``peak_live_grad_bytes`` from the fold plan + stage-boundary
    activations + the shared accumulation terms) and the wire-identity
    invariant (streamed blocks bitwise equal to slicing the producer's own
    one-pass tree).  bench-smoke (ci.yml) pins: wire_identical, measured
    interleaved peak <= 1.05x its bound (the 5% is allocator/XLA temp slack
    the fold plan cannot see), and interleaved peak < one-pass peak.
    Wall-clock is recorded but not pinned relative: at smoke scale the two
    passes are within CPU noise of each other -- the interleave buys MEMORY
    (largest stage vs whole tree) and overlap, not raw CPU throughput."""
    import dataclasses as dc
    import gc

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import smoke_config
    from repro.core.compression import FedQCSConfig
    from repro.fed.engine import (
        CohortConfig,
        CohortEngine,
        TokenClientData,
        make_interleaved_segments,
    )
    from repro.fed.scheduler import SchedulerConfig
    from repro.models import model as M
    from repro.models.segment_tap import interleaved_layout
    from repro.obs.recorder import InMemoryRecorder

    layers = 8 if fast else 16
    chunks = 4
    clients, batch, seq = 4, 2, 32
    cfg = dc.replace(smoke_config("qwen3-0.6b"), n_layers=layers)
    fed = FedQCSConfig(block_size=64, reduction_ratio=2, bits=3, gamp_iters=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    layout = interleaved_layout(cfg, fed.block_size, layer_chunks=chunks)
    prod = make_interleaved_segments(cfg, layout, layer_chunks=chunks)
    grad_fn = jax.grad(lambda p, b: M.train_loss(p, b, cfg))
    data = TokenClientData(cfg.vocab_size, batch=batch, seq=seq,
                           clients=clients, seed=1)

    def build(hook, obs=None):
        return CohortEngine(
            params, grad_fn, data, fed_cfg=fed,
            cohort=CohortConfig(method="fedqcs-ae", encode_stream=True,
                                record_nmse=False, seed=5),
            sched=SchedulerConfig(), layout=layout,
            grad_segments_fn=hook, obs=obs,
        )

    cohort0 = data.cohort_batch(0, np.arange(clients))
    rhos = jnp.ones((clients,), jnp.float32)

    # wire identity: the streamed blocks vs slicing the producer's own
    # one-pass tree (the encode is deterministic per block, so block
    # equality IS wire equality; payload equality is pinned in tests)
    tree = prod.grads_fn(params, cohort0)
    wire_identical = True
    for idx, blocks in prod(params, cohort0, layout):
        ref = layout.segment_blocks_batched(tree, idx)
        wire_identical = wire_identical and bool(jnp.array_equal(blocks, ref))
    del tree

    engines = {
        "one_pass_tree": build(None),
        "backward_interleaved": build(prod),
    }

    def client_pass(eng):
        res = jnp.zeros((clients, eng.nb, eng.n), jnp.float32)
        return eng._client_pass_streamed(params, cohort0, res, rhos, rhos)

    def sampled_pass(eng):
        """Blocking pass, sampling total live device bytes at each segment
        boundary; returns (peak delta bytes, payload bytes).  The input
        residual grid is allocated BEFORE the baseline sample: it is
        persistent engine state (CohortEngine.residuals exists across
        rounds), so the delta counts gradients + encoder state + the
        wire/new-residual accumulation -- what the round actually adds."""
        res = jnp.zeros((clients, eng.nb, eng.n), jnp.float32)
        jax.block_until_ready(res)
        gc.collect()
        base = sum(a.size * a.dtype.itemsize for a in jax.live_arrays())
        peak = 0
        seg_s = layout.segment_s(fed.s)
        pay = [None] * len(layout.segments)
        nres = [None] * len(layout.segments)
        for idx, seg_blocks in eng._grad_segments(params, cohort0):
            seg = layout.segments[idx]
            pay[idx], nres[idx] = eng._encode_seg_jit(
                seg_blocks, res[:, seg.row_slice], rhos, seg_s[idx]
            )
            jax.block_until_ready((seg_blocks, pay[idx]))
            live = sum(a.size * a.dtype.itemsize for a in jax.live_arrays())
            peak = max(peak, live - base)
        pay_bytes = sum(
            a.size * a.dtype.itemsize
            for p in pay for a in jax.tree_util.tree_leaves(p)
        )
        return peak, pay_bytes

    # spans: one recorded round on the interleaved engine -- the overlap
    # shows as backward+encode_overlap sub-phases inside client_pass
    obs_eng = build(prod, obs=InMemoryRecorder())
    obs_eng.run_round()  # warmup: compiles every per-segment graph
    obs_eng.run_round()
    phase = [
        e["phase_ms"] for e in obs_eng.obs.events if e["kind"] == "round"
    ][-1]

    # analytic accounting shared by the bound below
    nbar = layout.nbar
    grad_tree_bytes = clients * nbar * 4
    enc_stream_bytes = clients * layout.encoder_live_bytes(streamed=True)
    d = cfg.d_model
    ns = len(prod.stages)
    # stage-boundary carries (ns-1 live at the forward's end) + one live
    # cotangent + one in-flight VJP temp, and the int32 ctx leaves
    # (tokens/labels/positions)
    act_bytes = ((ns + 2) * clients * batch * seq * d * 4
                 + 16 * clients * batch * seq)
    res_accum_bytes = clients * layout.rows * fed.block_size * 4

    rows, entries = [], []
    for name, eng in engines.items():
        jax.block_until_ready(client_pass(eng)[0])  # compile
        reps = 3
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(client_pass(eng)[0])
        ms = 1e3 * (time.time() - t0) / reps
        peak, pay_bytes = sampled_pass(eng)
        entry = {
            "name": f"interleave[{name}]",
            "wall_ms": round(ms, 2),
            "clients": clients, "layers": layers, "chunks": chunks,
            "segments": len(layout.segments),
            "grad_tree_bytes": grad_tree_bytes,
            "measured_peak_live_bytes": int(peak),
            "payload_bytes": int(pay_bytes),
            "backend": jax.default_backend(),
        }
        if name == "backward_interleaved":
            bound = (prod.peak_live_grad_bytes(clients) + act_bytes
                     + res_accum_bytes + pay_bytes)
            entry.update({
                "wire_identical": wire_identical,
                "peak_live_grad_bytes": prod.peak_live_grad_bytes(clients),
                "activation_bytes": act_bytes,
                "res_accum_bytes": res_accum_bytes,
                "peak_live_bound_bytes": int(bound),
                "phase_backward_ms": round(phase.get("backward", 0.0), 2),
                "phase_encode_overlap_ms": round(
                    phase.get("encode_overlap", 0.0), 2
                ),
                "phase_client_pass_ms": round(
                    phase.get("client_pass", 0.0), 2
                ),
            })
            derived = (
                f"peak={peak};bound={int(bound)};"
                f"grad_tree_bytes={grad_tree_bytes};"
                f"wire_identical={wire_identical};"
                f"backward_ms={entry['phase_backward_ms']};"
                f"encode_overlap_ms={entry['phase_encode_overlap_ms']}"
            )
        else:
            # one-pass analytic peak: the whole tree + one segment's encoder
            entry["peak_live_bound_bytes"] = int(
                grad_tree_bytes + enc_stream_bytes + res_accum_bytes
                + pay_bytes
            )
            derived = (
                f"peak={peak};grad_tree_bytes={grad_tree_bytes};"
                f"enc_stream_bytes={enc_stream_bytes}"
            )
        entry["derived"] = derived
        rows.append(f"interleave[{name}],{ms * 1e3:.1f},{derived}")
        entries.append(entry)

    path = write_bench("interleave", "interleave_producer", entries)
    rows.append(f"interleave[json],0,{os.path.relpath(path)}")
    return rows


def quant_codebooks(fast=True):
    """Codebook-family microbench (DESIGN.md #Codebooks): packed-wire encode
    throughput, single-worker EA recovery NMSE, and honest wire accounting
    per registered family on identical seeded Bernoulli-Gaussian payloads.

    Rows (all at N=512, R=4 -> M=128):
      * ``lloyd_max[q2]`` / ``lloyd_max[q4]`` -- the paper's scalar quantizer
        at 2 and 4 bits/measurement (the rate bracket);
      * ``dithered_uniform[q4]`` -- the shared-seed dither family at 4 bits;
      * ``vq[q4_d2]`` -- the FedVQCS-style 2-dim / 16-centroid codebook:
        SAME wire bits as lloyd_max[q2] (4 bits per 2 measurements), lower
        quantization distortion kappa -> the rate-NMSE win to watch.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.compression import BQCSCodec, CompressedGradient, FedQCSConfig
    from repro.core.gamp import GampConfig, qem_gamp

    rng = np.random.default_rng(0)
    n, r = 512, 4
    nb = 64 if fast else 256
    iters = 25 if fast else 40
    s = n // 10
    g = np.zeros((nb, n), np.float32)
    for i in range(nb):
        idx = rng.choice(n, s, replace=False)
        g[i, idx] = rng.normal(0, 0.1, s)
    g = jnp.asarray(g)
    zeros = jnp.zeros_like(g)

    cases = [
        ("lloyd_max[q2]", dict(codebook="lloyd_max", bits=2)),
        ("lloyd_max[q4]", dict(codebook="lloyd_max", bits=4)),
        ("dithered_uniform[q4]", dict(codebook="dithered_uniform", bits=4)),
        ("vq[q4_d2]", dict(codebook="vq", bits=4, vq_dim=2)),
    ]
    rows, entries = [], []
    for name, ckw in cases:
        cfg = FedQCSConfig(block_size=n, reduction_ratio=r, s_ratio=s / n,
                           gamp_iters=iters, **ckw)
        codec = BQCSCodec(cfg)
        enc = jax.jit(codec.compress_blocks_packed)
        words, alpha, _ = jax.block_until_ready(enc(g, zeros))  # compile
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(enc(g, zeros))
        us = 1e6 * (time.time() - t0) / reps
        payload = CompressedGradient(words, alpha, nb * n, cfg.m, codec.codebook.bits)
        wire = payload.wire_bits()
        codes = codec.unpack(words)
        ghat = qem_gamp(codes, alpha, codec.a, codec.codebook,
                        GampConfig(iters=iters, variance_mode="scalar"))
        nmse = float(jnp.median(
            jnp.sum((ghat - g) ** 2, axis=1)
            / jnp.maximum(jnp.sum(g**2, axis=1), 1e-30)))
        cb = codec.codebook
        bpe = wire / (nb * n)
        derived = (
            f"family={cb.family};q={cb.bits};dim={cb.dim};levels={cb.n_levels};"
            f"kappa={cb.kappa:.4f};wire_bits_per_entry={bpe:.3f};nmse={nmse:.4f};"
            f"entries_per_sec={nb * n / (us / 1e6):.0f}"
        )
        rows.append(f"quant[{name}],{us:.1f},{derived}")
        entries.append({
            "name": name, "wall_ms": round(us / 1e3, 3), "us_per_call": round(us, 1),
            "derived": derived, "family": cb.family, "q": cb.bits, "dim": cb.dim,
            "levels": cb.n_levels, "kappa": round(cb.kappa, 5),
            "wire_bits_per_entry": round(bpe, 4), "nmse": round(nmse, 5),
            "nb": nb, "n": n, "m": cfg.m, "iters": iters,
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
        })
    path = write_bench("quant", "quant_codebooks", entries)
    rows.append(f"quant[json],0,{os.path.relpath(path)}")
    return rows


def recon_scaling(fast=True):
    """PS reconstruction engine (EXPERIMENTS.md #Recon-engine): blocks/sec of
    the EA (estimate-and-aggregate, best-NMSE) decode at cohort sizes
    {32, 256, 1000}, packed-vs-unpacked and chunked/sharded-vs-monolithic.

    Four decode paths per cohort over identical seeded payloads
    (heterogeneous per-block sparsity, so convergence varies):

      * ``recon_mono_unpacked``  -- the pre-engine path: one monolithic
        K*nb-row GAMP batch over the uint8 code view (what
        ``estimate_and_aggregate`` did before chunking existed);
      * ``recon_mono_packed``    -- same batch consuming wire words;
      * ``recon_chunked_packed`` -- lax.scan chunk stream, packed, early-stop
        per chunk: live GAMP state bounded at chunk rows (single device);
      * ``recon_sharded_packed`` -- the full engine: chunks sharded over a
        ('recon',) mesh of all host devices via shard_map, packed,
        early-stop.  The acceptance path.

    ``unpacked_peak_bytes`` records the largest uint8 code view any path
    materializes at once (rows*M monolithic, chunk*M per-chunk on the
    chunked XLA path, 0 in-kernel on TPU).
    """
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import recon_engine
    from repro.core.compression import BQCSCodec, FedQCSConfig, pack_codes
    from repro.core.gamp import GampConfig
    from repro.core.reconstruction import (
        estimate_and_aggregate,
        estimate_and_aggregate_packed,
    )

    n, r, q, nb = 256, 4, 2, 2
    iters = 15 if fast else 25
    cfg = FedQCSConfig(block_size=n, reduction_ratio=r, bits=q, s_ratio=0.08)
    codec = BQCSCodec(cfg)
    m = cfg.m
    gamp = GampConfig(iters=iters, variance_mode="scalar", tol=1e-4)
    gamp_es = dataclasses.replace(gamp, early_stop=True)
    devices = jax.devices()
    mesh = Mesh(np.array(devices), ("recon",)) if len(devices) > 1 else None

    rng = np.random.default_rng(0)
    rows_all, entries = [], []
    for k in (32, 256, 1000):
        rows = k * nb
        g = np.zeros((rows, n), np.float32)
        for i in range(rows):
            s = rng.integers(max(1, n // 40), cfg.s + 1)
            idx = rng.choice(n, s, replace=False)
            g[i, idx] = rng.normal(0, 0.1, s)
        codes, alpha, _ = codec.compress_blocks(
            jnp.asarray(g), jnp.zeros((rows, n), jnp.float32)
        )
        codes = codes.reshape(k, nb, m)
        alphas = alpha.reshape(k, nb)
        words = jax.vmap(lambda c: pack_codes(c, q))(codes)
        rhos = jnp.full((k,), 1.0 / k)
        # chunk sized so small cohorts don't scan-pad into dead work
        ndev = max(1, len(devices))
        chunk = min(128, max(8, -(-rows // ndev)))

        cases = {
            "mono_unpacked": (
                jax.jit(lambda c, a, rr: estimate_and_aggregate(
                    codec, c, a, rr, gamp, chunk=0)),
                (codes, alphas, rhos), rows * m,
            ),
            "mono_packed": (
                jax.jit(lambda w, a, rr: estimate_and_aggregate_packed(
                    codec, w, a, rr, gamp, chunk=0)),
                (words, alphas, rhos), rows * m,
            ),
            "chunked_packed": (
                jax.jit(lambda w, a, rr: estimate_and_aggregate_packed(
                    codec, w, a, rr, gamp_es, chunk=chunk)),
                (words, alphas, rhos), chunk * m,
            ),
            "sharded_packed": (
                jax.jit(lambda w, a, rr: recon_engine.ea_decode(
                    codec, w, a, rr, gamp_es, packed=True, chunk=chunk,
                    mesh=mesh)),
                (words, alphas, rhos), chunk * m,
            ),
        }
        walls, outs = {}, {}
        for label, (fn, args, _) in cases.items():
            jax.block_until_ready(fn(*args))  # compile
            reps = 3 if rows <= 512 else 2
            t0 = time.time()
            for _ in range(reps):
                outs[label] = jax.block_until_ready(fn(*args))
            walls[label] = (time.time() - t0) / reps
        ref = outs["mono_unpacked"]
        for label, (_, _, peak) in cases.items():
            wall = walls[label]
            bps = rows / wall
            speedup = walls["mono_unpacked"] / wall
            nmse = float(jnp.sum((outs[label] - ref) ** 2)
                         / jnp.maximum(jnp.sum(ref**2), 1e-30))
            name = f"recon_{label}[c{k}]"
            derived = (
                f"cohort={k};rows={rows};blocks_per_sec={bps:.1f};"
                f"speedup_vs_mono_unpacked={speedup:.2f};"
                f"unpacked_peak_bytes={peak};chunk={chunk}"
            )
            rows_all.append(f"recon[{name}],{1e6 * wall:.1f},{derived}")
            entries.append({
                "name": name, "wall_ms": round(wall * 1e3, 3),
                "derived": derived, "cohort": k, "rows": rows,
                "path": label, "chunk": chunk, "iters": iters,
                "blocks_per_sec": round(bps, 1),
                "speedup_vs_mono_unpacked": round(speedup, 2),
                "unpacked_peak_bytes": peak,
                "nmse_vs_mono_unpacked": nmse,
                "n": n, "m": m, "q": q, "devices": len(devices),
                "backend": jax.default_backend(),
            })
    path = write_bench("recon", "recon_scaling", entries)
    rows_all.append(f"recon[json],0,{os.path.relpath(path)}")
    return rows_all


def fed_cohort_scaling(fast=True):
    """Cohort engine throughput (EXPERIMENTS.md #Fed-cohort): clients/sec of
    one full federated round (grad + BQCS encode + channel + PS GAMP + server
    update) at cohort sizes {32, 256, 1000}, vmapped device pass vs the
    per-client Python-loop oracle.

    Two client models per size in runs/bench/BENCH_fed.json:
      * ``fed_vmap/fed_loop[cN]`` — a compact synthetic classifier, where
        per-client compute is tiny and the engine's claim (amortizing the
        per-client dispatch of the loop into one device pass) is what is
        measured; the recorded ``speedup_vs_loop`` is the orchestration win.
      * ``fed_vmap_mlp/fed_loop_mlp[cN]`` — the paper's MNIST MLP at the
        Sec. VI protocol (AWGN 10 dB, Dirichlet alpha=0.1), where the
        784-20-10 gradient + (10, 1591) encode GEMMs dominate both paths;
        the gap narrows toward the backend's batched-vs-small GEMM ratio.
    """
    import jax

    from repro.core.compression import FedQCSConfig
    from repro.fed.channel import ChannelConfig
    from repro.fed.engine import ArrayClientData, CohortConfig, CohortEngine
    from repro.fed.partition import PartitionConfig, partition_indices
    from repro.fed.scheduler import SchedulerConfig
    from repro.fed.server_opt import ServerOptConfig
    from repro.fed.toy import toy_classification, toy_loss, toy_params

    sizes = (32, 256, 1000)

    # -- compact synthetic classifier (orchestration-dominated) ------------
    xs, ys = toy_classification(n_samples=4096)
    small_fed = FedQCSConfig(block_size=64, reduction_ratio=2, bits=3,
                             s_ratio=0.1, gamp_iters=10,
                             gamp_variance_mode="scalar")

    def small_engine(k, impl):
        parts = partition_indices(
            ys, k, PartitionConfig(kind="dirichlet", alpha=0.1, min_size=2))
        return CohortEngine(
            toy_params(), jax.grad(toy_loss),
            ArrayClientData(xs, ys, parts, batch_size=2),
            fed_cfg=small_fed,
            cohort=CohortConfig(method="fedqcs-ae", impl=impl, record_nmse=False),
            sched=SchedulerConfig(),
            chan=ChannelConfig(kind="awgn", snr_db=10.0),
            server=ServerOptConfig(lr=0.01),
        )

    # -- the paper's MNIST MLP at the Sec. VI protocol ---------------------
    from repro.data import mnist
    from repro.paper.mlp import init_mlp, mlp_grad_fn

    (xtr, ytr, _, _), _ = mnist.load(0)
    mlp_fed = FedQCSConfig(block_size=1591, reduction_ratio=3, bits=3,
                           s_ratio=0.1, gamp_iters=15,
                           gamp_variance_mode="scalar", sparsifier="bisect")
    mlp_params = init_mlp(jax.random.PRNGKey(0))

    def mlp_engine(k, impl):
        parts = partition_indices(
            ytr, k, PartitionConfig(kind="dirichlet", alpha=0.1, min_size=2))
        return CohortEngine(
            mlp_params, mlp_grad_fn,
            ArrayClientData(xtr, ytr, parts, batch_size=1),
            fed_cfg=mlp_fed,
            cohort=CohortConfig(method="fedqcs-ae", impl=impl, record_nmse=False),
            sched=SchedulerConfig(),
            chan=ChannelConfig(kind="awgn", snr_db=10.0),
            server=ServerOptConfig(lr=0.003),
        )

    def timed_rounds(engine, reps):
        engine.run_round()  # compile + warm caches
        engine.run_round()
        t0 = time.time()
        for _ in range(reps):
            engine.run_round()
        return (time.time() - t0) / reps

    rows, entries = [], []
    for label, build, per_client_ms in (
        ("", small_engine, 1.0),  # ~1 ms/client loop cost -> many reps cheap
        ("_mlp", mlp_engine, 2.0),
    ):
        for k in sizes:
            walls = {}
            for impl in ("vmap", "loop"):
                # rep counts sized so each timing window is >~100 ms (the
                # small-cohort walls are a few ms and jitter-sensitive)
                if impl == "vmap":
                    reps = max(3, 320 // k) if fast else max(5, 640 // k)
                else:
                    reps = max(1, int(100.0 / (per_client_ms * k)) + (k <= 64))
                walls[impl] = timed_rounds(build(k, impl), reps)
            for impl in ("vmap", "loop"):
                wall, cps = walls[impl], k / walls[impl]
                name = f"fed_{impl}{label}[c{k}]"
                speedup = walls["loop"] / walls["vmap"]
                derived = (
                    f"cohort={k};clients_per_sec={cps:.1f};"
                    f"speedup_vs_loop={speedup:.2f}"
                )
                rows.append(f"fed[{name}],{1e6 * wall:.1f},{derived}")
                entries.append({
                    "name": name, "wall_ms": round(wall * 1e3, 3),
                    "derived": derived, "cohort": k, "impl": impl,
                    "model": "mnist_mlp" if label else "synthetic_classifier",
                    "clients_per_sec": round(cps, 1),
                    "speedup_vs_loop": round(speedup, 2),
                    "backend": jax.default_backend(),
                })
    path = write_bench("fed", "fed_cohort_scaling", entries)
    rows.append(f"fed[json],0,{os.path.relpath(path)}")
    return rows


def stream_scaling(fast=True):
    """Streaming vs barrier PS decode at census registration scale
    (EXPERIMENTS.md #Stream-bench): the scheduler tracks K registered
    clients (10^4 and 10^6), samples a ~10^3-client cohort, and the PS
    decodes the cohort's wire payloads either one-shot (the barrier path
    materializes every dequantized payload at once, so its decode state
    grows with the sampled cohort) or streamed through arrival-ordered
    batches into the carry-save stat tree.

    The streamed rounds' recorded ``peak_live_stats_bytes`` must be
    IDENTICAL across K — the constant-memory claim CI's bench-smoke job
    validates — and ``stream_vs_barrier_nmse`` must sit inside the pinned
    f32-reassociation tolerance (tests/test_stream.py, NMSE <= 1e-8).
    Payloads are generated once outside every timing window; the walls
    measure the PS decode path only.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import aggregator
    from repro.core.compression import BQCSCodec, FedQCSConfig
    from repro.core.recon_engine import decode_from_stats
    from repro.fed.scheduler import SchedulerConfig, SchedulerState, select_cohort
    from repro.fed.stream import (
        StreamConfig,
        StreamingPS,
        batch_arrivals,
        simulate_arrivals,
        stream_decode,
    )

    fed = FedQCSConfig(block_size=256, reduction_ratio=4, bits=2, s_ratio=0.1,
                       gamp_iters=10 if fast else 15,
                       gamp_variance_mode="scalar")
    codec = BQCSCodec(fed)
    cohort = 1000 if fast else 10_000
    nb = 2
    registered = (10_000, 1_000_000)
    reps = 3 if fast else 5

    # one sampled cohort's wire payloads, shared by every (K, path) cell
    blocks = jax.random.normal(
        jax.random.PRNGKey(0), (cohort, nb, fed.block_size), jnp.float32)
    words, alphas, _ = jax.vmap(codec.compress_blocks_packed)(
        blocks, jnp.zeros_like(blocks))
    jax.block_until_ready(words)
    m = fed.block_size // fed.reduction_ratio

    scfg = StreamConfig(batch_clients=64, buffer_batches=8, fanout=8,
                        deadline=1e9, seed=0)
    ps = StreamingPS(codec, mode="ae", stream=scfg)  # one jit cache, all K
    barrier_fn = jax.jit(lambda wd, al, wt: decode_from_stats(
        codec, aggregator.ae_batch_stats(codec, wd, al, wt)))

    rows, entries = [], []
    for k in registered:
        # the scheduler side really runs at K registrations; only the decode
        # state may not scale with it
        sched = SchedulerConfig(kind="uniform", sample_frac=cohort / k, seed=0)
        ids, rhos, _ = select_cohort(
            sched, SchedulerState.init(k), 0, np.ones(k))
        assert len(ids) == cohort
        w = rhos.astype(np.float32)
        times = simulate_arrivals(scfg, 0, cohort, np.ones(cohort, bool))
        batches = batch_arrivals(times, scfg.deadline, scfg.batch_clients)

        def stream_once():
            return stream_decode(codec, words, alphas, w, batches, ps=ps)

        ghat_s, info = stream_once()  # warm the fold/finalize jits
        jax.block_until_ready(ghat_s)
        t0 = time.time()
        for _ in range(reps):
            ghat_s, info = stream_once()
            jax.block_until_ready(ghat_s)
        wall_s = (time.time() - t0) / reps

        jw = jnp.asarray(w)
        ghat_b = jax.block_until_ready(barrier_fn(words, alphas, jw))
        t0 = time.time()
        for _ in range(reps):
            ghat_b = jax.block_until_ready(barrier_fn(words, alphas, jw))
        wall_b = (time.time() - t0) / reps

        nmse = float(jnp.sum(jnp.square(ghat_s - ghat_b))
                     / (jnp.sum(jnp.square(ghat_b)) + 1e-30))
        stream_peak = int(info["peak_live_stats_bytes"])
        barrier_peak = cohort * nb * m * 4  # the one-shot (C, nb, M) deq array
        for name, wall, peak in (
            (f"stream_round[k{k}]", wall_s, stream_peak),
            (f"barrier_round[k{k}]", wall_b, barrier_peak),
        ):
            derived = (
                f"registered={k};sampled={cohort};"
                f"peak_live_stats_bytes={peak};"
                f"stream_vs_barrier_nmse={nmse:.3e}"
            )
            rows.append(f"stream[{name}],{1e6 * wall:.1f},{derived}")
            entries.append({
                "name": name, "wall_ms": round(wall * 1e3, 3),
                "derived": derived, "registered": k, "sampled": cohort,
                "peak_live_stats_bytes": peak,
                "tree_tiers": int(info["tree_tiers"]),
                "batches": int(info["batches_admitted"]),
                "stream_vs_barrier_nmse": nmse,
                "backend": jax.default_backend(),
            })
    path = write_bench("stream", "stream_scaling", entries)
    rows.append(f"stream[json],0,{os.path.relpath(path)}")
    return rows


def channel_uplink(fast=True):
    """Gather vs over-the-air MIMO-MAC uplink at cohort sizes {32, 256, 1000}
    (EXPERIMENTS.md #Channel-bench; DESIGN.md #Channels).

    Two columns per cohort size K in runs/bench/BENCH_channel.json:

      * ``channel_gather[cK]`` -- the digital gather uplink: every client
        ships its packed wire words to the PS (``uplink_bytes`` grows
        linearly in K) and the PS runs the one-shot AE decode over the
        gathered (K, nb, W) payload matrix.
      * ``channel_mimo[cK]`` -- the mimo_mac family: all K clients transmit
        their Bussgang-pre-scaled dequantized rows SIMULTANEOUSLY, the PS
        receives one (n_rx, nb, M) superimposed signal whose
        ``uplink_bytes`` is CONSTANT in K (the claim CI's bench-smoke job
        validates against this file), and the decode wall is the
        joint-estimation path: spatial combining + EM-GAMP from the
        combined stats.

    The transmit-side superposition (``Y = H X + N``) is nature, not PS
    compute, so it runs outside the mimo timing window; both walls measure
    the PS decode path only.  ``cross_nmse_vs_gather`` records the
    joint-estimation estimate against the gather-decode oracle -- tight only
    where n_rx >= K (the c32 column at n_rx=64; tests/test_channel.py pins
    that regime), and degrading gracefully once the combiner is
    underdetermined (K > n_rx).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import aggregator, bussgang
    from repro.core.compression import BQCSCodec, FedQCSConfig
    from repro.core.recon_engine import decode_from_stats
    from repro.fed.channel import (
        ChannelConfig,
        ChannelRealization,
        get_channel_family,
        mimo_tx_gain,
        realize_uplink,
    )

    fed = FedQCSConfig(block_size=256, reduction_ratio=4, bits=2, s_ratio=0.1,
                       gamp_iters=10 if fast else 15,
                       gamp_variance_mode="scalar")
    codec = BQCSCodec(fed)
    nb = 2
    m = fed.m
    n_rx = 64
    chan = ChannelConfig(kind="mimo_mac", snr_db=40.0, n_rx=n_rx)
    fam = get_channel_family("mimo_mac")
    sizes = (32, 256, 1000)
    reps = 3 if fast else 5

    gather_fn = jax.jit(lambda wd, al, wt: decode_from_stats(
        codec, aggregator.ae_batch_stats(codec, wd, al, wt)))

    def mimo_decode(y_rx, wq, al, wt, active, eta, sigma2, h, h_hat):
        # PS-side joint estimation only: combine the superimposed reception,
        # then EM-GAMP from the combined stats (the engine's MAC decode path).
        real = ChannelRealization(
            jnp.zeros(al.shape, jnp.float32), active,
            h=h, h_hat=h_hat, sigma2=sigma2,
        )
        y_eff, nu = fam.combine(chan, real, y_rx, wq, active,
                                psi=codec.codebook.psi, tx_gain=eta)
        return decode_from_stats(
            codec, aggregator.mimo_batch_stats(codec, y_eff, nu, al, wt))

    mimo_fn = jax.jit(mimo_decode)

    rows, entries = [], []
    for k in sizes:
        blocks = jax.random.normal(
            jax.random.PRNGKey(1), (k, nb, fed.block_size), jnp.float32)
        words, alphas, _ = jax.vmap(codec.compress_blocks_packed)(
            blocks, jnp.zeros_like(blocks))
        w = jnp.ones((k,), jnp.float32)
        nwords = int(words.shape[-1])
        gather_bytes = k * nb * (nwords * 4 + 4)  # packed words + alpha, per client
        mimo_bytes = n_rx * nb * m * 4  # the one (n_rx, nb, M) f32 reception

        # the over-the-air part, outside the timing window: realize the
        # round's H, power-control + pre-scale, superimpose
        real = realize_uplink(chan, jax.random.PRNGKey(2 + k), k, nb)
        deq = codec.codebook.decode_packed(words, m)
        wq = bussgang.bussgang_weight(w[:, None], alphas, codec.codebook)
        active = (w > 0).astype(jnp.float32)
        eta = mimo_tx_gain(wq, active)
        y_rx = jax.block_until_ready(fam.transmit(
            chan, real, (eta * wq)[..., None] * deq, jax.random.PRNGKey(3 + k)))

        ghat_g = jax.block_until_ready(gather_fn(words, alphas, w))
        t0 = time.time()
        for _ in range(reps):
            ghat_g = jax.block_until_ready(gather_fn(words, alphas, w))
        wall_g = (time.time() - t0) / reps

        ghat_m = jax.block_until_ready(mimo_fn(
            y_rx, wq, alphas, w, active, eta, real.sigma2, real.h, real.h_hat))
        t0 = time.time()
        for _ in range(reps):
            ghat_m = jax.block_until_ready(mimo_fn(
                y_rx, wq, alphas, w, active, eta, real.sigma2, real.h,
                real.h_hat))
        wall_m = (time.time() - t0) / reps

        nmse = float(jnp.sum(jnp.square(ghat_m - ghat_g))
                     / (jnp.sum(jnp.square(ghat_g)) + 1e-30))
        for name, wall, nbytes, derived in (
            (f"channel_gather[c{k}]", wall_g, gather_bytes,
             f"cohort={k};uplink_bytes={gather_bytes};wire=gather_codes"),
            (f"channel_mimo[c{k}]", wall_m, mimo_bytes,
             f"cohort={k};uplink_bytes={mimo_bytes};n_rx={n_rx};"
             f"cross_nmse_vs_gather={nmse:.3e}"),
        ):
            rows.append(f"channel[{name}],{1e6 * wall:.1f},{derived}")
            entries.append({
                "name": name, "wall_ms": round(wall * 1e3, 3),
                "derived": derived, "cohort": k,
                "path": "mimo_mac" if "mimo" in name else "gather",
                "uplink_bytes": nbytes, "n_rx": n_rx,
                "cross_nmse_vs_gather": nmse,
                "backend": jax.default_backend(),
            })
    path = write_bench("channel", "channel_uplink", entries)
    rows.append(f"channel[json],0,{os.path.relpath(path)}")
    return rows


def obs_overhead(fast=True):
    """Null-recorder overhead contract (EXPERIMENTS.md #Obs-bench): the
    telemetry layer must be free when no recorder is attached.  Recorder
    activity is STATIC at engine construction (``bool(obs.active)``), so the
    null path builds the exact pre-telemetry jit graphs; the only residual
    cost is a handful of host-side no-op ``span``/``record`` calls per
    round.  Two measurements land in runs/bench/BENCH_obs.json:

      * ``obs_record_call`` / ``obs_span_call`` — direct per-call cost of
        ``NullRecorder.record`` and a collector-less ``span``; a
        conservative 16-call-per-round budget over the measured null-engine
        round wall gives ``overhead_pct``, the < 2% contract CI's
        bench-smoke job validates (direct measurement, not a wall-clock
        A/B, because sub-percent engine-wall deltas drown in jitter).
      * ``fed_round_null`` / ``fed_round_jsonl`` — informational end-to-end
        round walls of a small cohort engine with no recorder vs a live
        JSONL recorder (the jsonl wall includes the spans' blocking
        barriers, the decode-health host syncs, and the flushed line
        write — the cost a user opts into with ``--record``).
    """
    import shutil
    import tempfile

    import jax

    from repro.core.compression import FedQCSConfig
    from repro.fed.channel import ChannelConfig
    from repro.fed.engine import ArrayClientData, CohortConfig, CohortEngine
    from repro.fed.partition import PartitionConfig, partition_indices
    from repro.fed.scheduler import SchedulerConfig
    from repro.fed.server_opt import ServerOptConfig
    from repro.fed.toy import toy_classification, toy_loss, toy_params
    from repro.obs import NULL_RECORDER, JsonlRecorder
    from repro.obs.trace import span

    # -- direct no-op call cost --------------------------------------------
    calls = 20_000 if fast else 100_000
    t0 = time.perf_counter()
    for _ in range(calls):
        NULL_RECORDER.record("round", {"round": 0, "nmse": 0.0})
    record_ns = (time.perf_counter() - t0) / calls * 1e9
    t0 = time.perf_counter()
    for _ in range(calls):
        with span("uplink", None):
            pass
    span_ns = (time.perf_counter() - t0) / calls * 1e9

    # -- end-to-end round walls: null vs jsonl recorder --------------------
    xs, ys = toy_classification(n_samples=2048)
    fed = FedQCSConfig(block_size=64, reduction_ratio=2, bits=3,
                       s_ratio=0.1, gamp_iters=10,
                       gamp_variance_mode="scalar")
    k = 32
    parts = partition_indices(
        ys, k, PartitionConfig(kind="dirichlet", alpha=0.1, min_size=2))

    def build(obs):
        return CohortEngine(
            toy_params(), jax.grad(toy_loss),
            ArrayClientData(xs, ys, parts, batch_size=2),
            fed_cfg=fed,
            cohort=CohortConfig(method="fedqcs-ae", record_nmse=False),
            sched=SchedulerConfig(),
            chan=ChannelConfig(kind="awgn", snr_db=10.0),
            server=ServerOptConfig(lr=0.01),
            obs=obs,
        )

    def timed_rounds(engine, reps):
        engine.run_round()  # compile + warm caches
        engine.run_round()
        t0 = time.time()
        for _ in range(reps):
            engine.run_round()
        return (time.time() - t0) / reps

    reps = 10 if fast else 30
    wall_null = timed_rounds(build(None), reps)
    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    try:
        rec = JsonlRecorder(os.path.join(tmp, "run"))
        wall_jsonl = timed_rounds(build(rec), reps)
        rec.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # A null-path round makes 4 collector-less spans + a few flag checks and
    # at most one no-op record; budget 16 of the costlier call to be safe.
    per_round_s = 16 * max(record_ns, span_ns) * 1e-9
    overhead_pct = 100.0 * per_round_s / wall_null
    jsonl_pct = 100.0 * (wall_jsonl - wall_null) / wall_null

    rows, entries = [], []
    for name, wall_ms, derived, extra in (
        ("obs_record_call", record_ns * 1e-6,
         f"per_call_ns={record_ns:.0f};overhead_pct={overhead_pct:.4f}",
         {"per_call_ns": round(record_ns, 1),
          "overhead_pct": round(overhead_pct, 4)}),
        ("obs_span_call", span_ns * 1e-6,
         f"per_call_ns={span_ns:.0f};overhead_pct={overhead_pct:.4f}",
         {"per_call_ns": round(span_ns, 1),
          "overhead_pct": round(overhead_pct, 4)}),
        ("fed_round_null", wall_null * 1e3,
         f"cohort={k};recorder=null;overhead_pct={overhead_pct:.4f}",
         {"cohort": k, "recorder": "null",
          "overhead_pct": round(overhead_pct, 4)}),
        ("fed_round_jsonl", wall_jsonl * 1e3,
         f"cohort={k};recorder=jsonl;jsonl_vs_null_pct={jsonl_pct:.1f}",
         {"cohort": k, "recorder": "jsonl",
          "jsonl_vs_null_pct": round(jsonl_pct, 1)}),
    ):
        rows.append(f"obs[{name}],{1e3 * wall_ms:.1f},{derived}")
        entries.append({"name": name, "wall_ms": round(wall_ms, 6),
                        "derived": derived, **extra})
    path = write_bench("obs", "obs_overhead", entries)
    rows.append(f"obs[json],0,{os.path.relpath(path)}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (default is fast mode)")
    ap.add_argument("--fast", action="store_true",
                    help="explicit fast mode (the default; what CI runs)")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    if args.full and args.fast:
        ap.error("--full and --fast are mutually exclusive")
    fast = not args.full

    selected_early = [s for s in args.only.split(",") if s]
    if "recon" in (selected_early or ["recon"]):
        # The recon bench shards decode chunks over host devices (the CPU
        # stand-in for the mesh axis, same pattern as tests/conftest.py);
        # must be set before jax initializes, and it is PROCESS-WIDE -- so
        # it is only forced when the recon bench is actually selected, and
        # CI runs recon in its own invocation to keep every other bench's
        # timings on the default single-device baseline they have always
        # been recorded on.  Only the *host* (CPU) platform is affected,
        # and a caller-provided XLA_FLAGS wins.
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
        )

    from benchmarks import paper_figs

    benches = {
        "fig2": paper_figs.fig2_prior_fit,
        "fig3": paper_figs.fig3_accuracy_nmse,
        "fig4": paper_figs.fig4_overhead,
        "fig5": paper_figs.fig5_rq_grid,
        "fig6": paper_figs.fig6_sparsity,
        "table1": paper_figs.table1_complexity,
        "kernels": kernel_micro,
        "gamp": gamp_ea_vs_ae,
        "encode": encode_fused_vs_unfused,
        "interleave": interleave_producer,
        "quant": quant_codebooks,
        "recon": recon_scaling,
        "fed": fed_cohort_scaling,
        "stream": stream_scaling,
        "channel": channel_uplink,
        "obs": obs_overhead,
    }
    selected = [s for s in args.only.split(",") if s] or list(benches)
    print("name,us_per_call,derived")
    failed = 0
    for name in selected:
        try:
            for row in benches[name](fast=fast):
                print(row, flush=True)
        except Exception as e:
            failed += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    # Script form (`python benchmarks/run.py`): sys.path[0] is benchmarks/,
    # so the `benchmarks` package itself is not importable -- add the repo
    # root (the `-m benchmarks.run` form needs no help).
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if _root not in sys.path:
        sys.path.insert(0, _root)
    main()
