"""Benchmark harness: one function per paper table/figure + kernel micros.

    PYTHONPATH=src python -m benchmarks.run            # fast mode
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale horizons
    PYTHONPATH=src python -m benchmarks.run --only fig3,table1

Prints ``name,us_per_call,derived`` CSV; full traces land in runs/bench/.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def kernel_micro(fast=True):
    """Microbench the three Pallas kernels (interpret mode on CPU: validates
    the call path and gives relative-cost numbers, not TPU wall times)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import sensing
    from repro.core.quantizer import design_lloyd_max
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    nb, n, r = (128, 1024, 4)
    m = n // r
    blocks = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    a = sensing.sensing_matrix(jax.random.PRNGKey(0), m, n)
    quant = design_lloyd_max(4)
    rows = []

    def timed(name, fn, derived=""):
        jax.block_until_ready(fn())
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(fn())
        rows.append(f"{name},{1e6 * (time.time() - t0) / reps:.1f},{derived}")

    timed("kernel[bqcs_encode]", lambda: ops.bqcs_encode(blocks, a, quant),
          f"nb={nb};N={n};M={m}")
    timed("kernel[block_topk]", lambda: ops.block_sparsify(blocks, 102), "s=102")
    y = jnp.asarray(rng.normal(0, 1, (nb, m)), jnp.float32)
    nu = jnp.full((nb,), 0.05)
    en = jnp.full((nb,), 1.0)
    timed("kernel[gamp_ae_run]", lambda: ops.gamp_ae_run(y, nu, a, en, iters=10),
          "iters=10")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    fast = not args.full

    from benchmarks import paper_figs

    benches = {
        "fig2": paper_figs.fig2_prior_fit,
        "fig3": paper_figs.fig3_accuracy_nmse,
        "fig4": paper_figs.fig4_overhead,
        "fig5": paper_figs.fig5_rq_grid,
        "fig6": paper_figs.fig6_sparsity,
        "table1": paper_figs.table1_complexity,
        "kernels": kernel_micro,
    }
    selected = [s for s in args.only.split(",") if s] or list(benches)
    print("name,us_per_call,derived")
    failed = 0
    for name in selected:
        try:
            for row in benches[name](fast=fast):
                print(row, flush=True)
        except Exception as e:
            failed += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
