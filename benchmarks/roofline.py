import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis from compiled dry-run artifacts (single-pod 16x16 mesh).

Methodology (see EXPERIMENTS.md #Roofline): XLA's cost_analysis counts a
while-loop body ONCE, so layer-scanned full-depth modules under-report
FLOPs/bytes.  We therefore compile shallow *fully-unrolled* probe variants of
each architecture (1 and 2 layers; 3 probes when two distinct stacks exist)
at the cell's full width/batch, solve for the per-layer and fixed costs, and
extrapolate to full depth:

    total(L) = fixed + L * per_layer          (exact: costs are additive)

Terms per (arch x shape), all per-chip (cost_analysis reports the per-device
partitioned module):

    compute_s    = HLO_FLOPs / 197e12          (bf16 peak, TPU v5e)
    memory_s     = HLO_bytes / 819e9           (HBM bandwidth)
    collective_s = collective_bytes / 50e9     (ICI per-link)

plus MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (fwd-only),
and the usefulness ratio MODEL/HLO.

    PYTHONPATH=src python -m benchmarks.roofline --all
    PYTHONPATH=src python -m benchmarks.roofline --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m benchmarks.roofline --report   # markdown table
"""

import argparse
import json
import sys
import time
import traceback

PEAK_FLOPS = 197e12  # bf16 / chip (v5e)
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link (ICI)
CHIPS = 256

OUT_DIR = "runs/roofline"
DRYRUN_DIR = "runs/dryrun"


def _probe_plan(cfg):
    """Returns (probe_overrides, combine) where combine(list_of_cost_dicts)
    -> full-depth extrapolated costs."""
    fam = cfg.family
    if fam == "audio":
        probes = [
            {"n_encoder_layers": 1, "n_layers": 1},
            {"n_encoder_layers": 2, "n_layers": 1},
            {"n_encoder_layers": 1, "n_layers": 2},
        ]

        def combine(cs):
            enc = {k: cs[1][k] - cs[0][k] for k in cs[0]}
            dec = {k: cs[2][k] - cs[0][k] for k in cs[0]}
            return {
                k: cs[0][k] - enc[k] - dec[k]
                + cfg.n_encoder_layers * enc[k] + cfg.n_layers * dec[k]
                for k in cs[0]
            }

        return probes, combine
    if fam == "moe" and cfg.first_dense_layers:
        probes = [
            {"first_dense_layers": 1, "n_layers": 2},   # 1 dense + 1 moe
            {"first_dense_layers": 2, "n_layers": 3},   # 2 dense + 1 moe
            {"first_dense_layers": 1, "n_layers": 3},   # 1 dense + 2 moe
        ]

        def combine(cs):
            dense = {k: cs[1][k] - cs[0][k] for k in cs[0]}
            moe = {k: cs[2][k] - cs[0][k] for k in cs[0]}
            n_moe = cfg.n_layers - cfg.first_dense_layers
            return {
                k: cs[0][k] - dense[k] - moe[k]
                + cfg.first_dense_layers * dense[k] + n_moe * moe[k]
                for k in cs[0]
            }

        return probes, combine
    if fam == "hybrid":
        probes = [{"n_layers": cfg.attn_every}, {"n_layers": 2 * cfg.attn_every}]
        groups = cfg.n_layers // cfg.attn_every

        def combine(cs):
            per = {k: cs[1][k] - cs[0][k] for k in cs[0]}
            return {k: cs[0][k] - per[k] + groups * per[k] for k in cs[0]}

        return probes, combine
    probes = [{"n_layers": 1}, {"n_layers": 2}]

    def combine(cs):
        per = {k: cs[1][k] - cs[0][k] for k in cs[0]}
        return {k: cs[0][k] - per[k] + cfg.n_layers * per[k] for k in cs[0]}

    return probes, combine


def _compile_probe(cfg, shape: str, mesh):
    """Compiles one probe; returns {'flops', 'bytes', 'coll'} per device."""
    import jax

    from hlo_analysis import collective_bytes
    from repro.models import model as model_api
    from repro.optim.adam import OptConfig
    from repro.runtime import steps

    cell = model_api.SHAPES[shape]

    def attach(sds_tree, sh_tree):
        return jax.tree_util.tree_map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            sds_tree, sh_tree,
        )

    if cell.kind == "train":
        opt = OptConfig(state_dtype="int8" if cfg.param_count() > 50e9 else "float32")
        state = steps.init_train_state(cfg, opt, None, jax.random.PRNGKey(0), abstract=True)
        st_in = attach(state, steps.train_state_shardings(state, mesh, False))
        batch = model_api.input_specs(cfg, shape)
        b_in = attach(batch, steps.batch_shardings(cfg, shape, mesh))
        fn = steps.make_train_step(cfg, opt, None, mesh, donate=True)
        compiled = fn.lower(st_in, b_in).compile()
    elif cell.kind == "prefill":
        params = steps.abstract_params(cfg)
        p_in = attach(params, steps.sane_param_shardings(params, mesh))
        batch = model_api.input_specs(cfg, shape)
        b_in = attach(batch, steps.batch_shardings(cfg, shape, mesh))
        fn = steps.make_prefill_step(cfg, mesh)
        compiled = fn.lower(p_in, b_in).compile()
    else:
        params = steps.abstract_params(cfg)
        p_in = attach(params, steps.sane_param_shardings(params, mesh))
        specs = model_api.input_specs(cfg, shape)
        inputs = attach(specs, steps.batch_shardings(cfg, shape, mesh))
        fn = steps.make_decode_step(cfg, mesh, donate=True)
        compiled = fn.lower(p_in, inputs["cache"], inputs["tokens"], inputs["pos"]).compile()
    cost = dict(compiled.cost_analysis() or {})
    coll = collective_bytes(compiled.as_text()).get("total", 0)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll),
    }


def _model_flops(cfg, shape) -> float:
    """6*N_active*tokens for training, 2*N_active*tokens forward-only (global,
    dense-equivalent convention: attention flops excluded)."""
    from repro.models import model as model_api

    cell = model_api.SHAPES[shape]
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        return 6.0 * n_active * cell.seq * cell.batch
    if cell.kind == "prefill":
        return 2.0 * n_active * cell.seq * cell.batch
    return 2.0 * n_active * cell.batch  # decode: one token per sequence


def roofline_cell(arch: str, shape: str, out_dir: str = OUT_DIR, skip_existing=True):
    import dataclasses as dc

    import jax

    from repro.configs.registry import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.models import model as model_api

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}.json")
    if skip_existing and os.path.exists(path):
        rec = json.load(open(path))
        if rec.get("status") in ("ok", "skip"):
            return rec
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape}
    ok, reason = model_api.supports_cell(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        json.dump(rec, open(path, "w"), indent=1)
        return rec
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    try:
        probes, combine = _probe_plan(cfg)
        costs = []
        for over in probes:
            pcfg = dc.replace(cfg, unroll_layers=True, **over)
            costs.append(_compile_probe(pcfg, shape, mesh))
        total = combine(costs)
        mf_global = _model_flops(cfg, shape)
        mf_dev = mf_global / CHIPS
        compute_s = total["flops"] / PEAK_FLOPS
        memory_s = total["bytes"] / HBM_BW
        coll_s = total["coll"] / LINK_BW
        dom = max(
            (("compute", compute_s), ("memory", memory_s), ("collective", coll_s)),
            key=lambda kv: kv[1],
        )[0]
        bound_s = max(compute_s, memory_s, coll_s)
        rec.update(
            status="ok",
            probes=costs,
            hlo_flops_dev=total["flops"],
            hlo_bytes_dev=total["bytes"],
            coll_bytes_dev=total["coll"],
            compute_s=compute_s,
            memory_s=memory_s,
            collective_s=coll_s,
            dominant=dom,
            model_flops_global=mf_global,
            model_flops_dev=mf_dev,
            useful_ratio=mf_dev / max(total["flops"], 1.0),
            # fraction of the bound the pure-compute term occupies: how close
            # the cell would run to roofline if perfectly overlapped.
            mfu_upper_bound=(mf_dev / PEAK_FLOPS) / max(bound_s, 1e-12),
            wall_s=round(time.time() - t0, 1),
        )
        print(
            f"[roofline] {arch} {shape}: C={compute_s*1e3:.2f}ms M={memory_s*1e3:.2f}ms "
            f"X={coll_s*1e3:.2f}ms dom={dom} useful={rec['useful_ratio']:.2f} "
            f"mfu_ub={rec['mfu_upper_bound']:.2f}"
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}"[:1500],
                   traceback=traceback.format_exc()[-3000:])
        print(f"[roofline] ERROR {arch} {shape}: {type(e).__name__} {str(e)[:150]}")
    json.dump(rec, open(path, "w"), indent=1)
    return rec


def report(out_dir: str = OUT_DIR) -> str:
    import glob

    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rows.append(json.load(open(f)))
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | MODEL/HLO | MFU-UB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | SKIP: {r['reason'][:40]} | -- | -- |")
        elif r.get("status") == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
                f"| {r['collective_s']:.3e} | {r['dominant']} | {r['useful_ratio']:.2f} "
                f"| {r['mfu_upper_bound']:.2f} |"
            )
        else:
            lines.append(f"| {r.get('arch')} | {r.get('shape')} | ERR | | | | | |")
    return "\n".join(lines)


def main():
    sys.path.insert(0, os.path.dirname(__file__))
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--no-skip", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()
    if args.report:
        print(report(args.out))
        return
    from repro.configs.registry import ARCHS
    from repro.models import model as model_api

    cells = (
        [(a, s) for a in ARCHS for s in model_api.SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    for arch, shape in cells:
        roofline_cell(arch, shape, out_dir=args.out, skip_existing=not args.no_skip)


if __name__ == "__main__":
    main()
