"""One benchmark per paper table/figure (Sec. VI).  Each returns CSV rows
(name, us_per_call, derived) where `derived` carries the figure's headline
quantity; full traces are written to runs/bench/*.json.

fast mode (default) shortens the horizons so the suite completes on one CPU
core; pass fast=False (benchmarks.run --full) for paper-scale horizons.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import BQCSCodec, FedQCSConfig, flatten_to_blocks
from repro.core.gamp import GampConfig
from repro.paper import mlp as paper_mlp

OUT_DIR = "runs/bench"


def _dump(name, payload):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1)


def _row(name, wall_s, calls, derived):
    us = 1e6 * wall_s / max(calls, 1)
    return f"{name},{us:.1f},{derived}"


_FAST_STEPS = 120
_FULL_STEPS = 600


def fig2_prior_fit(fast=True):
    """Fig. 2: Bernoulli Gaussian-mixture fit of local gradient sub-vectors.
    derived = max CDF deviation (KS statistic) between empirical gradients
    and the EM-fitted BG-mixture."""
    from repro.core.gamp import make_init_theta, _input_channel, _em_update
    from repro.core.sparsify import block_sparsify
    from repro.data.mnist import load

    key = jax.random.PRNGKey(0)
    params = paper_mlp.init_mlp(key)
    (xtr, ytr, xte, yte), _ = load(0)
    g = paper_mlp.device_grad(params, jnp.asarray(xtr[:64]), jnp.asarray(ytr[:64]))
    blocks, _, _ = flatten_to_blocks(g, 1591)
    sparse, _ = block_sparsify(blocks, 159)
    t0 = time.time()
    # EM fit: iterate the scalar channel at high SNR to learn theta.
    nb = sparse.shape[0]
    # init component spread from the NONZERO energy (the blocks are ~90%
    # zeros; a whole-block std would park every component inside the spike)
    s_frac = 159.0 / 1591.0
    sigma = jnp.maximum(jnp.std(sparse, axis=1), 1e-9) / jnp.sqrt(s_frac)
    theta = make_init_theta(nb, 3, sigma)
    nu = jnp.full(sparse.shape, (0.05 * float(jnp.std(sparse))) ** 2 + 1e-12)
    for _ in range(50):
        _, _, lp0, lp, mp, pp = _input_channel(sparse, nu, theta)
        theta = _em_update(theta, lp0, lp, mp, pp)
    wall = time.time() - t0
    # KS distance on block 0
    lam0, lam, mu, phi = [np.asarray(t) for t in theta]
    xs = np.sort(np.asarray(sparse[0]))
    emp = np.arange(1, xs.size + 1) / xs.size
    from math import erf, sqrt

    def model_cdf(x):
        c = lam0[0] * (x >= 0)
        for l in range(3):
            c = c + lam[0, l] * 0.5 * (1 + erf((x - mu[0, l]) / sqrt(2 * max(phi[0, l], 1e-18))))
        return c

    ks = max(abs(model_cdf(float(x)) - e) for x, e in zip(xs, emp))
    _dump("fig2_prior_fit", {"ks": ks, "theta0": [float(lam0[0])]})
    return [_row("fig2_prior_fit", wall, 50, f"ks={ks:.3f}")]


def fig3_accuracy_nmse(fast=True):
    """Fig. 3: accuracy + NMSE at 1 bit/entry for all five frameworks."""
    steps = _FAST_STEPS if fast else _FULL_STEPS
    fed = FedQCSConfig(reduction_ratio=3, bits=3, s_ratio=0.1,
                       gamp_iters=15 if fast else 25, gamp_variance_mode="scalar")
    rows, payload = [], {}
    methods = ["none", "fedqcs-ea", "fedqcs-ae", "qcs-qiht", "signsgd"]
    if fast:
        methods = ["none", "fedqcs-ea", "fedqcs-ae", "signsgd"]
    for m in methods:
        r = paper_mlp.run_federated(m, steps=steps, fed_cfg=fed, eval_every=max(steps // 8, 1))
        nm = float(np.mean(r.nmses)) if r.nmses else 0.0
        payload[m] = dataclasses.asdict(r)
        rows.append(_row(f"fig3[{m}]", r.wall_s, steps,
                         f"acc={r.accs[-1]:.3f};nmse={nm:.3f};bits={r.bits_per_entry}"))
    _dump("fig3_accuracy_nmse", payload)
    return rows


def fig4_overhead(fast=True):
    """Fig. 4: accuracy vs communication overhead (Q=1..6 at R=3)."""
    steps = _FAST_STEPS if fast else _FULL_STEPS
    qs = (1, 3, 6) if fast else (1, 2, 3, 4, 5, 6)
    rows, payload = [], {}
    for q in qs:
        fed = FedQCSConfig(reduction_ratio=3, bits=q, s_ratio=0.1,
                           gamp_iters=15 if fast else 25, gamp_variance_mode="scalar")
        r = paper_mlp.run_federated("fedqcs-ae", steps=steps, fed_cfg=fed,
                                    eval_every=max(steps // 4, 1), record_nmse=False)
        payload[f"Q{q}"] = dataclasses.asdict(r)
        rows.append(_row(f"fig4[Q={q},R=3]", r.wall_s, steps,
                         f"acc={r.accs[-1]:.3f};bits={q/3.0:.2f}"))
    _dump("fig4_overhead", payload)
    return rows


def fig5_rq_grid(fast=True):
    """Fig. 5: accuracy across (R,Q) at fixed Q/R (1 bit and 0.5 bit)."""
    steps = _FAST_STEPS if fast else _FULL_STEPS
    combos = [(2, 2), (3, 3), (4, 4)] if fast else [(2, 2), (3, 3), (4, 4), (4, 2), (6, 3), (8, 4)]
    rows, payload = [], {}
    for r_, q_ in combos:
        fed = FedQCSConfig(reduction_ratio=r_, bits=q_, s_ratio=0.1,
                           gamp_iters=15 if fast else 25, gamp_variance_mode="scalar")
        rr = paper_mlp.run_federated("fedqcs-ea", steps=steps, fed_cfg=fed,
                                     eval_every=max(steps // 4, 1), record_nmse=False)
        payload[f"R{r_}Q{q_}"] = dataclasses.asdict(rr)
        rows.append(_row(f"fig5[R={r_},Q={q_}]", rr.wall_s, steps, f"acc={rr.accs[-1]:.3f}"))
    _dump("fig5_rq_grid", payload)
    return rows


def fig6_sparsity(fast=True):
    """Fig. 6: accuracy vs S_ratio at (R,Q)=(3,3)."""
    steps = _FAST_STEPS if fast else _FULL_STEPS
    srs = (0.05, 0.1, 0.2) if fast else (0.02, 0.05, 0.1, 0.15, 0.2, 0.3)
    rows, payload = [], {}
    for sr in srs:
        fed = FedQCSConfig(reduction_ratio=3, bits=3, s_ratio=sr,
                           gamp_iters=15 if fast else 25, gamp_variance_mode="scalar")
        r = paper_mlp.run_federated("fedqcs-ae", steps=steps, fed_cfg=fed,
                                    eval_every=max(steps // 4, 1), record_nmse=False)
        payload[f"s{sr}"] = dataclasses.asdict(r)
        rows.append(_row(f"fig6[s={sr}]", r.wall_s, steps, f"acc={r.accs[-1]:.3f}"))
    _dump("fig6_sparsity", payload)
    return rows


def table1_complexity(fast=True):
    """Table I: measured PS reconstruction cost per round for the QCS
    frameworks (+ the analytic complexity orders)."""
    from repro.core import bussgang
    from repro.core.gamp import em_gamp, qem_gamp
    from repro.core.baselines import qiht_reconstruct

    fed = FedQCSConfig(block_size=1591, reduction_ratio=3, bits=3, s_ratio=0.1, gamp_iters=25)
    codec = BQCSCodec(fed)
    k, nb = (8, 10) if fast else (30, 10)
    rng = np.random.default_rng(0)
    blocks = jnp.asarray(rng.standard_t(4, (k, nb, fed.block_size)) * 0.01, jnp.float32)
    codes, alphas = [], []
    for i in range(k):
        c, a, _ = codec.compress_blocks(blocks[i], jnp.zeros_like(blocks[i]))
        codes.append(c)
        alphas.append(a)
    codes, alphas = jnp.stack(codes), jnp.stack(alphas)
    rhos = jnp.full((k,), 1.0 / k)
    gamp = GampConfig(iters=fed.gamp_iters, variance_mode="scalar", tol=0.0)
    rows = []

    def timed(name, fn, order):
        fn()  # compile
        t0 = time.time()
        reps = 3
        for _ in range(reps):
            jax.block_until_ready(fn())
        rows.append(_row(f"table1[{name}]", time.time() - t0, reps, f"order={order}"))

    m = fed.m
    timed("fedqcs-ea",
          lambda: qem_gamp(codes.reshape(-1, m), alphas.reshape(-1), codec.a, codec.quantizer, gamp),
          "O(K*B*M*N*I)")
    def ae():
        y = bussgang.aggregate_codes(codes, alphas, rhos, codec.quantizer)
        nu = bussgang.effective_noise_var(alphas, rhos, codec.quantizer)
        return em_gamp(y, nu, codec.a, gamp)
    timed("fedqcs-ae(G=1)", ae, "O(G*B*M*N*I)")
    timed("qcs-qiht",
          lambda: qiht_reconstruct(codes.reshape(-1, m), alphas.reshape(-1), codec.a,
                                   codec.quantizer, fed.s, iters=25),
          "O(K*B*M*N*I)")
    _dump("table1_complexity", {"rows": rows})
    return rows
