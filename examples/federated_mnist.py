"""End-to-end paper reproduction driver (Sec. VI): train the 784-20-10 MLP
with K=30 non-IID devices and FedQCS compression at 1 bit/entry.

    PYTHONPATH=src python examples/federated_mnist.py --method fedqcs-ae --steps 300
    PYTHONPATH=src python examples/federated_mnist.py --compare   # all methods

Scenario axes beyond the paper (cohort engine, DESIGN.md #Fed-engine):

    # 1000 Dirichlet(0.1) clients, 10% sampling, AWGN 10 dB uplink
    PYTHONPATH=src python examples/federated_mnist.py --clients 1000 \
        --partition dirichlet --alpha 0.1 --sample-frac 0.1 --snr-db 10 --steps 50

    # FedVQCS-style vector codebook at the same wire rate (DESIGN.md #Codebooks)
    PYTHONPATH=src python examples/federated_mnist.py --codebook vq --Q 6 --vq-dim 2

Uses real MNIST if $MNIST_DIR points at the IDX files, else the deterministic
synthMNIST surrogate (see DESIGN.md #Offline-data note).
"""

import argparse

from repro.core.compression import FedQCSConfig
from repro.paper.mlp import run_federated

METHODS = ["fedqcs-ea", "fedqcs-ae", "qcs-qiht", "qcs-dither", "signsgd", "none"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="fedqcs-ae", choices=METHODS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--R", type=int, default=3)
    ap.add_argument("--Q", type=int, default=3)
    ap.add_argument("--s-ratio", type=float, default=0.1)
    ap.add_argument("--compare", action="store_true")
    # -- quantizer codebook axis (DESIGN.md #Codebooks) --------------------
    ap.add_argument("--codebook", default="lloyd_max",
                    choices=["lloyd_max", "dithered_uniform", "vq"])
    ap.add_argument("--vq-dim", type=int, default=2,
                    help="vector-codebook dimension d (with --codebook vq); "
                    "wire drops to Q/d bits per measurement")
    # -- cohort scenario axes (defaults reproduce the paper) ---------------
    ap.add_argument("--clients", type=int, default=30)
    ap.add_argument("--partition", default="paper",
                    choices=["paper", "iid", "shard", "dirichlet"])
    ap.add_argument("--alpha", type=float, default=0.1,
                    help="Dirichlet concentration (with --partition dirichlet)")
    ap.add_argument("--sample-frac", type=float, default=1.0,
                    help="cohort fraction per round (uniform sampling when < 1)")
    ap.add_argument("--snr-db", type=float, default=None,
                    help="uplink SNR in dB (unset = ideal channel)")
    ap.add_argument("--channel", default=None,
                    help="uplink family (ideal/awgn/rayleigh/mimo_mac; "
                         "default: awgn when --snr-db is set, else ideal)")
    ap.add_argument("--n-rx", type=int, default=8,
                    help="mimo_mac receive antennas")
    ap.add_argument("--csi-error", type=float, default=0.0,
                    help="mimo_mac CSI estimate error variance")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-round straggler probability")
    ap.add_argument("--chunk", type=int, default=0,
                    help="clients per scan chunk (0 = whole cohort in one pass)")
    ap.add_argument("--record", default=None, metavar="RUN_DIR",
                    help="record round/eval events to RUN_DIR (events.jsonl + "
                         "meta.json; render with `python -m repro.obs "
                         "summarize RUN_DIR`); --compare appends the method "
                         "name per row")
    args = ap.parse_args()

    fed = FedQCSConfig(reduction_ratio=args.R, bits=args.Q, s_ratio=args.s_ratio,
                       gamp_iters=25, gamp_variance_mode="scalar",
                       codebook=args.codebook, vq_dim=args.vq_dim)
    # (method, codebook) scenario grid: --compare runs the full baseline
    # roster (all six documented methods) PLUS the FedQCS rows under each
    # alternative codebook family -- EA/AE/dither/VQ under one harness.
    if args.compare:
        rows = [(m, "lloyd_max", args.Q) for m in METHODS[::-1]]
        # dithered-uniform wire path at the same Q; vq at Q*vq_dim bits per
        # code = the same Q bits per measurement (equal wire, FedVQCS gain).
        rows += [("fedqcs-ae", "dithered_uniform", args.Q),
                 ("fedqcs-ea", "dithered_uniform", args.Q)]
        # Validate the vq rows UP FRONT (the paper blocking fixes N=1591, so
        # M=1591//R): an incompatible (R, Q, d) must not burn the whole
        # baseline sweep before dying on the last rows.
        vq_bits = args.Q * args.vq_dim
        m_paper = 1591 // args.R
        if vq_bits > 8:
            print(f"  (skipping vq rows: Q*d = {vq_bits} bits/code > 8)")
        elif m_paper % args.vq_dim:
            print(f"  (skipping vq rows: vq_dim={args.vq_dim} does not divide "
                  f"M={m_paper})")
        else:
            rows += [("fedqcs-ae", "vq", vq_bits),
                     ("fedqcs-ea", "vq", vq_bits)]
    else:
        rows = [(args.method, args.codebook, args.Q)]
    cohort_kw = dict(
        k_devices=args.clients,
        partition=args.partition,
        alpha=args.alpha,
        scheduler="uniform" if args.sample_frac < 1.0 else "full",
        sample_frac=args.sample_frac,
        dropout=args.dropout,
        channel=args.channel
        or ("awgn" if args.snr_db is not None else "ideal"),
        snr_db=args.snr_db if args.snr_db is not None else 20.0,
        n_rx=args.n_rx,
        csi_error=args.csi_error,
        chunk=args.chunk,
    )
    print(f"(R,Q)=({args.R},{args.Q}) -> {fed.bits_per_entry:.2f} bits/entry "
          f"[{args.codebook}]; "
          f"K={args.clients} {args.partition} devices; {args.steps} rounds; "
          f"channel={cohort_kw['channel']}")
    print(f"{'method':24s} {'bits/entry':>10s} {'final acc':>9s} {'mean NMSE':>9s} {'wall':>6s}")
    import dataclasses as _dc

    from repro.fed.channel import get_channel_family

    for m, cbk, q in rows:
        kw = dict(cohort_kw)
        if m != "fedqcs-ae" and not get_channel_family(kw["channel"]).exact_codes:
            # code-domain methods need the exact codes at the PS: only the
            # Bussgang-linearized AE path absorbs uplink noise (DESIGN.md)
            print(f"  ({m}: noisy uplink unsupported -> ideal channel)")
            kw["channel"] = "ideal"
        row_fed = _dc.replace(fed, codebook=cbk, bits=q, vq_dim=args.vq_dim)
        recorder = None
        if args.record:
            from repro.obs import JsonlRecorder

            label = m if cbk == "lloyd_max" else f"{m}+{cbk}"
            run_dir = (
                f"{args.record}/{label}" if len(rows) > 1 else args.record
            )
            recorder = JsonlRecorder(
                run_dir,
                config={"method": m, "codebook": cbk, "Q": q, **cohort_kw},
            )
        r = run_federated(m, steps=args.steps, fed_cfg=row_fed,
                          eval_every=max(args.steps // 10, 1), obs=recorder, **kw)
        if recorder is not None:
            recorder.close()
        nm = sum(r.nmses) / len(r.nmses) if r.nmses else float("nan")
        label = m if cbk == "lloyd_max" else f"{m}+{cbk}"
        print(f"{label:24s} {r.bits_per_entry:10.2f} {r.accs[-1]:9.3f} {nm:9.3f} {r.wall_s:5.0f}s")
        print(f"  acc trace: {[round(a, 3) for a in r.accs]}")
    if args.record:
        print(f"run log(s) in {args.record}: "
              f"render with `python -m repro.obs summarize <run_dir>`")


if __name__ == "__main__":
    main()
