"""End-to-end paper reproduction driver (Sec. VI): train the 784-20-10 MLP
with K=30 non-IID devices and FedQCS compression at 1 bit/entry.

    PYTHONPATH=src python examples/federated_mnist.py --method fedqcs-ae --steps 300
    PYTHONPATH=src python examples/federated_mnist.py --compare   # all methods

Uses real MNIST if $MNIST_DIR points at the IDX files, else the deterministic
synthMNIST surrogate (see DESIGN.md #Offline-data note).
"""

import argparse

from repro.core.compression import FedQCSConfig
from repro.paper.mlp import run_federated


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="fedqcs-ae",
                    choices=["fedqcs-ea", "fedqcs-ae", "qcs-qiht", "qcs-dither",
                             "signsgd", "none"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--R", type=int, default=3)
    ap.add_argument("--Q", type=int, default=3)
    ap.add_argument("--s-ratio", type=float, default=0.1)
    ap.add_argument("--compare", action="store_true")
    args = ap.parse_args()

    fed = FedQCSConfig(reduction_ratio=args.R, bits=args.Q, s_ratio=args.s_ratio,
                       gamp_iters=25, gamp_variance_mode="scalar")
    methods = (
        ["none", "fedqcs-ea", "fedqcs-ae", "qcs-qiht", "signsgd"]
        if args.compare else [args.method]
    )
    print(f"(R,Q)=({args.R},{args.Q}) -> {args.Q/args.R:.2f} bits/entry; "
          f"K=30 non-IID devices; {args.steps} rounds")
    print(f"{'method':12s} {'bits/entry':>10s} {'final acc':>9s} {'mean NMSE':>9s} {'wall':>6s}")
    for m in methods:
        r = run_federated(m, steps=args.steps, fed_cfg=fed,
                          eval_every=max(args.steps // 10, 1))
        nm = sum(r.nmses) / len(r.nmses) if r.nmses else float("nan")
        print(f"{m:12s} {r.bits_per_entry:10.2f} {r.accs[-1]:9.3f} {nm:9.3f} {r.wall_s:5.0f}s")
        print(f"  acc trace: {[round(a, 3) for a in r.accs]}")


if __name__ == "__main__":
    main()
