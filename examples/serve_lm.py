"""Batched serving demo: prefill a prompt batch, then greedy-decode.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-1.3b --tokens 24

Exercises the prefill -> decode cache handoff for any architecture in the
zoo (reduced config on CPU; the full configs are exercised by the dry-run).
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_config
from repro.launch.mesh import make_single_device_mesh
from repro.models import model as M
from repro.runtime import steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    mesh = make_single_device_mesh()
    cfg = smoke_config(args.arch)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} has no decode step")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    smax = args.prompt_len + args.tokens

    key = jax.random.PRNGKey(1)
    if cfg.family == "audio":
        batch = {"frames": jax.random.normal(key, (args.batch, args.prompt_len, cfg.d_model)) * 0.02}
    else:
        batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}

    prefill_fn = steps.make_prefill_step(cfg, mesh)
    decode_fn = steps.make_decode_step(cfg, mesh, donate=False)

    logits, cache = prefill_fn(params, batch)
    # Grow the self-attn cache to smax for decoding (SSM caches are O(1)).
    if cfg.family not in ("ssm",):
        full = M.init_cache(cfg, args.batch, smax)

        def splice(dst, src):
            if dst.shape == src.shape:
                return src
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src, pad)

        cache = jax.tree.map(splice, full, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]
    for t in range(args.tokens - 1):
        tok, _, cache = decode_fn(params, cache, tok, jnp.int32(args.prompt_len + t))
        outs.append(tok)
    seq = jnp.concatenate(outs, axis=1)
    print(f"{args.arch}: decoded {seq.shape} tokens")
    for row in range(min(2, args.batch)):
        print("  sample", row, ":", list(map(int, seq[row, :12])))


if __name__ == "__main__":
    main()
