"""Quickstart: compress a gradient pytree with BQCS, reconstruct at the PS.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full pipeline on one simulated round: block sparsification
(+ error feedback), random projection, Lloyd-Max quantization, then both
reconstruction strategies (estimate-and-aggregate / aggregate-and-estimate),
and prints NMSE + wire accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core.compression import FedQCSConfig


def main():
    rng = np.random.default_rng(0)
    # A fake "model gradient": any pytree of arrays works.
    grads = {
        "dense/w": jnp.asarray(rng.standard_t(4, (256, 128)) * 0.01, jnp.float32),
        "dense/b": jnp.asarray(rng.standard_t(4, (128,)) * 0.01, jnp.float32),
        "head/w": jnp.asarray(rng.standard_t(4, (128, 64)) * 0.01, jnp.float32),
    }
    n_entries = sum(x.size for x in jax.tree.leaves(grads))

    cfg = FedQCSConfig(
        block_size=1024,      # N
        reduction_ratio=4,    # R = N/M
        bits=2,               # Q  -> Q/R = 0.5 bits per gradient entry
        s_ratio=0.05,         # top-5% kept per block
        gamp_iters=30,
    )
    codec = api.make_codec(cfg)
    print(f"protocol: N={cfg.block_size} M={cfg.m} Q={cfg.bits} "
          f"-> {cfg.bits_per_entry:.3f} bits/entry (fp32 baseline: 32)")

    # --- K=4 simulated workers, each with its own noisy gradient + EF state
    k = 4
    workers = [
        jax.tree.map(lambda x: x + jnp.asarray(rng.normal(0, 0.002, x.shape), jnp.float32), grads)
        for _ in range(k)
    ]
    states = [api.init_state(codec, grads) for _ in range(k)]
    payloads = []
    for i in range(k):
        p, spec, states[i] = api.compress(codec, workers[i], states[i])
        payloads.append(p)
    rhos = [1.0 / k] * k
    # payload.codes IS the wire format (packed uint32 words); wire_bits is
    # derived from the actual word count, alphas included.
    bits = payloads[0].wire_bits()
    assert payloads[0].codes.dtype == jnp.uint32
    print(f"wire: {bits} bits/worker/round = {bits / n_entries:.3f} bits/entry")

    truth = jax.tree.map(lambda *xs: sum(r * x for r, x in zip(rhos, xs)), *workers)
    for mode in ("ea", "ae"):
        ghat = api.reconstruct(codec, payloads, rhos, spec,
                               recon=api.ReconSpec(mode=mode))
        num = sum(float(jnp.sum((a - b) ** 2)) for a, b in
                  zip(jax.tree.leaves(ghat), jax.tree.leaves(truth)))
        den = sum(float(jnp.sum(b**2)) for b in jax.tree.leaves(truth))
        print(f"reconstruction [{mode}]: NMSE vs dense truth = {num / den:.4f}")
    print("(error feedback carries the sparsification remainder to the next round)")


if __name__ == "__main__":
    main()
