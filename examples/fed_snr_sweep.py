"""Accuracy vs uplink SNR: the wireless axis the cohort engine opens
(EXPERIMENTS.md #Fed-cohort).

Sweeps the AWGN (or Rayleigh block-fading) uplink SNR for FedQCS-AE on the
paper's MNIST MLP with a Dirichlet non-IID federation and partial
participation, and prints the accuracy/NMSE ladder — the channel's effective
noise variance threads into EM-GAMP's ``noise_var`` (eq. 24 + channel term),
so reconstruction degrades gracefully as the uplink worsens instead of the
codec silently assuming a clean wire.

    PYTHONPATH=src python examples/fed_snr_sweep.py                # defaults
    PYTHONPATH=src python examples/fed_snr_sweep.py --channel rayleigh \
        --clients 200 --sample-frac 0.2 --steps 60
"""

import argparse
import json
import os

from repro.core.compression import FedQCSConfig
from repro.paper.mlp import run_federated


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--sample-frac", type=float, default=0.3)
    ap.add_argument("--channel", default="awgn", choices=["awgn", "rayleigh"])
    ap.add_argument("--snrs", default="0,5,10,20",
                    help="comma-separated SNR (dB) points; 'ideal' is always run")
    ap.add_argument("--json-out", default="runs/bench/fed_snr_sweep.json")
    args = ap.parse_args()

    fed = FedQCSConfig(reduction_ratio=3, bits=3, s_ratio=0.1,
                       gamp_iters=15, gamp_variance_mode="scalar")
    common = dict(
        steps=args.steps, fed_cfg=fed, k_devices=args.clients,
        partition="dirichlet", alpha=args.alpha,
        scheduler="uniform", sample_frac=args.sample_frac,
        eval_every=max(args.steps // 4, 1),
    )
    points = [("ideal", None)] + [
        (args.channel, float(s)) for s in args.snrs.split(",") if s
    ]
    print(f"FedQCS-AE, K={args.clients} Dirichlet(alpha={args.alpha}), "
          f"{args.sample_frac:.0%} sampling, {args.steps} rounds")
    print(f"{'uplink':>14s} {'final acc':>9s} {'mean NMSE':>9s}")
    results = []
    for kind, snr in points:
        r = run_federated(
            "fedqcs-ae", channel=kind, snr_db=snr if snr is not None else 20.0,
            **common,
        )
        nm = sum(r.nmses) / len(r.nmses) if r.nmses else float("nan")
        label = "ideal" if kind == "ideal" else f"{kind}@{snr:g}dB"
        print(f"{label:>14s} {r.accs[-1]:9.3f} {nm:9.3f}")
        results.append({"uplink": label, "snr_db": snr, "acc": r.accs[-1],
                        "accs": r.accs, "mean_nmse": nm, "wall_s": r.wall_s})
    if args.json_out:
        os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
        with open(args.json_out, "w") as f:
            json.dump({"sweep": "accuracy_vs_snr", "channel": args.channel,
                       "clients": args.clients, "alpha": args.alpha,
                       "sample_frac": args.sample_frac, "results": results}, f,
                      indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
