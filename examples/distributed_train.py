"""Distributed LM training with FedQCS cross-pod gradient compression.

    PYTHONPATH=src python examples/distributed_train.py --steps 40
    PYTHONPATH=src python examples/distributed_train.py --arch qwen2-7b --steps 40
    PYTHONPATH=src python examples/distributed_train.py --inject-failure 20

Runs a reduced config of the chosen architecture on a simulated
(pod=2, data=2, model=2) mesh, with: FedQCS compressed cross-pod reduction,
checkpoint every 10 steps, optional pod-failure injection (the step keeps
going on the surviving pod via rho renormalization), and exact restart.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.checkpoint.checkpointer import Checkpointer  # noqa: E402
from repro.configs.registry import smoke_config  # noqa: E402
from repro.core.compression import FedQCSConfig  # noqa: E402
from repro.data.synthetic import TokenDataset  # noqa: E402
from repro.launch.mesh import make_debug_mesh  # noqa: E402
from repro.optim.adam import OptConfig  # noqa: E402
from repro.runtime import steps  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--ckpt-dir", default="runs/example_ckpt")
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="step at which pod 1 dies for 5 steps")
    ap.add_argument("--no-fedqcs", action="store_true")
    args = ap.parse_args()

    mesh = make_debug_mesh(2, 2, 2)
    cfg = smoke_config(args.arch)
    fed = None if args.no_fedqcs else FedQCSConfig(
        block_size=255, reduction_ratio=3, bits=3, s_ratio=0.05,
        gamp_iters=15, gamp_variance_mode="scalar",
    )
    opt = OptConfig(lr=3e-3, warmup_steps=5, decay_steps=2000)
    ds = TokenDataset(cfg.vocab_size, batch=16, seq=64, seed=0)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)

    state = steps.init_train_state(cfg, opt, fed, jax.random.PRNGKey(0), n_pods=2)
    start = 0
    if ckpt.latest_step() is not None:
        state, start = ckpt.restore(state)
        print(f"[restore] resumed from step {start}")
    step_fn = steps.make_train_step(cfg, opt, fed, mesh, donate=False)

    if fed is not None:
        nb = state["residual"].shape[1]
        bits = nb * (fed.m * fed.bits + 32)
        print(f"[wire] compressed payload/pod/step: {bits/8/1024:.0f} KiB "
              f"({fed.bits_per_entry:.2f} bits/entry; fp32 all-reduce would be "
              f"{nb*fed.block_size*32/8/1024:.0f} KiB)")

    for t in range(start, args.steps):
        if fed is not None:
            alive = 0.0 if (args.inject_failure >= 0 and args.inject_failure <= t < args.inject_failure + 5) else 1.0
            state["participating"] = jnp.asarray([1.0, alive])
        state, metrics = step_fn(state, ds.get_batch(t))
        if t % 5 == 0 or t == args.steps - 1:
            note = " [pod1 DOWN]" if fed is not None and float(state["participating"][1]) == 0 else ""
            print(f"step {t:4d}  loss {float(metrics['loss']):.4f}{note}")
        if t and t % 10 == 0:
            ckpt.save(t, state)
    ckpt.wait()
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
