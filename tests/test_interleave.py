"""Backward-interleaved segment producer (DESIGN.md #Interleave).

Correctness bars pinned here:

* producer gradients match ``jax.vmap(jax.grad(train_loss))`` at allclose
  across the staged registry families (NOT bitwise: the staged VJP and the
  monolithic grad are different XLA programs, so fusion differs at ~1e-8);
* the streamed WIRE through the engine is bit-identical to the one-pass
  encode of the producer's own gradient tree (``grads_fn``), for multiple
  families x grad_accum x emission order -- the segments path and the tree
  path share the same stage-gradient arrays, so this holds exactly;
* the engine's streamed-pass contract: duplicate / unknown / missing
  segment indices raise;
* the per-segment encode donates its residual slice (satellite of the
  interleave PR: the new residual writes into the gathered rows in place);
* ``backward`` / ``encode_overlap`` sub-phases land in round telemetry and
  stay out of the ``round_ms`` total.
"""

import functools
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.core.compression import FedQCSConfig
from repro.fed.engine import (
    CohortConfig,
    CohortEngine,
    make_interleaved_segments,
)
from repro.models import model as M
from repro.models.segment_tap import (
    InterleavedSegments,
    build_stages,
    interleaved_layout,
)

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)
C = 2  # cohort size for all producer tests
FED = FedQCSConfig(block_size=64, reduction_ratio=2, bits=3, gamp_iters=4)

STAGED_ARCHS = [
    "qwen3-0.6b",        # dense, tied embed
    "deepseek-v3-671b",  # moe + MLA + mtp + leading dense layer, untied
    "mamba2-1.3b",       # ssm, tied
    "zamba2-2.7b",       # hybrid (weight-shared attention block), untied
    "qwen2-vl-7b",       # vlm (patch prefix + M-RoPE positions)
]
WIRE_ARCHS = ["qwen3-0.6b", "mamba2-1.3b", "zamba2-2.7b"]


def _cohort_batch(cfg, b=2, s=16):
    """(C, ...) cohort batch, tokens varied per client."""
    if cfg.family == "vlm":
        sv = 4
        one = lambda k: {  # noqa: E731
            "tokens": jnp.full((b, s - sv), 1 + k, jnp.int32) % cfg.vocab_size,
            "labels": jnp.full((b, s - sv), 2 + k, jnp.int32) % cfg.vocab_size,
            "patches": jnp.full((b, sv, cfg.d_model), 0.01 * (k + 1),
                                jnp.float32),
            "positions": jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32), (3, b, s)
            ),
        }
    else:
        one = lambda k: {  # noqa: E731
            "tokens": (jnp.ones((b, s), jnp.int32) + k) % cfg.vocab_size,
            "labels": (jnp.ones((b, s), jnp.int32) + 2 * k) % cfg.vocab_size,
        }
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[one(k) for k in range(C)]
    )


@functools.lru_cache(maxsize=None)
def _setup(arch, layer_chunks=2, grad_accum=1):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, KEY)
    chunks = 1 if cfg.family == "hybrid" else layer_chunks
    layout = interleaved_layout(cfg, FED.block_size, layer_chunks=chunks)
    prod = make_interleaved_segments(
        cfg, layout, grad_accum=grad_accum, layer_chunks=chunks
    )
    return cfg, params, layout, prod


def _one_pass_hook(prod):
    """One-pass reference hook: materialize the producer's own tree, then
    slice segments layout-order -- the wire-identity oracle."""

    def hook(params, batch, layout):
        tree = prod.grads_fn(params, batch)
        for seg in layout.segments:
            yield seg.index, layout.segment_blocks_batched(tree, seg.index)

    return hook


def _shuffled_hook(prod):
    """Producer output re-emitted in a fixed shuffled order: the engine's
    streamed pass accepts any segment order."""

    def hook(params, batch, layout):
        out = list(prod(params, batch, layout))
        random.Random(7).shuffle(out)
        yield from out

    return hook


def _engine(params, layout, hook, grad_accum=1, cfg=None, obs=None):
    data = _FakeData()
    return CohortEngine(
        params,
        # grad_fn unused by the hooked streamed pass but required
        jax.grad(lambda p, b: M.train_loss(p, b, cfg)),
        data,
        fed_cfg=FED,
        cohort=CohortConfig(method="fedqcs-ae", encode_stream=True,
                            record_nmse=False, grad_accum=grad_accum,
                            seed=3),
        layout=layout,
        grad_segments_fn=hook,
        obs=obs,
    )


class _FakeData:
    """Engine-constructible stand-in; tests drive the client pass directly
    except the span test, which uses :meth:`cohort_batch`."""

    def __init__(self):
        self.counts = np.ones(C, np.int64)
        self.batch = None  # set by tests that run full rounds

    def cohort_batch(self, round_idx, ids):
        return jax.tree_util.tree_map(lambda x: x[ids], self.batch)


def _streamed(eng, params, batch):
    res = jnp.zeros((C, eng.nb, eng.n), jnp.float32)
    rhos = jnp.ones((C,), jnp.float32)
    return eng._client_pass_streamed(params, batch, res, rhos, rhos)


def _assert_trees_equal(a, b, exact=True):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for (path, x), y in zip(la, lb):
        if exact:
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y), err_msg=str(path)
            )
        else:
            # staged VJP vs monolithic grad are different XLA programs;
            # hybrid's weight-shared sums add cancellation noise on top
            np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), rtol=2e-4, atol=5e-5,
                err_msg=str(path),
            )


# ---------------------------------------------------------------------------
# gradients: staged VJP vs monolithic jax.grad
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", STAGED_ARCHS)
def test_producer_grads_allclose_vs_jax_grad(arch):
    cfg, params, layout, prod = _setup(arch)
    batch = _cohort_batch(cfg)
    ref = jax.vmap(
        jax.grad(lambda p, b: M.train_loss(p, b, cfg)), in_axes=(None, 0)
    )(params, batch)
    tree = prod.grads_fn(params, batch)
    assert (jax.tree_util.tree_structure(tree)
            == jax.tree_util.tree_structure(params))
    _assert_trees_equal(ref, tree, exact=False)


@pytest.mark.parametrize("arch", STAGED_ARCHS)
def test_producer_segments_cover_layout_in_backward_order(arch):
    cfg, params, layout, prod = _setup(arch)
    batch = _cohort_batch(cfg)
    seen = [idx for idx, _ in prod(params, batch, layout)]
    assert sorted(seen) == list(range(len(layout.segments)))
    # the stream is NOT layout order (backward order differs) unless the
    # model degenerates to one stage per segment in layout order
    if len(layout.segments) > 2:
        assert seen != list(range(len(layout.segments)))


def test_grad_accum_matches_engine_tree_fn():
    """Producer microbatching mirrors the engine's _grads_tree_fn at
    allclose (same mb split, mb-order sums, final /acc -- but per stage)."""
    cfg, params, layout, _ = _setup("qwen3-0.6b")
    prod = make_interleaved_segments(cfg, layout, grad_accum=4, layer_chunks=2)
    batch = _cohort_batch(cfg, b=4)
    eng = _engine(params, layout, prod, grad_accum=4, cfg=cfg)
    ref = eng._grads_tree_jit(params, batch)
    _assert_trees_equal(ref, prod.grads_fn(params, batch), exact=False)


# ---------------------------------------------------------------------------
# wire bit-identity through the engine's streamed pass
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", WIRE_ARCHS)
@pytest.mark.parametrize("grad_accum", [1, 4])
def test_wire_bit_identity(arch, grad_accum):
    """Interleaved (backward-order AND shuffled out-of-order) payloads and
    residuals are bitwise equal to the one-pass encode of the producer's own
    gradient tree."""
    cfg, params, layout, prod = _setup(arch, grad_accum=grad_accum)
    batch = _cohort_batch(cfg, b=4 if grad_accum > 1 else 2)
    eng = _engine(params, layout, prod, grad_accum=grad_accum, cfg=cfg)
    pay_ref, res_ref = _streamed(
        _engine(params, layout, _one_pass_hook(prod), grad_accum=grad_accum,
                cfg=cfg),
        params, batch,
    )
    for hook in (prod, _shuffled_hook(prod)):
        eng._grad_segments_fn = hook
        pay, res = _streamed(eng, params, batch)
        _assert_trees_equal(pay_ref, pay)
        np.testing.assert_array_equal(np.asarray(res_ref), np.asarray(res))


# ---------------------------------------------------------------------------
# streamed-pass contract: duplicate / unknown / missing segments
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _contract_fixture():
    cfg, params, layout, prod = _setup("qwen3-0.6b")
    eng = _engine(params, layout, prod, cfg=cfg)
    return cfg, params, layout, prod, eng


def test_duplicate_segment_raises():
    cfg, params, layout, prod, eng = _contract_fixture()

    def dup(p, b, lo):
        it = prod(p, b, lo)
        first = next(it)
        yield first
        yield first

    eng._grad_segments_fn = dup
    with pytest.raises(ValueError, match="twice"):
        _streamed(eng, params, _cohort_batch(cfg))


def test_unknown_segment_index_raises():
    cfg, params, layout, prod, eng = _contract_fixture()
    eng._grad_segments_fn = lambda p, b, lo: iter(
        [(len(lo.segments), jnp.zeros((C, 1, lo.n), jnp.float32))]
    )
    with pytest.raises(ValueError, match="layout has"):
        _streamed(eng, params, _cohort_batch(cfg))


def test_missing_segment_raises():
    cfg, params, layout, prod, eng = _contract_fixture()

    def partial(p, b, lo):
        yield next(prod(p, b, lo))

    eng._grad_segments_fn = partial
    with pytest.raises(ValueError, match="never yielded"):
        _streamed(eng, params, _cohort_batch(cfg))


def test_engine_requires_encode_stream_for_hook():
    cfg, params, layout, prod, eng = _contract_fixture()
    with pytest.raises(ValueError, match="encode_stream"):
        CohortEngine(
            params,
            jax.grad(lambda p, b: M.train_loss(p, b, cfg)),
            _FakeData(),
            fed_cfg=FED,
            cohort=CohortConfig(method="fedqcs-ae", encode_stream=False),
            layout=layout,
            grad_segments_fn=prod,
        )


def test_producer_rejects_foreign_layout():
    cfg, params, layout, prod, _ = _contract_fixture()
    other = interleaved_layout(cfg, FED.block_size, layer_chunks=1)
    with pytest.raises(ValueError, match="layout"):
        next(prod(params, _cohort_batch(cfg), other))


def test_vlm_grad_accum_rejected():
    cfg = smoke_config("qwen2-vl-7b")
    layout = interleaved_layout(cfg, FED.block_size)
    with pytest.raises(ValueError, match="VLM"):
        make_interleaved_segments(cfg, layout, grad_accum=2)


def test_hybrid_layer_chunks_rejected():
    cfg = smoke_config("zamba2-2.7b")
    with pytest.raises(ValueError, match="weight-shared"):
        build_stages(cfg, jax.eval_shape(
            lambda: M.init_params(cfg, jax.random.PRNGKey(0))
        ), layer_chunks=2)


def test_audio_family_rejected():
    cfg = smoke_config("whisper-base")
    layout = interleaved_layout(cfg, FED.block_size)
    with pytest.raises(NotImplementedError, match="audio"):
        InterleavedSegments(cfg, layout)


# ---------------------------------------------------------------------------
# residual donation through the per-segment encode
# ---------------------------------------------------------------------------


def test_encode_seg_jit_donates_residual():
    """The streamed per-segment encode aliases its residual-slice input to
    an output (donate_argnums): visible in the compiled HLO, and the donated
    buffer errors on reuse."""
    cfg, params, layout, prod, eng = _contract_fixture()
    seg = layout.segments[0]
    blocks = jnp.zeros((C, seg.rows, eng.n), jnp.float32)
    res = jnp.ones((C, seg.rows, eng.n), jnp.float32)
    rhos = jnp.ones((C,), jnp.float32)
    s = layout.segment_s(FED.s)[0]
    hlo = eng._encode_seg_jit.lower(blocks, res, rhos, s).compile().as_text()
    assert "input_output_alias" in hlo
    eng._encode_seg_jit(blocks, res, rhos, s)
    with pytest.raises(RuntimeError):
        _ = np.asarray(res)  # donated: buffer deleted


def test_producer_donates_boundary_carries():
    """The stage backward jits donate the boundary carry (consumed exactly
    once; the carry cotangent writes into it)."""
    cfg, params, layout, prod, eng = _contract_fixture()
    # stage 1 (first layer chunk) has a carry; lower its bwd jit
    batch = _cohort_batch(cfg)
    list(prod(params, batch, layout))  # compile everything
    bwd = prod._bwd_jits[1]
    sel = prod.stages[1].select(params)
    ctx = prod._ctx_jit(batch)
    b, s = batch["tokens"].shape[1], batch["tokens"].shape[2]
    x = jnp.zeros((C, b, s, cfg.d_model), jnp.float32)
    ct = jnp.zeros((C, b, s, cfg.d_model), jnp.float32)
    hlo = bwd.lower(sel, x, ct, ctx).compile().as_text()
    assert "input_output_alias" in hlo


# ---------------------------------------------------------------------------
# telemetry: backward / encode_overlap sub-phases
# ---------------------------------------------------------------------------


def test_interleave_spans_recorded():
    from repro.obs.recorder import InMemoryRecorder
    from repro.obs.trace import SUB_PHASES

    cfg, params, layout, prod = _setup("qwen3-0.6b")
    data = _FakeData()
    data.batch = _cohort_batch(cfg)
    eng = _engine(params, layout, prod, cfg=cfg, obs=InMemoryRecorder())
    eng.data = data
    eng.run_round()
    rounds = [e for e in eng.obs.events if e["kind"] == "round"]
    assert rounds, eng.obs.events
    phase = rounds[-1]["phase_ms"]
    assert phase["backward"] > 0 and phase["encode_overlap"] > 0
    assert "client_pass" in phase
    # sub-phases nest inside client_pass: round_ms excludes them
    expect = sum(v for k, v in phase.items() if k not in SUB_PHASES)
    assert abs(rounds[-1]["round_ms"] - expect) < 1e-6
