"""Per-architecture smoke tests (reduced configs): one train fwd+bwd and one
decode step on CPU; asserts shapes + finiteness.  Also family-specific
correctness checks (SSD chunked-vs-sequential, MLA absorbed decode, MoE
conservation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.models import model as M

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    if cfg.family == "vlm":
        sv = 8
        return {
            "tokens": jnp.ones((b, s - sv), jnp.int32),
            "labels": jnp.ones((b, s - sv), jnp.int32),
            "patches": jnp.full((b, sv, cfg.d_model), 0.01, jnp.float32),
            "positions": jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s)),
        }
    if cfg.family == "audio":
        return {
            "frames": jnp.full((b, s, cfg.d_model), 0.01, jnp.float32),
            "tokens": jnp.ones((b, 16), jnp.int32),
            "labels": jnp.ones((b, 16), jnp.int32),
        }
    return {"tokens": jnp.ones((b, s), jnp.int32), "labels": jnp.ones((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_and_decode(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(lambda p: M.train_loss(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0
    cache = M.init_cache(cfg, 2, 16)
    logits, cache2 = M.decode_step(params, cache, jnp.ones((2, 1), jnp.int32), jnp.int32(0), cfg)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936),
        "deepseek-v3-671b": (61, 7168, 128, 128, 129280),
        "qwen3-0.6b": (28, 1024, 16, 8, 151936),
        "qwen2.5-32b": (64, 5120, 40, 8, 152064),
        "qwen2-7b": (28, 3584, 28, 4, 152064),
        "command-r-35b": (40, 8192, 64, 8, 256000),
        "zamba2-2.7b": (54, 2560, 32, 32, 32000),
        "qwen2-vl-7b": (28, 3584, 28, 4, 152064),
        "mamba2-1.3b": (48, 2048, 0, 0, 50280),
        "whisper-base": (6, 512, 8, 8, 51865),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.vocab_size) == expect


def test_deepseek_param_count_near_671b():
    cfg = get_config("deepseek-v3-671b")
    n = cfg.param_count()
    assert 6.0e11 < n < 7.5e11, n
    na = cfg.active_param_count()
    assert 2.5e10 < na < 4.5e10, na  # ~37B active


def test_qwen3_moe_param_count_near_235b():
    cfg = get_config("qwen3-moe-235b-a22b")
    n = cfg.param_count()
    assert 2.0e11 < n < 2.6e11, n


def test_ssd_chunked_equals_sequential_decode():
    """The chunked SSD training scan and the one-step decode recurrence are
    the same operator: prefill state == state after T sequential decodes."""
    cfg = smoke_config("mamba2-1.3b")
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab_size)
    _, pc = M.prefill(params, {"tokens": toks}, cfg)
    cache = M.init_cache(cfg, 1, 16)
    for t in range(16):
        _, cache = M.decode_step(params, cache, toks[:, t : t + 1], jnp.int32(t), cfg)
    np.testing.assert_allclose(
        np.asarray(pc["ssm"]), np.asarray(cache["ssm"]), rtol=2e-3, atol=1e-5
    )


def test_transformer_prefill_matches_decode():
    """Prefill logits at the last position == logits from sequential decode."""
    cfg = smoke_config("qwen3-0.6b")
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab_size)
    logits_p, _ = M.prefill(params, {"tokens": toks}, cfg)
    cache = M.init_cache(cfg, 2, 8)
    for t in range(8):
        logits_d, cache = M.decode_step(params, cache, toks[:, t : t + 1], jnp.int32(t), cfg)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(logits_d[:, 0], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_mla_absorbed_decode_matches_train_attention():
    """MLA: absorbed-matmul decode must equal the decompressed train path for
    the same (single-token) attention problem."""

    from repro.models import mla as mla_mod

    cfg = smoke_config("deepseek-v3-671b")
    lp = mla_mod.init_mla(jax.random.PRNGKey(3), cfg)
    x_hist = jax.random.normal(jax.random.PRNGKey(4), (1, 5, cfg.d_model), jnp.float32) * 0.1
    positions = jnp.arange(5)[None]
    out_train = mla_mod.apply_mla_train(lp, x_hist, positions, cfg)
    # decode position 4 with cache built from positions 0..4
    cache = {
        "ckv": jnp.zeros((1, 5, cfg.kv_lora_rank), jnp.float32),
        "kr": jnp.zeros((1, 5, cfg.qk_rope_head_dim), jnp.float32),
    }
    for t in range(5):
        out_dec, cache = mla_mod.apply_mla_decode(
            lp, x_hist[:, t : t + 1], positions[:, t : t + 1], cfg, cache, jnp.int32(t)
        )
    np.testing.assert_allclose(
        np.asarray(out_dec[:, 0]), np.asarray(out_train[:, -1]), rtol=2e-3, atol=2e-4
    )


def test_moe_combine_conserves_weighting():
    """Router weights are renormalized over top-k: output is a convex combo of
    expert outputs (checked by making all experts the identity-ish map)."""
    from repro.models import moe as moe_mod

    cfg = smoke_config("qwen3-moe-235b-a22b")
    p = moe_mod.init_moe(jax.random.PRNGKey(5), cfg)
    # capacity is generous at this size; every token must be routed
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, cfg.d_model), jnp.float32) * 0.1
    y = moe_mod.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    # with zero expert weights the output must be exactly zero (no leakage)
    p0 = jax.tree_util.tree_map(jnp.zeros_like, p)
    y0 = moe_mod.apply_moe({"router": p["router"], "experts": p0["experts"]}, x, cfg)
    np.testing.assert_array_equal(np.asarray(y0), 0.0)


# ---------------------------------------------------------------------------
# fed-cohort grad path (launch/train.py --fed-cohort feeds jax.grad of
# train_loss into the cohort engine's flatten_to_blocks wire)
# ---------------------------------------------------------------------------

FED_COHORT_ARCHS = ["qwen3-0.6b", "mamba2-1.3b", "qwen3-moe-235b-a22b"]


@pytest.mark.parametrize("arch", FED_COHORT_ARCHS)
def test_fed_cohort_grad_smoke(arch):
    """The exact composition --fed-cohort runs per client: grad of train_loss
    on a token batch must mirror the param tree (same structure, shapes,
    dtypes) with every leaf finite and at least one nonzero."""
    cfg = smoke_config(arch)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg, b=2, s=16)
    grads = jax.grad(lambda p, b: M.train_loss(p, b, cfg))(params, batch)
    assert (jax.tree_util.tree_structure(grads)
            == jax.tree_util.tree_structure(params))
    for g, p in zip(jax.tree.leaves(grads), jax.tree.leaves(params)):
        assert g.shape == p.shape and g.dtype == p.dtype
        assert np.isfinite(np.asarray(g)).all()
    assert any(float(jnp.abs(g).max()) > 0 for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", FED_COHORT_ARCHS)
def test_fed_cohort_grad_blocks_roundtrip(arch):
    """Grad trees survive the engine's wire layout: flatten_to_blocks at the
    fed-cohort block size then blocks_to_tree is the identity, and the same
    grad fn vmaps over a client batch axis (the engine's cohort axis)."""
    from repro.core.compression import blocks_to_tree, flatten_to_blocks

    cfg = smoke_config(arch)
    params = M.init_params(cfg, KEY)
    grad_fn = jax.grad(lambda p, b: M.train_loss(p, b, cfg))
    grads = grad_fn(params, _batch(cfg, b=1, s=16))
    blocks, spec, nbar = flatten_to_blocks(grads, 255)
    assert blocks.ndim == 2 and blocks.shape[1] == 255
    back = blocks_to_tree(blocks, spec, nbar)
    for g, r in zip(jax.tree.leaves(grads), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
    # cohort axis: 3 clients' token batches through one vmapped grad pass
    tokens = jnp.stack(
        [_batch(cfg, b=1, s=16)["tokens"] + k for k in range(3)]
    ) % cfg.vocab_size
    cohort = {"tokens": tokens, "labels": tokens}
    gb = jax.vmap(grad_fn, in_axes=(None, 0))(params, cohort)
    for g, leaf in zip(jax.tree.leaves(grads), jax.tree.leaves(gb)):
        assert leaf.shape == (3,) + g.shape
        assert np.isfinite(np.asarray(leaf)).all()
