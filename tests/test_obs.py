"""Observability-layer tests (DESIGN.md #Observability): the versioned
event schema and its JSONL roundtrip, sink equivalence, the jit-safe
decode-health counters (clip saturation, GAMP health, buffer accounting
under fault injection, post-combining aux), the recorded round events on
both the barrier and streaming engine paths, the ``ReconSpec.return_info``
API surface, and the run-log reader CLI."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, bussgang
from repro.core.compression import BQCSCodec, FedQCSConfig, packed_width
from repro.core.recon_engine import ReconSpec
from repro.fed.channel import (
    ChannelConfig,
    get_channel_family,
    mimo_tx_gain,
    realize_uplink,
)
from repro.fed.engine import ArrayClientData, CohortConfig, CohortEngine
from repro.fed.partition import PartitionConfig, partition_indices
from repro.fed.scheduler import SchedulerConfig
from repro.fed.server_opt import ServerOptConfig
from repro.fed.stream import StreamConfig, batch_arrivals, stream_decode
from repro.fed.toy import toy_classification, toy_loss, toy_params
from repro.obs import (
    NULL_RECORDER,
    InMemoryRecorder,
    JsonlRecorder,
    SCHEMA_VERSION,
    validate_event,
)
from repro.obs.reader import iter_events, load_meta, load_rounds, summarize, validate_dir
from repro.obs.schema import validate_run
from repro.obs.trace import SpanCollector, span

jax.config.update("jax_platform_name", "cpu")

FED = FedQCSConfig(block_size=64, reduction_ratio=2, bits=3, s_ratio=0.2,
                   gamp_iters=10, gamp_variance_mode="scalar")


def _engine(obs=None, stream=None, channel=None):
    xs, ys = toy_classification(n_samples=512)
    parts = partition_indices(
        ys, 8, PartitionConfig(kind="dirichlet", alpha=0.5, min_size=2))
    return CohortEngine(
        toy_params(), jax.grad(toy_loss),
        ArrayClientData(xs, ys, parts, batch_size=2),
        fed_cfg=FED,
        cohort=CohortConfig(method="fedqcs-ae"),
        sched=SchedulerConfig(),
        chan=channel or ChannelConfig(kind="awgn", snr_db=10.0),
        server=ServerOptConfig(lr=0.01),
        stream=stream,
        obs=obs,
    )


@pytest.fixture(scope="module")
def barrier_events():
    rec = InMemoryRecorder()
    _engine(obs=rec).run(2)
    return rec.events


@pytest.fixture(scope="module")
def stream_events():
    rec = InMemoryRecorder()
    _engine(obs=rec, stream=StreamConfig(batch_clients=3, deadline=1e9)).run(2)
    return rec.events


# ---------------------------------------------------------------------------
# schema + sinks
# ---------------------------------------------------------------------------


def test_jsonl_schema_roundtrip(tmp_path):
    """Events written by JsonlRecorder read back enveloped, schema-valid,
    in order, with numpy/jax payload values coerced to JSON natives."""
    run_dir = str(tmp_path / "run_a")
    with JsonlRecorder(run_dir, config={"method": "fedqcs-ae", "Q": 3}) as rec:
        rec.record("round", {"round": 0, "cohort": 8, "participating": 7.0,
                             "nmse": np.float32(0.25),
                             "gamp_iters_mean": jnp.asarray(12.5)})
        rec.record("eval", {"round": 0, "accuracy": 0.9, "loss": 0.3})
        rec.record("span", {"name": "decode", "ms": 1.5})
        rec.record("note", {"msg": "checkpointed"})
    meta = load_meta(run_dir)
    events = list(iter_events(run_dir))
    assert validate_run(meta, events) == []
    assert meta["schema_version"] == SCHEMA_VERSION
    assert meta["config"]["Q"] == 3
    assert [ev["kind"] for ev in events] == ["round", "eval", "span", "note"]
    assert [ev["seq"] for ev in events] == [0, 1, 2, 3]
    rnd = events[0]
    assert rnd["v"] == SCHEMA_VERSION
    assert rnd["nmse"] == pytest.approx(0.25)  # np scalar -> plain float
    assert isinstance(rnd["nmse"], float) and isinstance(rnd["gamp_iters_mean"], float)
    # the file really is one JSON object per line
    with open(tmp_path / "run_a" / "events.jsonl") as f:
        assert all(json.loads(line) for line in f)


def test_validate_catches_malformed_events():
    ok = {"v": SCHEMA_VERSION, "kind": "round", "seq": 0, "t": 0.1,
          "round": 0, "cohort": 4, "participating": 4.0, "mystery_field": 1}
    assert validate_event(ok) == []  # unknown payload fields are fine
    assert validate_event({**ok, "v": 99})  # wrong version
    assert validate_event({**ok, "kind": "nope"})  # unknown kind
    bad = dict(ok)
    del bad["cohort"]
    assert any("cohort" in p for p in validate_event(bad))
    meta = {"run_id": "x", "schema_version": SCHEMA_VERSION, "created_unix": 0.0}
    assert validate_run(meta, [ok, {**ok, "seq": 0}])  # seq not monotone


def test_sink_equivalence(tmp_path):
    """The in-memory and JSONL sinks produce identical enveloped events for
    the same record() sequence (timestamps aside)."""
    payloads = [("round", {"round": 0, "cohort": 2, "participating": 2.0}),
                ("eval", {"round": 0, "loss": 1.0}),
                ("note", {"msg": "hi"})]
    mem = InMemoryRecorder()
    jsl = JsonlRecorder(str(tmp_path / "run_b"))
    for kind, p in payloads:
        mem.record(kind, p)
        jsl.record(kind, p)
    jsl.close()
    disk = list(iter_events(str(tmp_path / "run_b")))
    assert len(mem.events) == len(disk) == len(payloads)
    for a, b in zip(mem.events, disk):
        a, b = dict(a), dict(b)
        a.pop("t"), b.pop("t")
        assert a == b


def test_null_recorder_is_inert_default():
    assert NULL_RECORDER.active is False
    NULL_RECORDER.record("round", {"anything": 1})  # no-op, no error
    NULL_RECORDER.close()
    eng = _engine()  # no obs -> the null singleton, no aux collection
    assert eng.obs is NULL_RECORDER
    stats = eng.run_round()
    assert "gamp_iters_mean" not in stats  # health aux only when collecting
    assert all(np.isfinite(float(v)) for v in stats.values())


def test_span_collector_accumulates_and_drains():
    col = SpanCollector()
    with span("decode", col):
        pass
    with span("decode", col):
        pass
    with span("apply", col):
        pass
    assert set(col.ms) == {"decode", "apply"}
    drained = col.drain()
    assert drained["decode"] >= 0.0 and col.ms == {}
    with span("free"):  # collector-less: pure no-op timing
        pass


# ---------------------------------------------------------------------------
# decode-health counters
# ---------------------------------------------------------------------------


def test_clip_saturation_counts_extreme_lanes():
    """The counter is exactly the fraction of code lanes at an extreme
    level, packed and unpacked views agree, and a vq codebook (no level
    order) reports a constant 0."""
    codec = BQCSCodec(FED)
    nlev = codec.codebook.n_levels
    # known input: half the lanes pinned at the extremes
    idx = jnp.asarray(
        np.tile([0, nlev - 1, 1, nlev - 2], codec.cfg.m // 4), jnp.uint8
    )[None, :]
    assert float(codec.clip_saturation(idx, packed=False)) == pytest.approx(0.5)
    # packed/unpacked parity on real payloads
    blocks = jax.random.normal(jax.random.PRNGKey(0), (3, FED.block_size))
    words, _, _ = codec.compress_blocks_packed(blocks, jnp.zeros_like(blocks))
    codes, _, _ = codec.compress_blocks(blocks, jnp.zeros_like(blocks))
    sat_w = float(codec.clip_saturation(words, packed=True))
    sat_c = float(codec.clip_saturation(codes, packed=False))
    assert sat_w == pytest.approx(sat_c)
    assert sat_w == pytest.approx(
        float(np.mean((np.asarray(codes) == 0) | (np.asarray(codes) == nlev - 1))))
    vq_codec = BQCSCodec(FedQCSConfig(
        block_size=64, reduction_ratio=2, bits=4, s_ratio=0.2,
        gamp_iters=5, codebook="vq", vq_dim=2))
    assert float(vq_codec.clip_saturation(jnp.zeros((1, 4), jnp.uint8),
                                          packed=False)) == 0.0


def test_buffer_accounting_under_faults():
    """One streamed round under combined faults: a dropped batch shrinks
    admissions, duplicates are counted but never admitted, reordering
    changes neither, and a 1-slot buffer counts every forced drain."""
    codec = BQCSCodec(FED)
    c, nb = 9, 2
    blocks = jax.random.normal(jax.random.PRNGKey(1), (c, nb, FED.block_size))
    words, alphas, _ = jax.vmap(codec.compress_blocks_packed)(
        blocks, jnp.zeros_like(blocks))
    w = np.ones(c, np.float32)
    scfg = StreamConfig(batch_clients=3, buffer_batches=4)
    batches = batch_arrivals(np.arange(c, dtype=float), 1e9, 3)  # 3 batches
    _, clean = stream_decode(codec, words, alphas, w, batches, stream=scfg)
    assert clean["batches_admitted"] == 3
    assert clean["batches_rejected_dup"] == 0
    assert clean["participating"] == float(c)

    # drop batch 1, deliver batch 2 twice, reversed order
    faulty = [batches[2], batches[0], batches[2]]
    _, info = stream_decode(codec, words, alphas, w, faulty, stream=scfg)
    assert info["batches_admitted"] == 2
    assert info["batches_rejected_dup"] == 1
    assert info["participating"] == float(c - 3)

    # 1-slot buffer: every push after the first forces a drain
    tight = StreamConfig(batch_clients=3, buffer_batches=1)
    _, info = stream_decode(codec, words, alphas, w, batches, stream=tight)
    assert info["batches_backpressure"] == len(batches) - 1
    assert info["buffer_peak_occupancy"] == 1
    assert info["batches_admitted"] == 3
    assert clean["batches_backpressure"] == 0  # roomy buffer: none


def test_stream_decode_health_counters():
    """collect_health=True streams GAMP health out of the folds (EA) or the
    finalize decode (AE) without changing the decoded aggregate."""
    codec = BQCSCodec(FED)
    c, nb = 8, 2
    blocks = jax.random.normal(jax.random.PRNGKey(2), (c, nb, FED.block_size))
    words, alphas, _ = jax.vmap(codec.compress_blocks_packed)(
        blocks, jnp.zeros_like(blocks))
    w = np.ones(c, np.float32)
    batches = batch_arrivals(np.arange(c, dtype=float), 1e9, 4)
    for mode in ("ae", "ea"):
        ref, _ = stream_decode(
            codec, words, alphas, w, batches, mode=mode,
            stream=StreamConfig(batch_clients=4))
        from repro.fed.stream import StreamingPS

        ps = StreamingPS(codec, mode=mode, stream=StreamConfig(batch_clients=4),
                         collect_health=True)
        got, info = stream_decode(
            codec, words, alphas, w, batches, mode=mode,
            stream=StreamConfig(batch_clients=4), ps=ps)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
        assert 0.0 < info["gamp_iters_mean"] <= FED.gamp_iters
        assert info["gamp_iters_max"] <= FED.gamp_iters
        assert 0.0 <= info["gamp_converged_frac"] <= 1.0


def test_mimo_combine_aux_counters():
    """with_aux=True surfaces the post-combining CSI health: near-zero
    target mismatch under perfect CSI, strictly worse under CSI error."""
    codec = BQCSCodec(FED)
    c, nb = 4, 2
    blocks = jax.random.normal(jax.random.PRNGKey(3), (c, nb, FED.block_size))
    words, alphas, _ = jax.vmap(codec.compress_blocks_packed)(
        blocks, jnp.zeros_like(blocks))
    w = jnp.ones((c,), jnp.float32)
    mism = {}
    for err in (0.0, 0.3):
        chan = ChannelConfig(kind="mimo_mac", snr_db=40.0, n_rx=16, csi_error=err)
        fam = get_channel_family("mimo_mac")
        real = realize_uplink(chan, jax.random.PRNGKey(4), c, nb)
        deq = codec.codebook.decode_packed(words, codec.cfg.m)
        wq = bussgang.bussgang_weight(w[:, None], alphas, codec.codebook)
        active = (w > 0).astype(jnp.float32)
        eta = mimo_tx_gain(wq, active)
        y_rx = fam.transmit(chan, real, (eta * wq)[..., None] * deq,
                            jax.random.PRNGKey(5))
        y_eff, nu, aux = fam.combine(chan, real, y_rx, wq, active,
                                     psi=codec.codebook.psi, tx_gain=eta,
                                     with_aux=True)
        assert set(aux) >= {"csi_target_mismatch", "combiner_norm2"}
        assert float(aux["combiner_norm2"]) > 0.0
        mism[err] = float(aux["csi_target_mismatch"])
    assert mism[0.0] == pytest.approx(0.0, abs=1e-6)  # perfect CSI
    assert mism[0.3] > mism[0.0]


# ---------------------------------------------------------------------------
# engine round events: barrier + streaming paths
# ---------------------------------------------------------------------------


def _round_events(events):
    return [ev for ev in events if ev["kind"] == "round"]


def test_barrier_round_events(barrier_events):
    rounds = _round_events(barrier_events)
    assert len(rounds) == 2
    for i, ev in enumerate(rounds):
        assert validate_event(ev) == []
        assert ev["round"] == i
        assert ev["cohort"] == 8
        # decode health rides every round
        assert 0.0 < ev["gamp_iters_mean"] <= FED.gamp_iters
        assert ev["gamp_iters_max"] <= FED.gamp_iters
        assert 0.0 <= ev["gamp_converged_frac"] <= 1.0
        assert 0.0 <= ev["clip_saturation"] <= 1.0
        assert np.isfinite(ev["nmse"])
        assert ev["update_norm"] > 0.0 and ev["param_norm"] > 0.0
        # the barrier phase vocabulary, and round_ms is their sum
        assert set(ev["phase_ms"]) == {"uplink", "client_pass", "decode", "apply"}
        assert ev["round_ms"] == pytest.approx(sum(ev["phase_ms"].values()))
        # wire accounting: packed words + one f32 alpha per block, up;
        # an nbar-f32 model broadcast per cohort member, down
        codec = BQCSCodec(FED)
        width = packed_width(codec.codebook.n_codes(FED.m), codec.codebook.bits)
        nb = -(-toy_params_size() // FED.block_size)
        assert ev["wire_up_bytes"] == pytest.approx(
            ev["participating"] * nb * (width * 32 + 32) / 8.0)
        assert ev["wire_down_bytes"] == pytest.approx(
            ev["cohort"] * toy_params_size() * 4.0)


def toy_params_size():
    return sum(x.size for x in jax.tree_util.tree_leaves(toy_params()))


def test_streaming_round_events(stream_events):
    rounds = _round_events(stream_events)
    assert len(rounds) == 2
    for ev in rounds:
        assert validate_event(ev) == []
        # buffer accounting rides the streaming round event
        assert ev["batches_admitted"] >= 1
        assert ev["buffer_peak_occupancy"] >= 1
        assert ev["batches_rejected_dup"] == 0
        assert ev["batches_backpressure"] >= 0
        assert ev["peak_live_stats_bytes"] > 0
        # health from the finalize decode + the saturation counter
        assert 0.0 < ev["gamp_iters_mean"] <= FED.gamp_iters
        assert 0.0 <= ev["clip_saturation"] <= 1.0
        # the streaming phase vocabulary: fold, not decode
        assert set(ev["phase_ms"]) == {"uplink", "client_pass", "fold", "apply"}


def test_round_stats_unchanged_by_recording(barrier_events):
    """The recorder must not perturb the round itself: the same seeded
    engine without a recorder walks the same parameter trajectory."""
    eng = _engine()
    stats = [eng.run_round() for _ in range(2)]
    rounds = _round_events(barrier_events)
    for s, ev in zip(stats, rounds):
        assert s["nmse"] == pytest.approx(ev["nmse"], rel=1e-5)


# ---------------------------------------------------------------------------
# ReconSpec.return_info
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["ae", "ea"])
def test_reconstruct_return_info(mode):
    codec = api.make_codec(FED)
    grads = {"w": jax.random.normal(jax.random.PRNGKey(6), (200,))}
    state = api.init_state(codec, grads)
    payloads = []
    for k in range(3):
        g = {"w": jax.random.normal(jax.random.PRNGKey(10 + k), (200,))}
        p, spec, _ = api.compress(codec, g, state)
        payloads.append(p)
    rhos = [1 / 3] * 3
    bare = api.reconstruct(codec, payloads, rhos, spec, recon=ReconSpec(mode=mode))
    tree, info = api.reconstruct(
        codec, payloads, rhos, spec,
        recon=ReconSpec(mode=mode, return_info=True))
    np.testing.assert_allclose(
        np.asarray(tree["w"]), np.asarray(bare["w"]), rtol=1e-6)
    assert set(info) >= {"converged", "iters", "gamp_iters_mean",
                         "gamp_iters_max", "gamp_converged_frac"}
    assert 0.0 < float(info["gamp_iters_mean"]) <= FED.gamp_iters
    assert 0.0 <= float(info["gamp_converged_frac"]) <= 1.0
    assert int(np.max(np.asarray(info["iters"]))) <= FED.gamp_iters


# ---------------------------------------------------------------------------
# reader CLI over a real engine run
# ---------------------------------------------------------------------------


def test_jsonl_run_summarize_and_validate(tmp_path):
    run_dir = str(tmp_path / "run_c")
    rec = JsonlRecorder(run_dir, config={"clients": 8})
    eng = _engine(obs=rec)
    eng.run_round()
    rec.record("eval", {"round": 0, "accuracy": 0.5})
    rec.close()
    assert validate_dir(run_dir) == []
    out = summarize(run_dir)
    assert "rnd" in out and "nmse" in out and "it_mean" in out
    assert "phase wall-clock" in out and "decode health" in out
    assert len(load_rounds(run_dir)) == 1
    # closed recorder refuses further events rather than corrupting the log
    with pytest.raises(ValueError, match="close"):
        rec.record("note", {})
