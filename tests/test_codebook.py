"""Codebook-layer tests (DESIGN.md #Codebooks): family invariants, the
lloyd_max bit-identity pin, wire accounting, kernel/XLA agreement, the
kernel-bypass warning, and the vq-vs-scalar acceptance comparison."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as compression_mod
from repro.core import sensing, sparsify
from repro.core.codebook import (
    as_codebook,
    design_dithered_uniform,
    design_vq,
    index_bits,
    make_codebook,
)
from repro.core.compression import (
    BQCSCodec,
    CompressedGradient,
    FedQCSConfig,
    pack_codes,
    packed_width,
    unpack_codes,
)
from repro.core.gamp import GampConfig, qem_gamp, qem_gamp_packed
from repro.core.quantizer import design_lloyd_max, encode as lm_encode

jax.config.update("jax_platform_name", "cpu")


def _bg_blocks(rng, nb, n, s, scale=0.1):
    g = np.zeros((nb, n), np.float32)
    for i in range(nb):
        idx = rng.choice(n, s, replace=False)
        g[i, idx] = rng.normal(0, scale, s)
    return jnp.asarray(g)


# ---------------------------------------------------------------------------
# family invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", list(range(1, 9)))
def test_lloyd_max_fixed_point_gamma_equals_psi(bits):
    """At the Lloyd-Max fixed point the centroid condition forces
    gamma == psi, for EVERY wire width Q in 1..8 (Q=7 included -- the level
    count need not divide the word)."""
    cb = make_codebook(FedQCSConfig(bits=bits))
    assert cb.family == "lloyd_max" and cb.dim == 1
    assert cb.n_levels == 1 << bits and cb.bits == bits
    assert abs(cb.gamma - cb.psi) < 1e-4
    assert cb.kappa >= 0


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_vq_mmse_moments(bits):
    """k-means satisfies the centroid condition, so gamma ~= psi (the MMSE
    identity E[<Q,x>] = E[||Q||^2]) holds on held-out data."""
    cb = design_vq(1 << bits, 2, seed=0)
    assert abs(cb.gamma - cb.psi) < 5e-3
    assert 0 < cb.gamma < 1.0


def test_vq_beats_product_quantizer_kappa():
    """2-dim 16-centroid VQ vs the product of two Lloyd-Max Q=2 scalars
    (identical 2 bits/measurement): the jointly-designed codebook has
    strictly lower normalized distortion kappa -- the space-filling/shape
    gain that motivates the whole codebook axis."""
    vq = design_vq(16, 2, seed=0)
    lm = as_codebook(design_lloyd_max(2))
    assert vq.bits_per_entry == lm.bits_per_entry == 2.0
    assert vq.kappa < lm.kappa, (vq.kappa, lm.kappa)


def test_dithered_uniform_moments_match_monte_carlo():
    cb = design_dithered_uniform(3, m=64, seed=0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (1000, 64)), jnp.float32)
    qx = np.asarray(cb.decode(cb.encode(x)))
    x = np.asarray(x)
    assert abs(float(np.mean(qx * x)) - cb.gamma) < 5e-3
    assert abs(float(np.mean(qx**2)) - cb.psi) < 5e-3


def test_dithered_uniform_bounded_error():
    """Subtractive dither: |Q(x) - x| <= delta/2 for in-range inputs."""
    cb = design_dithered_uniform(4, m=128, seed=3)
    delta = float(cb.levels[1] - cb.levels[0])
    rng = np.random.default_rng(1)
    x = jnp.asarray(np.clip(rng.normal(0, 1, (8, 128)), -3.0, 3.0), jnp.float32)
    err = np.abs(np.asarray(cb.quantize(x)) - np.asarray(x))
    assert err.max() <= 0.5 * delta + 1e-6


def test_unknown_family_raises():
    with pytest.raises(ValueError, match="unknown codebook"):
        make_codebook(FedQCSConfig(codebook="nope"))


def test_vq_dim_must_divide_m():
    with pytest.raises(ValueError, match="must divide"):
        make_codebook(FedQCSConfig(block_size=96, reduction_ratio=3, bits=4,
                                   codebook="vq", vq_dim=3))  # M = 32


# ---------------------------------------------------------------------------
# the lloyd_max bit-identity pin (acceptance: pre-refactor wire unchanged)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits,use_kernels", [(2, False), (3, False), (3, True)])
def test_lloyd_max_wire_bit_identical_to_pre_refactor(bits, use_kernels):
    """codebook='lloyd_max' must produce the EXACT packed words of the
    pre-codebook pipeline (golden: design_lloyd_max -> top-S -> project ->
    searchsorted encode -> pack_codes), on the XLA and kernel paths, and
    wire_bits() must be unchanged."""
    rng = np.random.default_rng(7)
    n, m_ratio = 256, 4
    cfg = FedQCSConfig(block_size=n, reduction_ratio=m_ratio, bits=bits,
                       s_ratio=0.1, use_kernels=use_kernels,
                       gamp_variance_mode="scalar")
    codec = BQCSCodec(cfg)
    g = jnp.asarray(rng.normal(0, 0.1, (12, n)), jnp.float32)
    r = jnp.asarray(rng.normal(0, 0.01, (12, n)), jnp.float32)
    words, alpha, _ = codec.compress_blocks_packed(g, r)

    quant = design_lloyd_max(bits)
    sparse, _ = sparsify.block_sparsify(g + r, cfg.s)
    x, alpha_g = sensing.project_blocks(sparse, codec.a.T)
    golden = pack_codes(lm_encode(x, quant), bits)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(golden))
    np.testing.assert_allclose(np.asarray(alpha), np.asarray(alpha_g), rtol=1e-6)

    payload = CompressedGradient(words, alpha, 12 * n, cfg.m, codec.codebook.bits)
    w = packed_width(cfg.m, bits)
    assert payload.wire_bits() == 12 * (w * 32 + 32)  # the pre-refactor formula


# ---------------------------------------------------------------------------
# pack/unpack at non-power-of-two level counts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("levels", [3, 5, 6, 10, 12, 100])
@pytest.mark.parametrize("lanes", [1, 31, 97])
def test_pack_roundtrip_non_power_of_two_levels(levels, lanes):
    """Index width is ceil(log2 L): codes in [0, L) for non-power-of-two L
    roundtrip through the wire at that width."""
    bits = index_bits(levels)
    assert (1 << (bits - 1)) < levels <= (1 << bits)
    rng = np.random.default_rng(levels * 100 + lanes)
    codes = jnp.asarray(rng.integers(0, levels, (5, lanes)), jnp.uint8)
    words = pack_codes(codes, bits)
    assert words.shape == (5, packed_width(lanes, bits))
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(words, bits, lanes)), np.asarray(codes)
    )


def test_vq_non_power_of_two_levels_end_to_end():
    """A 12-centroid vq codebook packs at 4-bit width and roundtrips through
    the real codec wire."""
    rng = np.random.default_rng(5)
    cfg = FedQCSConfig(block_size=256, reduction_ratio=4, bits=4, s_ratio=0.1,
                       codebook="vq", vq_dim=2, vq_levels=12)
    codec = BQCSCodec(cfg)
    assert codec.codebook.n_levels == 12 and codec.codebook.bits == 4
    g = jnp.asarray(rng.normal(0, 0.1, (6, 256)), jnp.float32)
    words, alpha, _ = codec.compress_blocks_packed(g, jnp.zeros_like(g))
    codes = codec.unpack(words)
    assert int(codes.max()) < 12
    np.testing.assert_array_equal(
        np.asarray(pack_codes(codes, 4)), np.asarray(words)
    )


# ---------------------------------------------------------------------------
# dithered-uniform shared-seed determinism
# ---------------------------------------------------------------------------


def test_dithered_shared_seed_determinism():
    """Two independently-constructed codecs (worker and PS on different
    devices) derive the IDENTICAL dither from the protocol seed -- the wire
    needs no side channel; a different seed yields a different dither."""
    cfg = FedQCSConfig(block_size=128, reduction_ratio=4, bits=3, s_ratio=0.1,
                       codebook="dithered_uniform")
    c1, c2 = BQCSCodec(cfg), BQCSCodec(cfg)
    np.testing.assert_array_equal(c1.codebook.dither, c2.codebook.dither)
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(0, 0.1, (8, 128)), jnp.float32)
    w1, a1, _ = c1.compress_blocks_packed(g, jnp.zeros_like(g))
    w2, a2, _ = c2.compress_blocks_packed(g, jnp.zeros_like(g))
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    # decode on the "other side" inverts the same dither exactly
    np.testing.assert_array_equal(
        np.asarray(c1.dequantize_packed(w1)), np.asarray(c2.dequantize_packed(w2))
    )
    c3 = BQCSCodec(dataclasses.replace(cfg, seed=99))
    assert not np.array_equal(c1.codebook.dither, c3.codebook.dither)


def test_dithered_kernel_matches_xla_wire():
    rng = np.random.default_rng(3)
    cfg = FedQCSConfig(block_size=256, reduction_ratio=4, bits=3, s_ratio=0.1,
                       codebook="dithered_uniform")
    codec = BQCSCodec(cfg)
    g = jnp.asarray(rng.normal(0, 0.1, (10, 256)), jnp.float32)
    r = jnp.asarray(rng.normal(0, 0.01, (10, 256)), jnp.float32)
    w_xla, a_xla, res_xla = codec.compress_blocks_packed(g, r)
    from repro.kernels import ops

    w_k, a_k, res_k = ops.bqcs_encode_fused(g, r, codec.a, codec.codebook, cfg.s)
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_xla))
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_xla))
    np.testing.assert_allclose(np.asarray(res_k), np.asarray(res_xla), atol=1e-6)


def test_dithered_ea_exact_channel_reconstructs():
    """The truncated-posterior EA channel applies to the dithered family via
    the per-lane edge shift: recovery quality tracks lloyd_max at the same
    Q on the same payload."""
    rng = np.random.default_rng(4)
    n, s, nb = 512, 40, 8
    g = _bg_blocks(rng, nb, n, s)
    out = {}
    for fam in ("lloyd_max", "dithered_uniform"):
        cfg = FedQCSConfig(block_size=n, reduction_ratio=3, bits=4,
                          s_ratio=s / n, codebook=fam)
        codec = BQCSCodec(cfg)
        codes, alpha, _ = codec.compress_blocks(g, jnp.zeros_like(g))
        ghat = qem_gamp(codes, alpha, codec.a, codec.codebook,
                        GampConfig(iters=50))
        out[fam] = np.median(np.asarray(
            jnp.sum((ghat - g) ** 2, 1) / jnp.sum(g**2, 1)))
    # Absolute quality: the shifted-cell channel recovers the blocks.  The
    # lloyd_max ratio is loose -- at Q=4 the MMSE codebook's kappa is ~2.2x
    # below the uniform one's and GAMP compounds it -- the bound only pins
    # "same order of magnitude, channel not broken".
    assert out["dithered_uniform"] < 0.05, out
    assert out["dithered_uniform"] < 8.0 * out["lloyd_max"], out


# ---------------------------------------------------------------------------
# vq: kernel/XLA agreement + packed-domain equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m,d,bits", [
    (256, 64, 2, 4),    # even everything (W = 4)
    (256, 100, 2, 4),   # n_codes = 50: pack padding (W = 7, 6 slack lanes)
    (128, 32, 4, 3),    # d = 4, Q = 3: 8 levels over 4 dims
    (256, 66, 2, 5),    # Q = 5: 6 codes/word, n_codes = 33 -> W = 6
])
def test_vq_fused_kernel_matches_oracle(n, m, d, bits):
    """Fused nearest-centroid encode == the jnp oracle (vq_nearest + pack),
    words and alpha bit-exact in interpret mode, incl. the all-zero row."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(n + m + d)
    cb = design_vq(1 << bits, d, seed=1)
    blocks = jnp.asarray(rng.normal(0, 0.1, (9, n)), jnp.float32)
    resid = jnp.asarray(rng.normal(0, 0.01, (9, n)), jnp.float32)
    blocks = blocks.at[0].set(0.0)
    resid = resid.at[0].set(0.0)
    a = sensing.sensing_matrix(jax.random.PRNGKey(1), m, n)
    s = max(1, n // 10)
    wk, ak, rk = ops.bqcs_encode_fused(blocks, resid, a, cb, s)
    wr, ar, rr = ref.bqcs_encode_fused_ref(
        blocks, resid, a.T, None, s, bits, centroids=cb.jnp_centroids()
    )
    assert wk.dtype == jnp.uint32
    assert wk.shape == (9, packed_width(m // d, bits))
    np.testing.assert_array_equal(np.asarray(wk), np.asarray(wr))
    np.testing.assert_array_equal(np.asarray(ak), np.asarray(ar))
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), atol=1e-6)
    assert float(ak[0]) == 0.0


def test_vq_decode_is_nearest_centroid():
    rng = np.random.default_rng(6)
    cb = design_vq(16, 2, seed=0)
    y = jnp.asarray(rng.normal(0, 1, (4, 32)), jnp.float32)
    deq = np.asarray(cb.quantize(y))
    # brute-force nearest centroid per (j-major) group
    yv = np.asarray(y).reshape(4, 2, 16)  # (nb, d, G)
    dv = deq.reshape(4, 2, 16)
    c = np.asarray(cb.centroids)
    for b in range(4):
        for g_idx in range(16):
            vec = yv[b, :, g_idx]
            best = c[np.argmin(((c - vec) ** 2).sum(1))]
            np.testing.assert_allclose(dv[b, :, g_idx], best, rtol=1e-5)


def test_vq_packed_ea_equals_unpacked():
    rng = np.random.default_rng(8)
    n, s, nb = 256, 24, 6
    g = _bg_blocks(rng, nb, n, s)
    cfg = FedQCSConfig(block_size=n, reduction_ratio=4, bits=4, s_ratio=s / n,
                       codebook="vq", vq_dim=2, gamp_iters=20)
    codec = BQCSCodec(cfg)
    codes, alpha, _ = codec.compress_blocks(g, jnp.zeros_like(g))
    words, alpha2, _ = codec.compress_blocks_packed(g, jnp.zeros_like(g))
    np.testing.assert_array_equal(np.asarray(alpha), np.asarray(alpha2))
    gcfg = GampConfig(iters=20)
    gh_u = qem_gamp(codes, alpha, codec.a, codec.codebook, gcfg)
    gh_p = qem_gamp_packed(words, alpha2, codec.a, codec.codebook, gcfg, cfg.m)
    np.testing.assert_array_equal(np.asarray(gh_u), np.asarray(gh_p))


# ---------------------------------------------------------------------------
# acceptance: vq (d=2, Q=4) vs scalar on the synthetic BG recovery test
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernels", [False, True])
def test_vq_wire_and_nmse_vs_scalar(use_kernels):
    """vq with d=2, Q=4 rides the wire at no more bits than scalar Q=2 (the
    4-bit code covers TWO measurements; by the ceil identity of
    DESIGN.md #Wire-format the word counts coincide, so <= is the attainable
    bound) and STRICTLY fewer than the same-resolution scalar Q=4 family
    member it replaces -- at equal or better NMSE than scalar Q=2 on the
    synthetic BG recovery test, on both the XLA and kernel (interpret-mode)
    paths.  NMSE compares on the production AE decode, where both families
    run the identical Bussgang-linearized channel and the comparison
    isolates the CODEBOOK's distortion (kappa_vq < kappa_q2); the scalar
    families' exact-channel EA decode is a decoder refinement orthogonal to
    the codebook axis."""
    from repro.core.reconstruction import aggregate_and_estimate

    rng = np.random.default_rng(10)
    n, s, nb = 512, 40, 16
    g = _bg_blocks(rng, nb, n, s)
    results = {}
    for tag, ckw in (
        ("scalar_q2", dict(codebook="lloyd_max", bits=2)),
        ("scalar_q4", dict(codebook="lloyd_max", bits=4)),
        ("vq_q4_d2", dict(codebook="vq", bits=4, vq_dim=2)),
    ):
        cfg = FedQCSConfig(block_size=n, reduction_ratio=4, s_ratio=s / n,
                           use_kernels=use_kernels,
                           gamp_variance_mode="scalar", **ckw)
        codec = BQCSCodec(cfg)
        words, alpha, _ = codec.compress_blocks_packed(g, jnp.zeros_like(g))
        payload = CompressedGradient(words, alpha, nb * n, cfg.m,
                                     codec.codebook.bits)
        codes = codec.unpack(words)
        ghat = aggregate_and_estimate(
            codec, codes[None], alpha[None], jnp.ones((1,)),
            gamp=GampConfig(iters=40, variance_mode="scalar"),
            use_pallas=use_kernels,
        )
        nmse = float(np.median(np.asarray(
            jnp.sum((ghat - g) ** 2, 1) / jnp.sum(g**2, 1))))
        results[tag] = (payload.wire_bits(), nmse)
    (w2, e2), (w4, e4), (wv, ev) = (
        results["scalar_q2"], results["scalar_q4"], results["vq_q4_d2"])
    assert wv <= w2, results  # equal wire to scalar Q=2 ...
    assert wv < w4, results  # ... strictly below scalar Q=4
    assert ev <= e2 * 1.02, results  # ... at equal-or-better NMSE


# ---------------------------------------------------------------------------
# the silent kernel-bypass warning (use_kernels + exact variance)
# ---------------------------------------------------------------------------


def test_kernel_bypass_warns_once(monkeypatch):
    monkeypatch.setattr(compression_mod, "_KERNEL_BYPASS_WARNED", False)
    cfg = FedQCSConfig(block_size=128, reduction_ratio=4, bits=2,
                       use_kernels=True)  # gamp_variance_mode="exact" default
    with pytest.warns(UserWarning, match="scalar-variance"):
        BQCSCodec(cfg)
    # one-time: a second codec does not warn again
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        BQCSCodec(cfg)


def test_no_bypass_warning_for_valid_configs(monkeypatch):
    monkeypatch.setattr(compression_mod, "_KERNEL_BYPASS_WARNED", False)
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        BQCSCodec(FedQCSConfig(use_kernels=True, gamp_variance_mode="scalar"))
        BQCSCodec(FedQCSConfig(use_kernels=False))  # exact + no kernels: fine


# ---------------------------------------------------------------------------
# fed engine: the codebook as a scenario axis
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method,fam", [
    ("fedqcs-ae", "vq"), ("fedqcs-ea", "vq"),
    ("fedqcs-ae", "dithered_uniform"), ("fedqcs-ea", "dithered_uniform"),
])
def test_engine_round_with_codebook_axis(method, fam):
    from repro.fed.engine import ArrayClientData, CohortConfig, CohortEngine
    from repro.fed.partition import PartitionConfig, partition_indices
    from repro.fed.toy import toy_classification, toy_loss, toy_params

    x, y = toy_classification()
    parts = partition_indices(y, 6, PartitionConfig(kind="iid", min_size=4))
    engine = CohortEngine(
        toy_params(), jax.grad(toy_loss), ArrayClientData(x, y, parts, batch_size=4),
        fed_cfg=FedQCSConfig(block_size=64, reduction_ratio=2, bits=4,
                             codebook=fam, vq_dim=2, gamp_iters=10),
        cohort=CohortConfig(method=method),
    )
    stats = engine.run_round()
    assert np.isfinite(stats["nmse"]), stats
    assert stats["nmse"] < 1.5, stats


# ---------------------------------------------------------------------------
# wire roundtrip property tests: every family x Q in 1..8 x lane counts that
# do NOT fill the last uint32 word (the word-slack paths)
# ---------------------------------------------------------------------------

try:  # optional dev dependency (pyproject [dev] extra)
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # property tests skip via importorskip
    from hypothesis_stub import hypothesis, st

import functools

from repro.core.codebook import VectorCodebook


@functools.lru_cache(maxsize=None)
def _lm_cb(bits):
    return as_codebook(design_lloyd_max(bits))


@functools.lru_cache(maxsize=None)
def _du_cb(bits, m, seed):
    return design_dithered_uniform(bits, m, seed)


@functools.lru_cache(maxsize=None)
def _vq_cb(bits, dim, seed):
    # The wire layer only reads (bits, dim, n_levels) + a centroid table, so
    # random centroids stand in for the (slow) k-means design here.
    rng = np.random.default_rng((seed, 0x70))
    n_lev = 1 << bits
    return VectorCodebook(
        family="vq", bits=bits, dim=dim, n_levels=n_lev, gamma=0.5, psi=0.5,
        centroids=rng.normal(size=(n_lev, dim)),
    )


@hypothesis.given(
    family=st.sampled_from(["lloyd_max", "dithered_uniform", "vq"]),
    bits=st.integers(1, 8),
    lanes=st.integers(1, 97),
    nb=st.integers(1, 3),
    dim=st.integers(2, 3),
    seed=st.integers(0, 99),
)
@hypothesis.settings(max_examples=40, deadline=None)
def test_wire_roundtrip_all_families(family, bits, lanes, nb, dim, seed):
    """pack -> unpack is the identity and packed-domain dequantization equals
    index-domain dequantization, across all three codebook families, every
    wire width Q in 1..8, and arbitrary (non-word-multiple) lane counts."""
    if family == "vq":
        m = lanes * dim  # one index covers `dim` measurements
        cb = _vq_cb(bits, dim, seed)
    else:
        m = lanes
        cb = _lm_cb(bits) if family == "lloyd_max" else _du_cb(bits, m, seed)
    assert cb.n_codes(m) == lanes
    rng = np.random.default_rng((seed, bits, lanes))
    codes = jnp.asarray(rng.integers(0, cb.n_levels, size=(nb, lanes)), jnp.uint8)
    words = pack_codes(codes, cb.bits)
    assert words.dtype == jnp.uint32
    assert words.shape == (nb, packed_width(lanes, cb.bits))
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(words, cb.bits, lanes)), np.asarray(codes)
    )
    np.testing.assert_array_equal(
        np.asarray(cb.decode_packed(words, m)), np.asarray(cb.decode(codes, m))
    )
