"""Channel-family registry tests (DESIGN.md #Channels): registry resolution,
bit-identical ports of the pre-registry per-client models, the MIMO-MAC
joint-estimation decode against the gather-decode oracle, imperfect-CSI
degradation, config validation, and the ReconSpec API surface."""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregator, api, bussgang
from repro.core.compression import BQCSCodec, FedQCSConfig
from repro.core.recon_engine import ReconSpec, decode_from_stats
from repro.fed.channel import (
    CHANNEL_FAMILIES,
    ChannelConfig,
    ChannelFamily,
    ChannelRealization,
    get_channel_family,
    mimo_tx_gain,
    realize_uplink,
    register_channel_family,
    snr_noise_var,
)
from repro.fed.engine import ArrayClientData, CohortConfig, CohortEngine
from repro.fed.partition import PartitionConfig, partition_indices
from repro.fed.scheduler import SchedulerConfig
from repro.fed.server_opt import ServerOptConfig
from repro.fed.stream import StreamConfig
from repro.fed.toy import toy_classification, toy_loss, toy_params

jax.config.update("jax_platform_name", "cpu")

FED = FedQCSConfig(block_size=64, reduction_ratio=2, bits=3, s_ratio=0.2,
                   gamp_iters=30, gamp_variance_mode="scalar")


def _cohort_payloads(codec, k, nb=3, seed=0):
    blocks = jax.random.normal(
        jax.random.PRNGKey(seed), (k, nb, codec.cfg.block_size), jnp.float32)
    words, alphas, _ = jax.vmap(codec.compress_blocks_packed)(
        blocks, jnp.zeros_like(blocks))
    return words, alphas


def _mimo_decode(codec, chan, real, words, alphas, w, key):
    """The barrier MAC round: power control, pre-scale, superimpose,
    combine, GAMP."""
    fam = get_channel_family(chan.kind)
    deq = codec.codebook.decode_packed(words, codec.cfg.m)
    wq = bussgang.bussgang_weight(w[:, None], alphas, codec.codebook)
    active = (w > 0).astype(jnp.float32)
    eta = mimo_tx_gain(wq, active)
    y_rx = fam.transmit(chan, real, (eta * wq)[..., None] * deq, key)
    y_eff, nu = fam.combine(chan, real, y_rx, wq, active,
                            psi=codec.codebook.psi, tx_gain=eta)
    ghat = decode_from_stats(
        codec, aggregator.mimo_batch_stats(codec, y_eff, nu, alphas, w))
    return ghat, y_eff, nu


def _nmse(a, b):
    return float(jnp.sum(jnp.square(a - b)) / (jnp.sum(jnp.square(b)) + 1e-30))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_resolves_all_builtin_families():
    for kind in ("ideal", "awgn", "rayleigh", "mimo_mac"):
        fam = get_channel_family(kind)
        assert fam.name == kind
    assert get_channel_family("ideal").exact_codes
    assert not get_channel_family("awgn").exact_codes
    assert get_channel_family("mimo_mac").multiple_access
    assert get_channel_family("mimo_mac").combine is not None
    assert not get_channel_family("rayleigh").multiple_access


def test_registry_unknown_kind_error_lists_families():
    with pytest.raises(ValueError, match="unknown channel kind"):
        get_channel_family("carrier_pigeon")
    with pytest.raises(ValueError, match="mimo_mac"):
        realize_uplink(ChannelConfig(kind="nope"), jax.random.PRNGKey(0), 4, 2)


def test_registry_is_the_plugin_point():
    # A third-party family lands as ONE registration: realize_uplink and the
    # engine's gating both route through the registry, no kind dispatch.
    def _realize(cfg, key, clients, nblocks):
        return ChannelRealization(
            jnp.full((clients, nblocks), 0.125, jnp.float32),
            jnp.ones((clients,), jnp.float32),
        )

    register_channel_family("test_custom", ChannelFamily(
        name="test_custom", exact_codes=False, multiple_access=False,
        realize=_realize,
        transmit=lambda cfg, real, x, key: x,
        effective_noise=lambda real: real.noise_var,
    ))
    try:
        real = realize_uplink(
            ChannelConfig(kind="test_custom"), jax.random.PRNGKey(0), 3, 2)
        assert float(real.noise_var[0, 0]) == 0.125
    finally:
        del CHANNEL_FAMILIES["test_custom"]


def test_no_channel_kind_dispatch_outside_registry():
    # The acceptance guard: the ONLY `kind ==` dispatch on channel families
    # lives in the registry lookup; engine/stream/drivers go through traits.
    import pathlib
    import re

    src = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    pat = re.compile(r"kind\s*==\s*[\"'](ideal|awgn|rayleigh|mimo_mac)[\"']")
    offenders = [
        str(p) for p in src.rglob("*.py")
        if p.name != "channel.py" and pat.search(p.read_text())
    ]
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# bit-identical ports of the pre-registry models
# ---------------------------------------------------------------------------


def test_ported_realizations_bit_identical():
    key = jax.random.PRNGKey(7)
    c, nb = 6, 4

    ideal = realize_uplink(ChannelConfig(), key, c, nb)
    assert np.array_equal(np.asarray(ideal.noise_var), np.zeros((c, nb)))
    assert np.array_equal(np.asarray(ideal.mask), np.ones(c))

    awgn = realize_uplink(ChannelConfig(kind="awgn", snr_db=13.0), key, c, nb)
    assert np.array_equal(
        np.asarray(awgn.noise_var),
        np.full((c, nb), snr_noise_var(13.0), np.float32))

    # the pre-registry rayleigh draw, inlined: exact op-for-op sequence
    cfg = ChannelConfig(kind="rayleigh", snr_db=9.0, outage_gain=0.3)
    gain = jax.random.exponential(key, (c,), jnp.float32)
    alive = gain >= cfg.outage_gain
    nu_ref = jnp.where(alive, snr_noise_var(9.0) / jnp.where(alive, gain, 1.0), 0.0)
    ray = realize_uplink(cfg, key, c, nb)
    assert np.array_equal(
        np.asarray(ray.noise_var),
        np.asarray(jnp.broadcast_to(nu_ref[:, None], (c, nb)).astype(jnp.float32)))
    assert np.array_equal(np.asarray(ray.mask), np.asarray(alive, np.float32))


def test_ported_transmit_bit_identical():
    # The per-client reception reproduces the pre-registry noise op sequence
    # exactly: x + normal(key, x.shape, x.dtype) * sqrt(noise_var)[..., None].
    key, k_noise = jax.random.split(jax.random.PRNGKey(3))
    c, nb, m = 5, 3, 8
    x = jax.random.normal(key, (c, nb, m), jnp.float32)
    cfg = ChannelConfig(kind="awgn", snr_db=6.0)
    real = realize_uplink(cfg, key, c, nb)
    fam = get_channel_family("awgn")
    got = fam.transmit(cfg, real, x, k_noise)
    ref = x + jax.random.normal(k_noise, x.shape, x.dtype) * jnp.sqrt(
        real.noise_var)[..., None]
    assert np.array_equal(np.asarray(got), np.asarray(ref))
    # ideal is the identity
    icfg = ChannelConfig()
    ireal = realize_uplink(icfg, key, c, nb)
    assert get_channel_family("ideal").transmit(icfg, ireal, x, k_noise) is x


# ---------------------------------------------------------------------------
# mimo_mac: joint estimation vs the gather-decode oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("combiner", ["lmmse", "zf"])
def test_mimo_joint_estimation_matches_gather_oracle(combiner):
    """With n_rx >> K at high SNR and perfect CSI the spatially-combined
    observation is the Bussgang aggregate, so the joint-estimation decode
    must land on the gather-decode oracle (calibrated: cross-NMSE ~3e-5)."""
    codec = BQCSCodec(FED)
    k, nb = 8, 3
    words, alphas = _cohort_payloads(codec, k)
    w = jnp.full((k,), 1.0 / k, jnp.float32)
    chan = ChannelConfig(kind="mimo_mac", snr_db=60.0, n_rx=64,
                         combiner=combiner)
    real = realize_uplink(chan, jax.random.PRNGKey(11), k, nb)

    oracle = decode_from_stats(
        codec, aggregator.ae_batch_stats(codec, words, alphas, w))
    ghat, y_eff, nu = _mimo_decode(
        codec, chan, real, words, alphas, w, jax.random.PRNGKey(12))

    assert bool(jnp.all(jnp.isfinite(ghat))) and bool(jnp.all(nu > 0))
    # measurement domain: y_eff is the Bussgang aggregate
    deq = codec.codebook.decode_packed(words, codec.cfg.m)
    wq = bussgang.bussgang_weight(w[:, None], alphas, codec.codebook)
    y_ref = jnp.sum(wq[..., None] * deq, axis=0)
    assert _nmse(y_eff, y_ref) <= 1e-4
    # gradient domain: pinned against the calibrated ~3e-5 cross-NMSE
    assert _nmse(ghat, oracle) <= 1e-3


def test_mimo_imperfect_csi_degrades_monotonically():
    """Fixed key => the true H is IDENTICAL across csi_error values (the
    realize hook splits the CSI-perturbation key off the H key), so the
    measurement-domain combining error is strictly monotone in csi_error."""
    codec = BQCSCodec(FED)
    k, nb = 8, 3
    words, alphas = _cohort_payloads(codec, k)
    w = jnp.full((k,), 1.0 / k, jnp.float32)
    deq = codec.codebook.decode_packed(words, codec.cfg.m)
    wq = bussgang.bussgang_weight(w[:, None], alphas, codec.codebook)
    y_ref = jnp.sum(wq[..., None] * deq, axis=0)

    key = jax.random.PRNGKey(21)
    errs, h_seen = [], []
    for csi in (0.0, 0.05, 0.5):
        chan = ChannelConfig(kind="mimo_mac", snr_db=60.0, n_rx=64,
                             csi_error=csi)
        real = realize_uplink(chan, key, k, nb)
        h_seen.append(np.asarray(real.h))
        _, y_eff, nu = _mimo_decode(
            codec, chan, real, words, alphas, w, jax.random.PRNGKey(22))
        errs.append(float(jnp.sum(jnp.square(y_eff - y_ref))))
        assert bool(jnp.all(nu > 0))
    assert np.array_equal(h_seen[0], h_seen[1])
    assert np.array_equal(h_seen[0], h_seen[2])
    assert errs[0] < errs[1] < errs[2], errs


def test_mimo_all_silent_cohort_is_safe():
    # Every client in outage/silent: the combiner must not blow up (f -> 0,
    # y_eff -> 0, nu -> receiver noise only).
    codec = BQCSCodec(FED)
    k, nb = 4, 2
    words, alphas = _cohort_payloads(codec, k)
    w = jnp.zeros((k,), jnp.float32)
    chan = ChannelConfig(kind="mimo_mac", snr_db=20.0, n_rx=8)
    real = realize_uplink(chan, jax.random.PRNGKey(5), k, nb)
    ghat, y_eff, nu = _mimo_decode(
        codec, chan, real, words, alphas, w, jax.random.PRNGKey(6))
    assert bool(jnp.all(jnp.isfinite(y_eff)))
    assert bool(jnp.all(jnp.isfinite(nu)))
    assert bool(jnp.all(jnp.isfinite(ghat)))


def test_mimo_tx_gain_normalizes_air_power():
    # eta^2 * mean(active w^2) == 1: unit average transmit power on the air
    # (the per-client families' SNR reference), regardless of rho scale --
    # WITHOUT it the rho pre-scaling pays a 1/K^2 SNR penalty and the
    # engine's MAC rounds decode to ~zero (the regression this pins).
    w = jnp.asarray([[0.1, 0.2], [0.05, 0.4], [0.3, 0.3], [9.0, 9.0]])
    active = jnp.asarray([1.0, 1.0, 1.0, 0.0])  # silent client excluded
    eta = mimo_tx_gain(w, active)
    mean_w2 = float(jnp.sum(jnp.square(w) * active[:, None]) / 6.0)
    assert float(eta) == pytest.approx(1.0 / np.sqrt(mean_w2), rel=1e-6)
    # uniform rho = 1/K: the gain exactly cancels the 1/K^2 power penalty
    k = 16
    wu = jnp.full((k, 3), 1.0 / k, jnp.float32)
    assert float(mimo_tx_gain(wu, jnp.ones((k,)))) == pytest.approx(k, rel=1e-6)
    assert float(mimo_tx_gain(wu, jnp.zeros((k,)))) == 0.0


def test_mimo_realize_validates_config():
    with pytest.raises(ValueError, match="n_rx"):
        realize_uplink(ChannelConfig(kind="mimo_mac", n_rx=0),
                       jax.random.PRNGKey(0), 4, 2)
    with pytest.raises(ValueError, match="combiner"):
        realize_uplink(ChannelConfig(kind="mimo_mac", combiner="mrc"),
                       jax.random.PRNGKey(0), 4, 2)


# ---------------------------------------------------------------------------
# engine + streaming rounds over the air
# ---------------------------------------------------------------------------

DIM, CLASSES = 24, 4


def _engine(clients=8, **kw):
    x, y = toy_classification(n_samples=600, dim=DIM, classes=CLASSES, seed=0)
    parts = partition_indices(
        y, clients, PartitionConfig(kind="dirichlet", alpha=0.2, min_size=4))
    defaults = dict(
        fed_cfg=FED,
        cohort=CohortConfig(method="fedqcs-ae"),
        sched=SchedulerConfig(),
        chan=ChannelConfig(kind="mimo_mac", snr_db=30.0, n_rx=32),
        server=ServerOptConfig(lr=0.01),
    )
    defaults.update(kw)
    return CohortEngine(
        toy_params(dim=DIM, classes=CLASSES, seed=0), jax.grad(toy_loss),
        ArrayClientData(x, y, parts, batch_size=4), **defaults,
    )


def test_engine_mimo_round_runs_and_updates():
    eng = _engine()
    p0 = jax.tree.map(jnp.copy, eng.params)
    for _ in range(2):
        stats = eng.run_round()
        assert all(np.isfinite(v) for v in stats.values()), stats
        assert stats["nu_quant"] > 0 and stats["nu_channel"] > 0
        # the power-control regression pin: without mimo_tx_gain the rho
        # pre-scaling sinks the receive SNR and the decode collapses to ~0
        # (nmse ~= 1.0); with it the MAC round reconstructs
        assert stats["nmse"] < 0.9, stats
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p0, eng.params)
    assert max(jax.tree.leaves(moved)) > 0


def test_engine_mimo_rejects_code_domain_methods():
    # the multiple-access wire never carries exact codes: trait-gated
    with pytest.raises(ValueError, match="ideal"):
        _engine(cohort=CohortConfig(method="fedqcs-ea"))


def test_engine_streaming_mimo_round_matches_barrier_closely():
    """The streamed MAC round superimposes each arrival batch over the SAME
    round realization H (columns restricted to the batch); at high SNR the
    only difference from the barrier round is the per-batch receiver-noise
    draw, so the two stay close and both track the model update."""
    kw = dict(chan=ChannelConfig(kind="mimo_mac", snr_db=50.0, n_rx=32),
              sched=SchedulerConfig(seed=3))
    barrier = _engine(**kw)
    streamed = _engine(
        stream=StreamConfig(batch_clients=3, buffer_batches=4, fanout=4,
                            deadline=1e9, seed=0),
        **kw)
    sb = barrier.run_round()
    ss = streamed.run_round()
    assert all(np.isfinite(v) for v in sb.values()), sb
    assert all(np.isfinite(v) for v in ss.values()), ss
    gb = jnp.concatenate([x.ravel() for x in jax.tree.leaves(barrier.params)])
    gs = jnp.concatenate([x.ravel() for x in jax.tree.leaves(streamed.params)])
    assert _nmse(gs, gb) <= 5e-2


# ---------------------------------------------------------------------------
# FedQCSConfig.validate()
# ---------------------------------------------------------------------------


def test_validate_rejects_ea_over_psum_dequant():
    cfg = FedQCSConfig(recon_mode="ea", wire_mode="psum_dequant")
    with pytest.raises(ValueError, match="gather_codes"):
        api.make_codec(cfg)


def test_validate_rejects_vq_dim_not_dividing_m():
    cfg = FedQCSConfig(block_size=64, reduction_ratio=2, bits=6,
                       codebook="vq", vq_dim=3)  # M = 32, 3 does not divide
    with pytest.raises(ValueError, match="vq_dim"):
        api.make_codec(cfg)


@pytest.mark.parametrize("bad", [
    dict(bits=0),
    dict(bits=9),
    dict(s_ratio=0.0),
    dict(s_ratio=1.5),
    dict(wire_mode="carrier_pigeon"),
    dict(recon_mode="magic"),
    dict(reduction_ratio=0),
    dict(recon_chunk=-1),
    dict(gamp_variance_mode="vector"),
])
def test_validate_rejects_bad_knobs(bad):
    with pytest.raises(ValueError):
        api.make_codec(FedQCSConfig(**bad))


def test_validate_accepts_paper_blocking():
    # N=1591, R=3: M = 1591 // 3 = 530 -- R does NOT have to divide N (the
    # paper's own Sec. VI blocking), validate() must not over-constrain.
    cfg = FedQCSConfig(block_size=1591, reduction_ratio=3, bits=3, s_ratio=0.1)
    codec = api.make_codec(cfg)
    assert codec.cfg.m == 530


# ---------------------------------------------------------------------------
# ReconSpec API surface
# ---------------------------------------------------------------------------


def _one_payload_setup():
    codec = api.make_codec(dataclasses.replace(FED, gamp_iters=15))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (96,), jnp.float32)}
    state = api.init_state(codec, grads)
    payload, spec, _ = api.compress(codec, grads, state)
    return codec, payload, spec


@pytest.mark.parametrize("mode", ["ea", "ae"])
def test_reconstruct_recon_spec_equals_deprecated_kwargs(mode):
    codec, payload, spec = _one_payload_setup()
    new = api.reconstruct(codec, [payload], [1.0], spec,
                          recon=ReconSpec(mode=mode))
    with pytest.warns(DeprecationWarning, match="ReconSpec"):
        old = api.reconstruct(codec, [payload], [1.0], spec, mode=mode)
    assert np.array_equal(np.asarray(new["w"]), np.asarray(old["w"]))


def test_reconstruct_rejects_mixing_spec_and_kwargs():
    codec, payload, spec = _one_payload_setup()
    with pytest.raises(TypeError, match="recon"):
        api.reconstruct(codec, [payload], [1.0], spec,
                        recon=ReconSpec(mode="ae"), mode="ae")


def test_reconstruct_emits_no_warning_on_new_surface():
    codec, payload, spec = _one_payload_setup()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        api.reconstruct(codec, [payload], [1.0], spec,
                        recon=ReconSpec(mode="ae"))


def test_recon_spec_validation():
    with pytest.raises(ValueError, match="mode"):
        ReconSpec(mode="magic")
    with pytest.raises(ValueError, match="groups"):
        ReconSpec(groups=0)
    with pytest.raises(ValueError, match="ea"):
        ReconSpec(mode="ea", channel=(jnp.zeros((1, 2)), jnp.zeros((1,))))
    with pytest.raises(ValueError, match="groups"):
        ReconSpec(groups=2, channel=(jnp.zeros((1, 2)), jnp.zeros((1,))))


def test_recon_spec_resolve_fills_config_defaults():
    cfg = FedQCSConfig(recon_chunk=7, use_kernels=False)
    spec = ReconSpec(mode="ae").resolve(cfg)
    assert spec.chunk == 7 and spec.use_pallas is False
    explicit = ReconSpec(mode="ae", chunk=3, use_pallas=True).resolve(cfg)
    assert explicit.chunk == 3 and explicit.use_pallas is True
