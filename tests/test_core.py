"""Unit + property tests for the FedQCS core library."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dependency (pyproject [dev] extra)
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # property tests skip via importorskip
    from hypothesis_stub import hypothesis, st

from repro.core import api, sparsify
from repro.core.compression import (
    BQCSCodec,
    FedQCSConfig,
    blocks_to_tree,
    flatten_to_blocks,
    flatten_to_blocks_batched,
    pack_codes,
    unpack_codes,
)
from repro.core.gamp import GampConfig, qem_gamp
from repro.core.quantizer import decode, design_lloyd_max, encode, quantize

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# quantizer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 5, 6, 8])
def test_lloyd_max_fixed_point(bits):
    q = design_lloyd_max(bits)
    assert np.all(np.diff(q.levels) > 0)
    assert np.all(np.diff(q.thresholds) > 0)
    # At the Lloyd-Max fixed point gamma == psi (centroid condition); the
    # fixed-point iteration converges geometrically, slower at higher bits.
    assert abs(q.gamma - q.psi) < 1e-4
    # Distortion decreases with bits, kappa -> 0.
    assert q.kappa >= 0


def test_lloyd_max_known_values():
    q1 = design_lloyd_max(1)
    np.testing.assert_allclose(q1.levels, [-0.7979, 0.7979], atol=1e-3)
    q2 = design_lloyd_max(2)
    np.testing.assert_allclose(q2.levels, [-1.510, -0.4528, 0.4528, 1.510], atol=1e-3)
    assert abs(q2.distortion - 0.1175) < 1e-3


def test_bussgang_constants_match_monte_carlo():
    """Prop. 1: gamma = E[Q(x)x], psi = E[Q(x)^2], distortion uncorrelated."""
    q = design_lloyd_max(3)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, 200_000), jnp.float32)
    qx = quantize(x, q)
    gamma_mc = float(jnp.mean(qx * x))
    psi_mc = float(jnp.mean(qx**2))
    assert abs(gamma_mc - q.gamma) < 5e-3
    assert abs(psi_mc - q.psi) < 5e-3
    d = qx - q.gamma * x
    assert abs(float(jnp.mean(d * x))) < 5e-3  # uncorrelated
    assert abs(float(jnp.var(d)) - (q.psi - q.gamma**2)) < 5e-3


@hypothesis.given(bits=st.integers(1, 8), seed=st.integers(0, 999))
@hypothesis.settings(max_examples=20, deadline=None)
def test_encode_decode_consistency(bits, seed):
    q = design_lloyd_max(bits)
    x = jnp.asarray(np.random.default_rng(seed).normal(0, 1, 512), jnp.float32)
    codes = encode(x, q)
    assert int(codes.max()) < 2**bits
    deq = decode(codes, q)
    # decode is the nearest level: re-encoding a decoded value is idempotent
    assert (encode(deq, q) == codes).all()


# ---------------------------------------------------------------------------
# sparsify + error feedback
# ---------------------------------------------------------------------------


@hypothesis.given(
    nb=st.integers(1, 6), n=st.sampled_from([32, 100, 256]),
    s_frac=st.floats(0.05, 0.9), seed=st.integers(0, 99),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_sparsify_identity_and_count(nb, n, s_frac, seed):
    s = max(1, int(n * s_frac))
    x = jnp.asarray(np.random.default_rng(seed).normal(0, 1, (nb, n)), jnp.float32)
    sparse, resid = sparsify.block_sparsify(x, s)
    np.testing.assert_array_equal(np.asarray(sparse + resid), np.asarray(x))
    assert (np.count_nonzero(np.asarray(sparse), axis=1) <= s).all()
    # kept entries dominate dropped
    sp, rs = np.asarray(sparse), np.asarray(resid)
    for i in range(nb):
        kept = np.abs(sp[i][sp[i] != 0])
        drop = np.abs(rs[i][rs[i] != 0])
        if kept.size and drop.size:
            assert kept.min() >= drop.max() - 1e-7


# ---------------------------------------------------------------------------
# packing / flatten plumbing
# ---------------------------------------------------------------------------


@hypothesis.given(bits=st.sampled_from([1, 2, 3, 4, 5, 6, 8]), m=st.integers(1, 97),
                  seed=st.integers(0, 99))
@hypothesis.settings(max_examples=30, deadline=None)
def test_pack_roundtrip(bits, m, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 2**bits, (4, m)), jnp.uint8)
    words = pack_codes(codes, bits)
    assert (unpack_codes(words, bits, m) == codes).all()
    # wire width: ceil(m / (32//bits)) words
    assert words.shape == (4, -(-m // (32 // bits)))


# Non-hypothesis twin of the property above (runs on the minimal-deps CI
# leg): every wire Q, with M deliberately NOT a multiple of 32 // Q so the
# word-padding lanes are exercised, plus the extremes.
@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("m", [1, 31, 97, 250])
def test_pack_roundtrip_parametrized(bits, m):
    per_word = 32 // bits
    rng = np.random.default_rng(bits * 1000 + m)
    codes = jnp.asarray(rng.integers(0, 2**bits, (6, m)), jnp.uint8)
    words = pack_codes(codes, bits)
    assert words.dtype == jnp.uint32
    assert words.shape == (6, -(-m // per_word))
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(words, bits, m)), np.asarray(codes)
    )
    # saturated codes must not bleed across bit-group boundaries
    full = jnp.full((2, m), 2**bits - 1, jnp.uint8)
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(pack_codes(full, bits), bits, m)), np.asarray(full)
    )


def test_wire_bits_from_packed_word_count():
    """wire_bits derives from the ACTUAL packed word count: for Q=3 a word
    carries 10 codes (2 slack bits), so the honest wire is ceil(M/10)*32
    bits per block -- more than the ideal M*Q, and far less than the int32
    codes the pre-packed path used to ship."""
    from repro.core.compression import CompressedGradient, packed_width

    rng = np.random.default_rng(0)
    for bits, m, nb in [(3, 256, 8), (2, 64, 4), (4, 97, 5), (8, 31, 3), (1, 128, 2)]:
        codes = jnp.asarray(rng.integers(0, 2**bits, (nb, m)), jnp.uint8)
        words = pack_codes(codes, bits)
        payload = CompressedGradient(words, jnp.ones((nb,)), nbar=nb * 100, m=m, bits=bits)
        w = packed_width(m, bits)
        assert words.shape[1] == w
        assert payload.wire_bits() == nb * (w * 32 + 32)
        # honest: covers every code bit, never narrower than the ideal M*Q
        assert payload.wire_bits() >= nb * (m * bits + 32)
        # and exactly the ideal when Q divides 32 and the words are full
        if 32 % bits == 0 and m % (32 // bits) == 0:
            assert payload.wire_bits() == nb * (m * bits + 32)


def test_compress_tree_payload_is_packed():
    """End-to-end worker payload: codes are uint32 words sized to wire_bits,
    and api.reconstruct unpacks them back to a working gradient tree."""
    rng = np.random.default_rng(8)
    cfg = FedQCSConfig(block_size=128, reduction_ratio=4, bits=3, s_ratio=0.1,
                       gamp_iters=10)
    codec = BQCSCodec(cfg)
    tree = {"w": jnp.asarray(rng.normal(0, 0.1, (40, 10)), jnp.float32)}
    state = api.init_state(codec, tree)
    payload, spec, state = api.compress(codec, tree, state)
    assert payload.codes.dtype == jnp.uint32
    nb, w = payload.codes.shape
    assert w == -(-cfg.m // (32 // cfg.bits))
    assert payload.wire_bits() == payload.codes.size * 32 + payload.alpha.size * 32
    out = api.reconstruct(codec, [payload], [1.0], spec, recon=api.ReconSpec(mode="ae"))
    assert out["w"].shape == tree["w"].shape
    assert np.isfinite(np.asarray(out["w"])).all()


def test_flatten_roundtrip_pytree():
    rng = np.random.default_rng(0)
    tree = {
        "a": jnp.asarray(rng.normal(0, 1, (13, 7)), jnp.float32),
        "b": [jnp.asarray(rng.normal(0, 1, (5,)), jnp.bfloat16),
              jnp.asarray(rng.normal(0, 1, (2, 3, 4)), jnp.float32)],
    }
    blocks, spec, nbar = flatten_to_blocks(tree, 32, row_multiple=4)
    assert blocks.shape[0] % 4 == 0
    out = blocks_to_tree(blocks, spec, nbar)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-2
        )


def test_flatten_batched_matches_unbatched():
    rng = np.random.default_rng(1)
    tree1 = {"w": jnp.asarray(rng.normal(0, 1, (9, 11)), jnp.float32)}
    tree2 = {"w": jnp.asarray(rng.normal(0, 1, (9, 11)), jnp.float32)}
    stacked = {"w": jnp.stack([tree1["w"], tree2["w"]])}
    bb, spec_b, nbar_b = flatten_to_blocks_batched(stacked, 16, row_multiple=2)
    b1, spec1, nbar1 = flatten_to_blocks(tree1, 16, row_multiple=2)
    np.testing.assert_array_equal(np.asarray(bb[0]), np.asarray(b1))
    assert nbar_b == nbar1


# ---------------------------------------------------------------------------
# end-to-end codec + reconstruction
# ---------------------------------------------------------------------------


def test_ea_reconstruction_quality_sparse_signal():
    """Exactly-sparse Gaussian blocks at paper settings -> low NMSE."""
    rng = np.random.default_rng(0)
    n, s, nb = 510, 40, 8
    g = np.zeros((nb, n), np.float32)
    for i in range(nb):
        idx = rng.choice(n, s, replace=False)
        g[i, idx] = rng.normal(0, 0.1, s)
    g = jnp.asarray(g)
    cfg = FedQCSConfig(block_size=n, reduction_ratio=3, bits=3, s_ratio=s / n, gamp_iters=50)
    codec = BQCSCodec(cfg)
    codes, alpha, _ = codec.compress_blocks(g, jnp.zeros_like(g))
    ghat = qem_gamp(codes, alpha, codec.a, codec.quantizer,
                    GampConfig(iters=50))
    per_block = np.asarray(
        jnp.sum((ghat - g) ** 2, axis=1) / jnp.sum(g**2, axis=1)
    )
    # AMP has a small per-block failure probability near the phase boundary;
    # the pipeline's error feedback absorbs stragglers across steps.  Require
    # typical-case quality + bounded failure count.
    assert np.median(per_block) < 0.06, per_block
    assert (per_block < 0.2).sum() >= nb - 1, per_block


def test_ae_matches_theorem1_bound():
    """AE reconstruction (G=1) should not exceed the Thm-1 LMMSE-style bound
    (evaluated with empirical block stats) by more than fp slack."""
    rng = np.random.default_rng(2)
    cfg = FedQCSConfig(block_size=256, reduction_ratio=3, bits=3, s_ratio=0.1, gamp_iters=50)
    codec = BQCSCodec(cfg)
    k, nb = 4, 8
    blocks, codes, alphas = [], [], []
    for _ in range(k):
        b = np.zeros((nb, 256), np.float32)
        for i in range(nb):
            idx = rng.choice(256, cfg.s, replace=False)
            b[i, idx] = rng.normal(0, 0.1, cfg.s)
        b = jnp.asarray(b)
        c, a, _ = codec.compress_blocks(b, jnp.zeros_like(b))
        blocks.append(b)
        codes.append(c)
        alphas.append(a)
    rhos = jnp.full((k,), 1.0 / k)
    from repro.core.reconstruction import aggregate_and_estimate

    gsum = sum(rhos[i] * blocks[i] for i in range(k))
    ghat = aggregate_and_estimate(codec, jnp.stack(codes), jnp.stack(alphas), rhos)
    mse = float(jnp.mean(jnp.sum((ghat - gsum) ** 2, axis=1)))
    # Thm 1 bound with empirical per-block moments
    q = codec.quantizer
    var = jnp.sum(jnp.stack([rhos[i] ** 2 * jnp.var(blocks[i], axis=1) for i in range(k)]), 0)
    musq = jnp.sum(jnp.stack([(rhos[i] * jnp.mean(blocks[i], axis=1)) ** 2 for i in range(k)]), 0)
    r = cfg.reduction_ratio
    bound = 256 * var * (1 - var / (r * var + q.kappa * (var + musq)))
    assert mse <= float(jnp.mean(bound)) * 1.15, (mse, float(jnp.mean(bound)))


def test_partial_participation_exactness():
    """A worker with rho=0 must be *exactly* ignored (failure semantics)."""
    rng = np.random.default_rng(3)
    cfg = FedQCSConfig(block_size=128, reduction_ratio=4, bits=3, s_ratio=0.1, gamp_iters=20)
    codec = BQCSCodec(cfg)
    b1 = jnp.asarray(rng.normal(0, 0.1, (4, 128)), jnp.float32)
    b2 = jnp.asarray(rng.normal(0, 0.1, (4, 128)), jnp.float32)
    garbage = jnp.asarray(rng.normal(0, 100.0, (4, 128)), jnp.float32)
    out = {}
    for tag, blocks, rhos in (
        ("with_dead", [b1, b2, garbage], [0.5, 0.5, 0.0]),
        ("without", [b1, b2], [0.5, 0.5]),
    ):
        cs, as_ = [], []
        for b in blocks:
            c, a, _ = codec.compress_blocks(b, jnp.zeros_like(b))
            cs.append(c)
            as_.append(a)
        from repro.core.reconstruction import aggregate_and_estimate

        out[tag] = aggregate_and_estimate(
            codec, jnp.stack(cs), jnp.stack(as_), jnp.asarray(rhos, jnp.float32)
        )
    np.testing.assert_allclose(
        np.asarray(out["with_dead"]), np.asarray(out["without"]), rtol=1e-4, atol=1e-6
    )


def test_error_feedback_accumulates_everything():
    """With EF, repeated compression of a CONSTANT gradient transmits the full
    vector over time: sum of reconstructions -> scaled truth (direction)."""
    rng = np.random.default_rng(4)
    cfg = FedQCSConfig(block_size=128, reduction_ratio=3, bits=4, s_ratio=0.05, gamp_iters=30)
    codec = BQCSCodec(cfg)
    g = jnp.asarray(rng.normal(0, 0.1, (2, 128)), jnp.float32)
    residual = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n_steps = 40  # residual plateaus after ~N/S steps (here 20), then cos climbs
    for _ in range(n_steps):
        codes, alpha, residual = codec.compress_blocks(g, residual)
        ghat = qem_gamp(codes, alpha, codec.a, codec.quantizer, GampConfig(iters=30))
        acc = acc + ghat
    acc = acc / n_steps
    cos = float(jnp.sum(acc * g) / (jnp.linalg.norm(acc) * jnp.linalg.norm(g)))
    assert cos > 0.9, cos
