"""Fallback shim for the optional ``hypothesis`` dev dependency.

The property tests are decorated with ``@hypothesis.given(...)`` at module
level, so a plain ``import hypothesis`` fails *collection* of the whole
module when the package is absent.  Test modules instead do

    try:
        import hypothesis
        import hypothesis.strategies as st
    except ModuleNotFoundError:
        from hypothesis_stub import hypothesis, st

With the shim in place each property test body is replaced by a
``pytest.importorskip("hypothesis")`` guard, so they skip cleanly (with the
standard "could not import" reason) while every non-property test still runs.
Install the real package via the ``dev`` extra in pyproject.toml.
"""

import pytest


class _Strategies:
    """st.integers(...), st.floats(...), ... -- arguments are never drawn."""

    def __getattr__(self, name):
        return lambda *args, **kwargs: None


class _Hypothesis:
    @staticmethod
    def given(*_args, **_kwargs):
        def decorate(fn):
            def property_test_skipped():
                pytest.importorskip("hypothesis")

            property_test_skipped.__name__ = fn.__name__
            property_test_skipped.__doc__ = fn.__doc__
            return property_test_skipped

        return decorate

    @staticmethod
    def settings(*_args, **_kwargs):
        return lambda fn: fn


hypothesis = _Hypothesis()
st = _Strategies()
