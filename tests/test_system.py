"""End-to-end system behaviour tests: distributed train step (both impls),
checkpoint/restart exactness, elastic resharding, partial participation,
int8 optimizer states, and learning progress with compression."""

import os

import pytest

# The debug mesh needs >= 8 host devices; set before first jax import.
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint.checkpointer import Checkpointer  # noqa: E402
from repro.configs.registry import smoke_config  # noqa: E402
from repro.core.compression import FedQCSConfig  # noqa: E402
from repro.data.synthetic import TokenDataset  # noqa: E402
from repro.launch.mesh import make_debug_mesh, make_single_device_mesh  # noqa: E402
from repro.optim.adam import OptConfig, QLeaf  # noqa: E402
from repro.runtime import steps  # noqa: E402

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 simulated devices"
)

# jax 0.4.x: subset-manual shard_map (auto=...) CHECK-fails natively in XLA
# and CPU replay is only ulp-deterministic; both work on jax >= 0.5 (which is
# what jax.shard_map's existence detects).  CI runs current jax, so the gated
# paths stay covered there.
_MODERN_JAX = hasattr(jax, "shard_map")

CFG = smoke_config("qwen3-0.6b")
# R=2 keeps the 2-pod aggregated support well inside the AMP-easy phase so
# the 60-step learning check is fast (R=3/Q=3 is the paper's operating point
# and is exercised by the benchmarks at longer horizons).
FED = FedQCSConfig(
    block_size=256, reduction_ratio=2, bits=4, s_ratio=0.08,
    gamp_iters=15, gamp_variance_mode="scalar",
)
OPT = OptConfig(lr=3e-3, warmup_steps=2, decay_steps=100)
DS = TokenDataset(CFG.vocab_size, batch=16, seq=32, seed=7)


def _train(n, fed=FED, state=None, start=0, mesh=None, impl="auto", opt=OPT):
    mesh = mesh or make_debug_mesh(2, 2, 2)
    state = state if state is not None else steps.init_train_state(
        CFG, opt, fed, jax.random.PRNGKey(0), n_pods=2
    )
    fn = steps.make_train_step(CFG, opt, fed, mesh, donate=False, impl=impl)
    losses = []
    for i in range(start, start + n):
        state, m = fn(state, DS.get_batch(i))
        losses.append(float(m["loss"]))
    return state, losses


def test_fedqcs_training_learns():
    # 60 steps: the warmup-phase loss slope varies with jax version (RNG/init
    # numerics); at this horizon the drop is ~3x the margin on every version
    # tested, so the check is robust without weakening the property.
    _, losses = _train(60)
    assert losses[-1] < losses[0] - 0.05, losses[:: max(len(losses) // 4, 1)]


@pytest.mark.skipif(
    not _MODERN_JAX,
    reason="manual-subset shard_map aborts (native XLA CHECK) on jax<0.5",
)
def test_auto_and_shard_map_impls_agree():
    """Implementation equivalence, asserted where it is well-posed:
    * the compression pipeline (sparsify -> project -> quantize -> error
      feedback) must match to fp round-off -> residuals ~identical;
    * losses identical (same fwd path);
    * params within ~2*lr: GAMP is a contraction-mapped nonlinear solver, so
      last-ulp differences in the (mathematically identical) Bussgang
      aggregation order perturb its output, and one Adam step turns ANY
      gradient perturbation into an O(lr) parameter difference."""
    out = {}
    for impl in ("auto", "shard_map"):
        st = steps.init_train_state(CFG, OPT, FED, jax.random.PRNGKey(0), n_pods=2)
        fn = steps.make_train_step(CFG, OPT, FED, mesh_shared(), donate=False, impl=impl)
        st, m = fn(st, DS.get_batch(0))
        out[impl] = (float(m["loss"]), st)
    assert abs(out["auto"][0] - out["shard_map"][0]) < 1e-5
    for a, b in zip(
        jax.tree.leaves(out["auto"][1]["residual"]),
        jax.tree.leaves(out["shard_map"][1]["residual"]),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    lr = OPT.lr
    for a, b in zip(
        jax.tree.leaves(out["auto"][1]["params"]),
        jax.tree.leaves(out["shard_map"][1]["params"]),
    ):
        d = float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
        assert d <= 2.0 * lr, d


_MESH = None


def mesh_shared():
    global _MESH
    if _MESH is None:
        _MESH = make_debug_mesh(2, 2, 2)
    return _MESH


def test_checkpoint_restart_exact(tmp_path):
    """Save at step 3, continue to 6; restart from the checkpoint and replay
    4..6 -> identical parameters (deterministic data keyed by step)."""
    mesh = make_debug_mesh(2, 2, 2)
    state, _ = _train(3, mesh=mesh)
    ckpt = Checkpointer(str(tmp_path), async_save=False)
    ckpt.save(3, state)
    cont, _ = _train(3, state=state, start=3, mesh=mesh)
    template = steps.init_train_state(CFG, OPT, FED, jax.random.PRNGKey(0), n_pods=2)
    restored, step = ckpt.restore(template)
    assert step == 3
    replay, _ = _train(3, state=restored, start=3, mesh=mesh)
    for a, b in zip(jax.tree.leaves(cont["params"]), jax.tree.leaves(replay["params"])):
        if _MODERN_JAX:  # bitwise-deterministic replay
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:  # jax 0.4.x CPU recompiles with ulp-level nondeterminism,
            # amplified ~lr-scale by the 3 replayed Adam steps
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=1e-5)


def test_checkpoint_elastic_resharding(tmp_path):
    """A checkpoint saved from the 2x2x2 run restores onto a DIFFERENT mesh
    (1x1x1) with explicit shardings -- the elastic scale-down path."""
    state, _ = _train(2)
    ckpt = Checkpointer(str(tmp_path), async_save=False)
    ckpt.save(2, state)
    small_mesh = make_single_device_mesh()
    template = steps.init_train_state(CFG, OPT, FED, jax.random.PRNGKey(0), n_pods=2)
    shardings = steps.train_state_shardings(template, small_mesh, fed=True)
    restored, _ = ckpt.restore(template, shardings=shardings)
    fn = steps.make_train_step(CFG, OPT, FED, small_mesh, donate=False)
    restored2, m = fn(restored, DS.get_batch(2))
    assert np.isfinite(float(m["loss"]))


def test_ea_recon_mode_step():
    """recon_mode='ea' (estimate-and-aggregate in the collective): the step
    runs, trains, and produces finite loss through the per-worker Q-EM-GAMP
    batch, with the fused-kernel dispatch engaged (use_kernels=True and FED
    is scalar-variance, so the collective routes through qgamp_ea_run)."""
    import dataclasses

    fed = dataclasses.replace(FED, recon_mode="ea", use_kernels=True)
    state, losses = _train(2, fed=fed)
    assert all(np.isfinite(l) for l in losses), losses


def test_ea_psum_dequant_rejected():
    """recon_mode='ea' needs per-worker codes: the shard_map collective must
    reject the psum_dequant wire at trace time with a clear error."""
    import dataclasses

    fed = dataclasses.replace(FED, recon_mode="ea", wire_mode="psum_dequant")
    with pytest.raises(ValueError, match="gather_codes"):
        _train(1, fed=fed, impl="shard_map")


@pytest.mark.skipif(
    not _MODERN_JAX,
    reason="manual-subset shard_map aborts (native XLA CHECK) on jax<0.5",
)
def test_ea_recon_mode_shard_map_step():
    """The manual-'pod' EA branch (packed-code all_gather -> per-worker
    Q-EM-GAMP inside the shard_map body, fused kernel engaged)."""
    import dataclasses

    fed = dataclasses.replace(FED, recon_mode="ea", use_kernels=True)
    _, losses = _train(1, fed=fed, impl="shard_map")
    assert np.isfinite(losses[0]), losses


def _collect_eqns(jaxpr, name, out):
    """Recursively collects eqns named ``name`` from a jaxpr and every
    sub-jaxpr in its params (duck-typed: works across jax core relocations)."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            out.append(eqn)
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", v)
            if hasattr(inner, "eqns"):
                _collect_eqns(inner, name, out)
    return out


def test_gather_codes_payload_is_packed_uint32():
    """wire_mode='gather_codes': the cross-pod all_gather operands are the
    packed uint32 words + the f32 alphas, and their combined size equals
    CompressedGradient.wire_bits -- the true Q/R-bit wire, not int32 codes."""
    import dataclasses

    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from repro import jax_compat
    from repro.core.compression import BQCSCodec, packed_width
    from repro.runtime.collectives import fedqcs_pod_allreduce

    fed = dataclasses.replace(FED, wire_mode="gather_codes")
    codec = BQCSCodec(fed)
    nb, n = 8, fed.block_size
    mesh = Mesh(np.array(jax.devices()[:2]), ("pod",))
    smap = jax_compat.shard_map(
        lambda b, r: fedqcs_pod_allreduce(b, r, codec),
        mesh=mesh,
        in_specs=(P("pod"), P("pod")),
        out_specs=(P("pod"), P("pod")),
        axis_names={"pod"},
        check_vma=False,
    )
    blocks = jnp.zeros((2 * nb, n), jnp.float32)
    resid = jnp.zeros_like(blocks)
    with jax_compat.set_mesh(mesh):
        jaxpr = jax.make_jaxpr(smap)(blocks, resid)
    gathers = _collect_eqns(jaxpr.jaxpr, "all_gather", [])
    assert gathers, "gather_codes step lowered without any all_gather"
    w = packed_width(fed.m, fed.bits)
    by_dtype = {}
    for eqn in gathers:
        aval = eqn.invars[0].aval
        by_dtype.setdefault(str(aval.dtype), []).append(tuple(aval.shape))
    # the code payload is uint32 words of the canonical width
    assert (nb, w) in by_dtype.get("uint32", []), by_dtype
    # and no unpacked (nb, M) code tensor crosses the pod axis
    for shapes in by_dtype.values():
        assert (nb, fed.m) not in shapes, by_dtype
    # gathered bits (words + alphas + the scalar participation flag's f32)
    words_bits = nb * w * 32
    alpha_bits = nb * 32
    payload_bits = words_bits + alpha_bits
    from repro.core.compression import CompressedGradient

    ref_payload = CompressedGradient(
        jnp.zeros((nb, w), jnp.uint32), jnp.zeros((nb,)), nbar=nb * n,
        m=fed.m, bits=fed.bits,
    )
    assert payload_bits == ref_payload.wire_bits()


def test_partial_participation_payload_ignored_and_residual_carry():
    """A pod with participating=0 contributes exactly zero (rho_k = 0): the
    reconstructed aggregate is bit-identical under arbitrary changes to the
    dead pod's gradient — and the dead pod's residual carries its FULL
    gradient forward (blocks + residual), not just the top-S remainder, so a
    straggler's work is re-transmitted on rejoin instead of lost."""
    from repro.core.compression import BQCSCodec
    from repro.runtime.collectives import fedqcs_vmapped_allreduce

    codec = BQCSCodec(FED)
    nb, n = 4, FED.block_size
    rng = np.random.default_rng(0)
    blocks0 = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    resid0 = jnp.asarray(rng.normal(0, 0.1, (nb, n)), jnp.float32)
    garbage = jnp.asarray(rng.normal(0, 100.0, (nb, n)), jnp.float32)
    dead_res = jnp.asarray(rng.normal(0, 0.1, (nb, n)), jnp.float32)
    part = jnp.asarray([1.0, 0.0])

    def run(dead_blocks):
        return fedqcs_vmapped_allreduce(
            jnp.stack([blocks0, dead_blocks]),
            jnp.stack([resid0, dead_res]),
            codec,
            part,
        )

    ghat_a, res_a = run(garbage)
    ghat_b, res_b = run(jnp.zeros((nb, n), jnp.float32))
    # dead payload exactly ignored: aggregate independent of its content
    np.testing.assert_array_equal(np.asarray(ghat_a), np.asarray(ghat_b))
    # alive pod's residual: the usual encoder remainder, same in both runs
    np.testing.assert_array_equal(np.asarray(res_a[0]), np.asarray(res_b[0]))
    # dead pod's residual: full carry, blocks + residual
    np.testing.assert_array_equal(
        np.asarray(res_a[1]), np.asarray(garbage + dead_res)
    )


def test_partial_participation_shard_map_residual_carry():
    """Same contract through the manual-'pod' collective (gather_codes wire):
    the dead pod's residual is the full carry and the alive pod's aggregate
    ignores the dead payload exactly."""
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from repro import jax_compat
    from repro.core.compression import BQCSCodec
    from repro.runtime.collectives import fedqcs_pod_allreduce

    codec = BQCSCodec(FED)
    nb, n = 4, FED.block_size
    rng = np.random.default_rng(1)
    blocks0 = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    resid = jnp.zeros((2 * nb, n), jnp.float32)
    part = jnp.asarray([1.0, 0.0])
    mesh = Mesh(np.array(jax.devices()[:2]), ("pod",))
    smap = jax_compat.shard_map(
        lambda b, r, p: fedqcs_pod_allreduce(b, r, codec, participating=p[0]),
        mesh=mesh,
        in_specs=(P("pod"), P("pod"), P("pod")),
        out_specs=(P("pod"), P("pod")),
        axis_names={"pod"},
        check_vma=False,
    )

    def run(dead_blocks):
        with jax_compat.set_mesh(mesh):
            return smap(jnp.concatenate([blocks0, dead_blocks]), resid, part)

    garbage = jnp.asarray(rng.normal(0, 50.0, (nb, n)), jnp.float32)
    ghat_a, res_a = run(garbage)
    ghat_b, res_b = run(jnp.zeros((nb, n), jnp.float32))
    np.testing.assert_array_equal(np.asarray(ghat_a), np.asarray(ghat_b))
    # every pod reconstructs the same aggregate redundantly
    np.testing.assert_array_equal(np.asarray(ghat_a[:nb]), np.asarray(ghat_a[nb:]))
    # dead pod residual = its full carry (zero prior residual -> its blocks)
    np.testing.assert_array_equal(np.asarray(res_a[nb:]), np.asarray(garbage))


def test_partial_participation_step():
    """Marking pod 1 dead must still step (rho renormalization) -- failure
    degrades gradient quality instead of failing the step."""
    mesh = make_debug_mesh(2, 2, 2)
    state = steps.init_train_state(CFG, OPT, FED, jax.random.PRNGKey(0), n_pods=2)
    state["participating"] = jnp.asarray([1.0, 0.0])
    fn = steps.make_train_step(CFG, OPT, FED, mesh, donate=False)
    state2, m = fn(state, DS.get_batch(0))
    assert np.isfinite(float(m["loss"]))
    moved = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(state2["params"]), jax.tree.leaves(state["params"]))
    )
    assert moved > 0


def test_int8_optimizer_states():
    opt = OptConfig(lr=3e-3, warmup_steps=2, decay_steps=100, state_dtype="int8")
    state, losses = _train(10, opt=opt)
    leaves = jax.tree_util.tree_leaves(
        state["opt"]["m"], is_leaf=lambda x: isinstance(x, QLeaf)
    )
    assert any(isinstance(l, QLeaf) for l in leaves)
    q = next(l for l in leaves if isinstance(l, QLeaf))
    assert q.q.dtype == jnp.int8
    assert losses[-1] < losses[0] + 0.05  # no divergence from quantized states


def test_baseline_and_fedqcs_share_data_path():
    """Baseline (no compression) trains faster or equal at equal steps."""
    _, fed_losses = _train(12)
    mesh = make_debug_mesh(2, 2, 2)
    state_b = steps.init_train_state(CFG, OPT, None, jax.random.PRNGKey(0))
    fn = steps.make_train_step(CFG, OPT, None, mesh, donate=False)
    base_losses = []
    for i in range(12):
        state_b, m = fn(state_b, DS.get_batch(i))
        base_losses.append(float(m["loss"]))
    assert base_losses[-1] <= fed_losses[-1] + 0.05
