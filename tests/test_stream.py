"""Streaming-PS tests (DESIGN.md #Streaming-PS): the partial-stat algebra,
the carry-save aggregator tree's memory bound, the pinned streamed-vs-barrier
tolerance contract, fault injection (drop / duplicate / reorder), deadline
degradation into the non-participation contract, and the bounded ingest
buffer's backpressure semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregator, bussgang
from repro.core.compression import BQCSCodec, FedQCSConfig
from repro.core.recon_engine import decode_from_stats
from repro.core.reconstruction import (
    aggregate_and_estimate,
    estimate_and_aggregate_packed,
    gamp_config_from,
)
from repro.fed.channel import ChannelConfig
from repro.fed.engine import ArrayClientData, CohortConfig, CohortEngine
from repro.fed.partition import PartitionConfig, partition_indices
from repro.fed.scheduler import SchedulerConfig
from repro.fed.server_opt import ServerOptConfig
from repro.fed.stream import (
    BoundedIngestBuffer,
    StreamConfig,
    batch_arrivals,
    late_discount,
    simulate_arrivals,
    stream_decode,
)
from repro.fed.toy import toy_classification, toy_loss, toy_params
from repro.runtime.collectives import fedqcs_partial_fold, fedqcs_partial_finalize

jax.config.update("jax_platform_name", "cpu")

FED = FedQCSConfig(block_size=64, reduction_ratio=2, bits=3, s_ratio=0.2,
                   gamp_iters=10, gamp_variance_mode="scalar")

# The PINNED streamed-vs-barrier tolerance contract: partial-aggregation
# order may change the decoded aggregate only through f32 reassociation of
# the client sums, so decoded aggregates agree to NMSE <= 1e-8 (observed
# ~1e-13 at these sizes) and entrywise to the usual reconstruction round-off.
NMSE_TOL = 1e-8
ATOL = 1e-5


def _nmse(a, b):
    return float(jnp.sum(jnp.square(a - b)) / (jnp.sum(jnp.square(b)) + 1e-30))


@pytest.fixture(scope="module")
def payload():
    """One 13-client cohort's wire payloads + raw weights (one weight zero:
    a dropped client riding in the cohort arrays)."""
    codec = BQCSCodec(FED)
    c, nb = 13, 3
    blocks = jax.random.normal(jax.random.PRNGKey(0), (c, nb, FED.block_size))
    res = jnp.zeros_like(blocks)
    words, alphas, _ = jax.vmap(codec.compress_blocks_packed)(blocks, res)
    codes = jax.vmap(codec.compress_blocks)(blocks, res)[0]
    w = np.abs(np.random.default_rng(0).normal(size=c)).astype(np.float32)
    w[3] = 0.0
    return codec, words, codes, alphas, w


def _scfg(**kw):
    defaults = dict(batch_clients=4, buffer_batches=2, fanout=2)
    defaults.update(kw)
    return StreamConfig(**defaults)


def _batches(c, size=4):
    times = np.arange(c, dtype=float) * 0.1
    return batch_arrivals(times, 1e9, size)


# ---------------------------------------------------------------------------
# partial-stat algebra
# ---------------------------------------------------------------------------


def test_ae_partial_fold_matches_oneshot_stats(payload):
    """Folding per-batch AE sufficient statistics equals the one-shot stats
    over the full cohort, and their normalization equals the barrier
    Bussgang aggregate built from the normalized rhos."""
    codec, words, _, alphas, w = payload
    jw = jnp.asarray(w)
    one = aggregator.ae_batch_stats(codec, words, alphas, jw)
    folded = None
    for sl in (slice(0, 5), slice(5, 9), slice(9, 13)):
        part = aggregator.ae_batch_stats(codec, words[sl], alphas[sl], jw[sl])
        folded = part if folded is None else aggregator.stats_add(folded, part)
    for a, b in zip(jax.tree_util.tree_leaves(folded), jax.tree_util.tree_leaves(one)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)

    rhos = jnp.asarray(w / w.sum())
    y_n, nu_n, en_n = aggregator.normalized_stats(folded)
    q = codec.codebook
    np.testing.assert_allclose(
        np.asarray(y_n),
        np.asarray(bussgang.aggregate_packed(words, alphas, rhos, q, FED.m)),
        rtol=1e-4, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(nu_n),
        np.asarray(bussgang.effective_noise_var(alphas, rhos, q)),
        rtol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(en_n),
        np.asarray(bussgang.signal_energy(alphas, rhos, FED.m, FED.block_size)),
        rtol=1e-4,
    )
    assert float(folded.count) == 12.0  # the w == 0 slot is not a participant


def test_zero_weight_slots_contribute_nothing(payload):
    """A zero-weight (padding / dropped) slot leaves every statistic
    unchanged -- the contract that makes fixed-shape batch padding sound."""
    codec, words, _, alphas, w = payload
    jw = jnp.asarray(w[:4])
    base = aggregator.ae_batch_stats(codec, words[:4], alphas[:4], jw)
    padded = aggregator.ae_batch_stats(
        codec,
        jnp.concatenate([words[:4], words[7:8]]),
        jnp.concatenate([alphas[:4], alphas[7:8]]),
        jnp.concatenate([jw, jnp.zeros((1,), jnp.float32)]),
    )
    for a, b in zip(jax.tree_util.tree_leaves(base), jax.tree_util.tree_leaves(padded)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_aggregator_tree_matches_linear_fold_and_bounds_memory():
    """The carry-save tree's root equals the plain left-to-right fold, and
    live stats stay O(log batches): 37 pushes at fanout 4 never hold more
    than a handful of tiers, far below the 37 a barrier would stack."""
    rng = np.random.default_rng(1)
    zero = aggregator.zero_stats("ea", 2, 8)
    tree = aggregator.AggregatorTree(zero, fanout=4)
    linear = zero
    for _ in range(37):
        s = aggregator.PartialStats(
            "ea",
            jnp.asarray(rng.normal(size=(2, 8)), jnp.float32),
            jnp.zeros((2,), jnp.float32),
            jnp.zeros((2,), jnp.float32),
            jnp.asarray(rng.random(), jnp.float32),
            jnp.ones((), jnp.float32),
        )
        tree.push(s)
        linear = aggregator.stats_add(linear, s)
    np.testing.assert_allclose(
        np.asarray(tree.root().y), np.asarray(linear.y), rtol=1e-5, atol=1e-6
    )
    assert tree.pushed == 37
    assert len(tree.tiers) <= 4  # ceil(log4 37) + 1
    assert tree.peak_live_bytes <= 4 * zero.nbytes
    assert tree.peak_live_bytes < 37 * zero.nbytes


def test_stats_mode_mismatch_raises():
    a = aggregator.zero_stats("ae", 1, 8)
    b = aggregator.zero_stats("ea", 1, 8)
    with pytest.raises(ValueError, match="fold"):
        aggregator.stats_add(a, b)
    with pytest.raises(ValueError, match="mode"):
        aggregator.zero_stats("nope", 1, 8)


# ---------------------------------------------------------------------------
# streamed decode vs the one-shot barrier (the pinned contract)
# ---------------------------------------------------------------------------


def test_stream_decode_matches_barrier_ae(payload):
    codec, words, codes, alphas, w = payload
    rhos = jnp.asarray(w / w.sum())
    g_bar = aggregate_and_estimate(codec, codes, alphas, rhos, gamp=gamp_config_from(codec))
    g_str, info = stream_decode(
        codec, words, alphas, w, _batches(13), mode="ae", stream=_scfg()
    )
    assert _nmse(g_str, g_bar) <= NMSE_TOL
    np.testing.assert_allclose(np.asarray(g_str), np.asarray(g_bar), atol=ATOL)
    assert info["participating"] == 12.0
    assert info["batches_admitted"] == 4


def test_stream_decode_matches_barrier_ea(payload):
    codec, words, _, alphas, w = payload
    rhos = jnp.asarray(w / w.sum())
    g_bar = estimate_and_aggregate_packed(codec, words, alphas, rhos)
    g_str, _ = stream_decode(
        codec, words, alphas, w, _batches(13), mode="ea", stream=_scfg()
    )
    assert _nmse(g_str, g_bar) <= NMSE_TOL
    np.testing.assert_allclose(np.asarray(g_str), np.asarray(g_bar), atol=ATOL)


def test_stream_reorder_within_contract(payload):
    """Sub-cohort batches arriving in ANY order decode the same aggregate
    (fold order changes only f32 reassociation)."""
    codec, words, _, alphas, w = payload
    batches = _batches(13)
    ref, _ = stream_decode(codec, words, alphas, w, batches, stream=_scfg())
    for perm in ([3, 1, 0, 2], [1, 3, 2, 0]):
        got, _ = stream_decode(
            codec, words, alphas, w, [batches[i] for i in perm], stream=_scfg()
        )
        assert _nmse(got, ref) <= NMSE_TOL


def test_stream_duplicate_batch_rejected_not_double_counted(payload):
    """A redelivered batch is rejected at buffer admission: the decode is
    BITWISE identical to the clean round, with the rejection counted."""
    codec, words, _, alphas, w = payload
    batches = _batches(13)
    ref, info0 = stream_decode(codec, words, alphas, w, batches, stream=_scfg())
    dup = batches[:1] + batches  # batch 0 delivered twice
    got, info = stream_decode(codec, words, alphas, w, dup, stream=_scfg())
    assert info["batches_rejected_dup"] == 1
    assert info["batches_admitted"] == info0["batches_admitted"]
    assert bool(jnp.all(got == ref))


def test_stream_dropped_batch_degrades_to_nonparticipation(payload):
    """A batch that never arrives decodes as if its clients had weight 0 --
    exactly the barrier aggregate over the surviving sub-cohort."""
    codec, words, codes, alphas, w = payload
    batches = _batches(13)
    survived = batches[:2] + batches[3:]  # batch 2 lost
    w_eff = w.copy()
    w_eff[batches[2]] = 0.0
    rhos = jnp.asarray(w_eff / w_eff.sum())
    g_bar = aggregate_and_estimate(codec, codes, alphas, rhos, gamp=gamp_config_from(codec))
    g_str, info = stream_decode(codec, words, alphas, w, survived, stream=_scfg())
    assert _nmse(g_str, g_bar) <= NMSE_TOL
    assert info["participating"] == float(np.sum(w_eff > 0))


def test_stream_empty_round_is_exact_zero_update(payload):
    """Nothing arrived by the deadline: graceful degradation to the exact
    zero aggregate (the barrier blackout behavior), no GAMP run."""
    codec, words, _, alphas, w = payload
    g, info = stream_decode(codec, words, alphas, w, [], stream=_scfg())
    np.testing.assert_array_equal(np.asarray(g), 0.0)
    assert info["participating"] == 0.0


def test_noisy_stream_is_batching_invariant(payload):
    """Per-CLIENT noise keys make the channel draw independent of how
    arrivals batch up: 4-client batches and one 13-client batch fold the
    same noisy observation (up to reassociation)."""
    codec, words, _, alphas, w = payload
    nu_chan = jnp.full(alphas.shape, 0.05, jnp.float32)
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(9), i))(
        jnp.arange(13)
    )
    a, _ = stream_decode(
        codec, words, alphas, w, _batches(13, 4), stream=_scfg(),
        nu_chan=nu_chan, noise_keys=keys,
    )
    b, _ = stream_decode(
        codec, words, alphas, w, _batches(13, 13),
        stream=_scfg(batch_clients=13, buffer_batches=1),
        nu_chan=nu_chan, noise_keys=keys,
    )
    assert _nmse(a, b) <= NMSE_TOL


# ---------------------------------------------------------------------------
# bounded ingest buffer
# ---------------------------------------------------------------------------


def test_bounded_buffer_contract():
    buf = BoundedIngestBuffer(2)
    assert buf.push(b"a", 1) and buf.push(b"b", 2)
    assert buf.full and len(buf) == 2
    assert not buf.push(b"a", 1)  # duplicate: rejected, does NOT occupy a slot
    assert buf.rejected_dup == 1 and len(buf) == 2
    with pytest.raises(RuntimeError, match="full"):
        buf.push(b"c", 3)
    assert buf.pop() == 1  # FIFO
    assert buf.push(b"c", 3)
    assert not buf.push(b"b", 2)  # dedup persists across drains
    assert buf.peak_occupancy == 2
    with pytest.raises(ValueError, match="capacity"):
        BoundedIngestBuffer(0)


def test_stream_backpressure_bounds_buffer(payload):
    """With a 1-slot buffer the driver must drain before every push: the
    round still decodes every batch, with peak occupancy pinned at 1."""
    codec, words, _, alphas, w = payload
    batches = _batches(13, 2)
    ref, _ = stream_decode(codec, words, alphas, w, batches, stream=_scfg(batch_clients=2))
    got, info = stream_decode(
        codec, words, alphas, w, batches,
        stream=_scfg(batch_clients=2, buffer_batches=1),
    )
    assert info["buffer_peak_occupancy"] == 1
    assert info["batches_admitted"] == len(batches)
    assert _nmse(got, ref) <= NMSE_TOL


# ---------------------------------------------------------------------------
# arrival simulator
# ---------------------------------------------------------------------------


def test_simulate_arrivals_deterministic_and_masks_dead():
    cfg = StreamConfig(seed=5, straggler_prob=0.3, straggler_mult=100.0)
    alive = np.array([True] * 8 + [False] * 2)
    t1 = simulate_arrivals(cfg, 3, 10, alive)
    t2 = simulate_arrivals(cfg, 3, 10, alive)
    np.testing.assert_array_equal(t1, t2)
    assert np.all(np.isinf(t1[8:])) and np.all(np.isfinite(t1[:8]))
    assert not np.array_equal(t1, simulate_arrivals(cfg, 4, 10, alive))


def test_batch_arrivals_partitions_in_arrival_order():
    times = np.array([5.0, 0.1, np.inf, 2.0, 9.0, 1.0])
    batches = batch_arrivals(times, 8.0, 2)
    assert [list(b) for b in batches] == [[1, 5], [3, 0]]  # 4 missed, 2 is inf
    flat = np.concatenate(batches)
    assert np.all(np.diff(times[flat]) >= 0)


def test_late_discount_monotone_and_identity():
    cfg = StreamConfig(soft_deadline=2.0, late_decay=0.7)
    t = np.array([0.5, 2.0, 3.0, 5.0, np.inf])
    d = late_discount(cfg, t)
    assert d[0] == d[1] == 1.0  # beat the soft deadline: undiscounted
    assert np.all(np.diff(d[1:4]) < 0)  # later arrival => smaller weight
    np.testing.assert_array_equal(
        late_discount(StreamConfig(late_decay=0.0), t), 1.0
    )


# ---------------------------------------------------------------------------
# collectives partial-aggregation entry points
# ---------------------------------------------------------------------------


def test_collectives_partial_fold_and_finalize(payload):
    codec, words, codes, alphas, w = payload
    jw = jnp.asarray(w)
    stats = None
    for sl in (slice(0, 6), slice(6, 13)):
        stats = fedqcs_partial_fold(stats, words[sl], alphas[sl], jw[sl], codec)
    one = aggregator.ae_batch_stats(codec, words, alphas, jw)
    np.testing.assert_allclose(np.asarray(stats.y), np.asarray(one.y), rtol=1e-5, atol=1e-6)
    rhos = jnp.asarray(w / w.sum())
    g_bar = aggregate_and_estimate(codec, codes, alphas, rhos, gamp=gamp_config_from(codec))
    g = fedqcs_partial_finalize(stats, codec)
    assert _nmse(g, g_bar) <= NMSE_TOL


def test_decode_from_stats_ea_is_normalized_sum():
    ghat = jnp.asarray(np.random.default_rng(2).normal(size=(5, 2, 16)), jnp.float32)
    w = jnp.asarray([0.5, 1.5, 0.0, 2.0, 1.0])
    stats = aggregator.ea_batch_stats(ghat, w)
    out = decode_from_stats(BQCSCodec(FED), stats)
    want = jnp.einsum("k,kbn->bn", w / jnp.sum(w), ghat)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# engine streaming round mode
# ---------------------------------------------------------------------------

DIM, CLASSES = 24, 4


def _engine(clients=8, **kw):
    x, y = toy_classification(n_samples=600, dim=DIM, classes=CLASSES, seed=0)
    parts = partition_indices(
        y, clients, PartitionConfig(kind="dirichlet", alpha=0.2, min_size=4)
    )
    defaults = dict(
        fed_cfg=FED,
        cohort=CohortConfig(method="fedqcs-ae"),
        sched=SchedulerConfig(),
        chan=ChannelConfig(),
        server=ServerOptConfig(lr=0.01),
    )
    defaults.update(kw)
    return CohortEngine(
        toy_params(dim=DIM, classes=CLASSES, seed=0),
        jax.grad(toy_loss),
        ArrayClientData(x, y, parts, batch_size=4),
        **defaults,
    )


def test_engine_streaming_matches_barrier_round():
    """With a deadline no client misses, the streaming round and the barrier
    round drive IDENTICAL training trajectories (within the reconstruction
    round-off of the pinned contract)."""
    barrier = _engine()
    stream = _engine(stream=StreamConfig(batch_clients=3, deadline=1e9, fanout=2))
    for _ in range(2):
        sb = barrier.run_round()
        ss = stream.run_round()
        assert np.isfinite(sb["nmse"]) and np.isfinite(ss["nmse"])
    assert ss["participating"] == sb["participating"] == 8.0
    for a, b in zip(
        jax.tree_util.tree_leaves(barrier.params), jax.tree_util.tree_leaves(stream.params)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL)
    np.testing.assert_allclose(
        np.asarray(barrier.residuals), np.asarray(stream.residuals), atol=ATOL
    )


def test_engine_streaming_ea_round_runs():
    e = _engine(
        cohort=CohortConfig(method="fedqcs-ea"),
        stream=StreamConfig(batch_clients=4, deadline=1e9),
    )
    stats = e.run(2)[-1]
    assert np.isfinite(stats["nmse"])
    assert stats["participating"] == 8.0


def test_engine_streaming_deadline_cutoff_full_residual_carry():
    """Total straggler blackout: nobody beats the deadline.  The round still
    completes as an exact zero update, every cohort residual absorbs the FULL
    gradient (the PR-3 non-participation contract), and no client is stamped
    as having participated."""
    e = _engine(
        sched=SchedulerConfig(kind="async", sample_frac=1.0),
        stream=StreamConfig(
            batch_clients=4, deadline=8.0, straggler_prob=1.0, straggler_mult=1e12
        ),
    )
    ref = _engine()  # same seeds: reproduces the round-0 gradient blocks
    params0 = e.params
    ids = np.arange(8)
    blocks = ref._grads_jit(ref.params, ref.data.cohort_batch(0, ids))

    stats = e.run_round()
    assert stats["participating"] == 0.0 and stats["arrived"] == 0.0
    # zero update: fedavg with a zero aggregate leaves params untouched
    for a, b in zip(jax.tree_util.tree_leaves(params0), jax.tree_util.tree_leaves(e.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # full carry: residual == blocks + (zero) prior residual, bit-exact
    np.testing.assert_array_equal(np.asarray(e.residuals), np.asarray(blocks))
    # non-arrival is not participation: nobody's last_round was stamped
    np.testing.assert_array_equal(e.sched_state.last_round, -1)


def test_engine_streaming_noisy_channel_round():
    e = _engine(
        chan=ChannelConfig(kind="awgn", snr_db=10.0),
        stream=StreamConfig(batch_clients=3, deadline=1e9),
    )
    stats = e.run(2)[-1]
    assert np.isfinite(stats["nmse"])


def test_engine_streaming_rejects_non_fedqcs_methods():
    with pytest.raises(ValueError, match="streaming"):
        _engine(cohort=CohortConfig(method="signsgd"), stream=StreamConfig())
    with pytest.raises(ValueError, match="groups"):
        _engine(
            cohort=CohortConfig(method="fedqcs-ae", groups=2), stream=StreamConfig()
        )
