"""Test-suite device setup: the distributed-system tests (tests/test_system.py)
need a simulated (pod=2, data=2, model=2) mesh = 8 host devices.  This is
test-local configuration: the production dry-run sets its own 512-device
count inside repro/launch/dryrun.py, and benchmarks run with the default
single device."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
