"""repro.fed cohort engine tests: partitioners, schedulers, channel models,
vmap-vs-loop bit-exactness, channel->GAMP noise threading, server optimizers,
and the 1000-client acceptance scenario on the MNIST MLP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import FedQCSConfig
from repro.fed.channel import ChannelConfig, realize_uplink, snr_noise_var
from repro.fed.engine import (
    ArrayClientData,
    CohortConfig,
    CohortEngine,
    TokenClientData,
)
from repro.fed.partition import PartitionConfig, partition_indices, partition_stats
from repro.fed.scheduler import (
    SchedulerConfig,
    SchedulerState,
    select_cohort,
    staleness_discount,
)
from repro.fed.server_opt import ServerOptConfig
from repro.fed.toy import toy_classification, toy_loss, toy_params

# ---------------------------------------------------------------------------
# shared tiny federation (fast: 24-dim classifier, 64-entry blocks)
# ---------------------------------------------------------------------------

DIM, CLASSES, N_SAMPLES = 24, 4, 600
FED = FedQCSConfig(block_size=64, reduction_ratio=2, bits=3, s_ratio=0.1,
                   gamp_iters=10, gamp_variance_mode="scalar")
_loss = toy_loss


def _dataset(seed=0):
    return toy_classification(n_samples=N_SAMPLES, dim=DIM, classes=CLASSES, seed=seed)


def _params(seed=0):
    return toy_params(dim=DIM, classes=CLASSES, seed=seed)


def _engine(clients=8, **kw):
    x, y = _dataset()
    parts = partition_indices(
        y, clients, PartitionConfig(kind="dirichlet", alpha=0.2, min_size=4)
    )
    defaults = dict(
        fed_cfg=FED,
        cohort=CohortConfig(method="fedqcs-ae"),
        sched=SchedulerConfig(),
        chan=ChannelConfig(),
        server=ServerOptConfig(lr=0.01),
    )
    defaults.update(kw)
    return CohortEngine(
        _params(), jax.grad(_loss), ArrayClientData(x, y, parts, batch_size=4),
        **defaults,
    )


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["iid", "shard", "dirichlet"])
def test_partition_disjoint_cover(kind):
    _, y = _dataset()
    parts = partition_indices(y, 10, PartitionConfig(kind=kind, alpha=0.5))
    allidx = np.concatenate(parts)
    assert len(allidx) == len(np.unique(allidx)) == N_SAMPLES  # disjoint cover
    assert all(len(p) > 0 for p in parts)


def test_partition_deterministic():
    _, y = _dataset()
    cfg = PartitionConfig(kind="dirichlet", alpha=0.1, seed=3)
    a = partition_indices(y, 7, cfg)
    b = partition_indices(y, 7, cfg)
    assert all(np.array_equal(pa, pb) for pa, pb in zip(a, b))


def test_dirichlet_alpha_controls_skew():
    """Low alpha -> near one-class clients; high alpha -> near-uniform."""
    _, y = _dataset()

    def skew(alpha):
        parts = partition_indices(y, 12, PartitionConfig(kind="dirichlet", alpha=alpha))
        stats = partition_stats(parts, y)
        frac = stats / np.maximum(stats.sum(axis=1, keepdims=True), 1)
        return float(frac.max(axis=1).mean())  # mean dominant-class fraction

    assert skew(0.05) > skew(100.0) + 0.2
    assert skew(100.0) < 0.55  # near the 1/CLASSES=0.25 uniform level


def test_paper_partition_one_digit_per_client():
    _, y = _dataset()
    parts = partition_indices(y, 8, PartitionConfig(kind="paper", per_client=20))
    stats = partition_stats(parts, y)
    assert (np.count_nonzero(stats, axis=1) == 1).all()  # single label each
    # generalized digit map: client k holds label k * n_classes // clients
    labels = stats.argmax(axis=1)
    assert np.array_equal(labels, np.arange(8) * CLASSES // 8)
    assert all(len(p) == 20 for p in parts)


# ---------------------------------------------------------------------------
# schedulers
# ---------------------------------------------------------------------------


def test_full_scheduler_counts_proportional():
    counts = np.array([10, 30, 60])
    ids, rhos, _ = select_cohort(
        SchedulerConfig(kind="full"), SchedulerState.init(3), 0, counts
    )
    assert np.array_equal(ids, [0, 1, 2])
    np.testing.assert_allclose(rhos, counts / counts.sum(), rtol=1e-6)


def test_uniform_sampling_size_and_determinism():
    counts = np.ones(100)
    cfg = SchedulerConfig(kind="uniform", sample_frac=0.25, seed=5)
    st = SchedulerState.init(100)
    ids1, rhos1, _ = select_cohort(cfg, st, 3, counts)
    ids2, _, _ = select_cohort(cfg, st, 3, counts)
    assert len(ids1) == 25 and np.array_equal(ids1, ids2)
    assert abs(rhos1.sum() - 1.0) < 1e-6
    ids3, _, _ = select_cohort(cfg, st, 4, counts)
    assert not np.array_equal(ids1, ids3)  # fresh draw per round


def test_dropout_zeroes_rho_and_tracks_participation():
    counts = np.ones(50)
    cfg = SchedulerConfig(kind="uniform", sample_frac=1.0, dropout_prob=0.5, seed=1)
    st = SchedulerState.init(50)
    ids, rhos, st2 = select_cohort(cfg, st, 0, counts)
    dropped = rhos == 0
    assert dropped.any() and (~dropped).any()  # p=0.5 over 50 draws
    assert abs(rhos.sum() - 1.0) < 1e-6
    # only survivors' last_round advances
    assert (st2.last_round[ids[~dropped]] == 0).all()
    assert (st2.last_round[ids[dropped]] == -1).all()
    # total blackout -> all-zero rhos (engine then applies a zero update)
    _, rhos_all, _ = select_cohort(
        SchedulerConfig(kind="uniform", dropout_prob=1.0), st, 0, counts
    )
    assert (rhos_all == 0).all()


def test_async_staleness_downweights():
    counts = np.ones(4)
    st = SchedulerState(last_round=np.array([5, 0, 5, 5]))
    cfg = SchedulerConfig(kind="async", sample_frac=1.0, staleness_decay=1.0)
    _, rhos, _ = select_cohort(cfg, st, 6, counts)
    # client 1 missed rounds 1..5 -> staleness 5 -> weight 1/(1+5) of fresh
    np.testing.assert_allclose(rhos[1] / rhos[0], 1.0 / 6.0, rtol=1e-6)
    assert abs(rhos.sum() - 1.0) < 1e-6


# ---------------------------------------------------------------------------
# channels
# ---------------------------------------------------------------------------


def test_channel_noise_var_mapping():
    key = jax.random.PRNGKey(0)
    ideal = realize_uplink(ChannelConfig(), key, 4, 3)
    assert (np.asarray(ideal.noise_var) == 0).all() and (np.asarray(ideal.mask) == 1).all()
    awgn = realize_uplink(ChannelConfig(kind="awgn", snr_db=10.0), key, 4, 3)
    np.testing.assert_allclose(np.asarray(awgn.noise_var), 0.1, rtol=1e-6)
    assert abs(snr_noise_var(0.0) - 1.0) < 1e-12  # 0 dB = unit noise power


def test_rayleigh_fading_and_outage():
    cfg = ChannelConfig(kind="rayleigh", snr_db=10.0, outage_gain=0.5)
    real = realize_uplink(cfg, jax.random.PRNGKey(2), 500, 2)
    mask = np.asarray(real.mask)
    nu = np.asarray(real.noise_var)
    # P(outage) = 1 - exp(-0.5) ~ 0.39
    assert 0.25 < 1.0 - mask.mean() < 0.55
    assert (nu[mask == 0] == 0).all()  # outage slots carry no noise term
    assert (nu[mask == 1] > 0).all()
    # equalized variance is sigma^2 / gain, so it exceeds sigma^2 for the
    # sub-unit-gain survivors
    assert nu[mask == 1].max() > snr_noise_var(10.0)
    assert (nu[:, 0] == nu[:, 1]).all()  # block fading: constant per client


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def _trees_equal(a, b):
    return all(
        bool(jnp.all(la == lb))
        for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def test_engine_vmap_matches_loop_bitexact():
    """The vmapped cohort pass and the per-client Python-loop oracle produce
    bit-identical params, residuals, and stats — with partial participation,
    dropout, and a noisy AWGN uplink all active."""
    kw = dict(
        sched=SchedulerConfig(kind="uniform", sample_frac=0.75, dropout_prob=0.25),
        chan=ChannelConfig(kind="awgn", snr_db=10.0),
    )
    ev = _engine(cohort=CohortConfig(method="fedqcs-ae", impl="vmap"), **kw)
    el = _engine(cohort=CohortConfig(method="fedqcs-ae", impl="loop"), **kw)
    for _ in range(3):
        sv, sl = ev.run_round(), el.run_round()
        assert sv == sl
    assert _trees_equal(ev.params, el.params)
    assert bool(jnp.all(ev.residuals == el.residuals))


def test_engine_chunked_scan_matches_single_pass():
    """chunk-scanning the client pass changes memory, not values."""
    ec = _engine(cohort=CohortConfig(method="fedqcs-ae", chunk=3))
    e1 = _engine(cohort=CohortConfig(method="fedqcs-ae", chunk=0))
    for _ in range(2):
        ec.run_round(), e1.run_round()
    assert _trees_equal(ec.params, e1.params)
    assert bool(jnp.all(ec.residuals == e1.residuals))


@pytest.mark.parametrize("method", ["fedqcs-ea", "qcs-qiht", "qcs-dither", "signsgd", "none"])
def test_engine_methods_run_and_match_loop(method):
    """Every legacy method runs through the engine, and the vmapped pass
    stays bit-identical to the loop oracle."""
    ev = _engine(cohort=CohortConfig(method=method, impl="vmap"))
    el = _engine(cohort=CohortConfig(method=method, impl="loop"))
    sv, sl = ev.run_round(), el.run_round()
    assert sv == sl and all(np.isfinite(v) for v in sv.values())
    assert _trees_equal(ev.params, el.params)


def test_engine_channel_noise_threads_into_gamp():
    """The uplink's effective variance reaches em_gamp's noise_var: the round
    stats expose a positive channel term at finite SNR (zero when ideal) and
    reconstruction NMSE degrades as SNR drops."""
    ideal = _engine(chan=ChannelConfig())
    noisy = _engine(chan=ChannelConfig(kind="awgn", snr_db=0.0))
    s_ideal = [ideal.run_round() for _ in range(4)]
    s_noisy = [noisy.run_round() for _ in range(4)]
    assert all(s["nu_channel"] == 0.0 for s in s_ideal)
    assert all(s["nu_channel"] > 0.0 for s in s_noisy)
    assert np.mean([s["nmse"] for s in s_noisy]) > np.mean(
        [s["nmse"] for s in s_ideal]
    )


def test_engine_dropout_blackout_is_zero_update_with_full_residual_carry():
    """All clients dropped -> params unchanged, and every cohort member's
    residual absorbs its full gradient (nothing a straggler computed is
    lost)."""
    e = _engine(
        sched=SchedulerConfig(dropout_prob=1.0),
        cohort=CohortConfig(method="fedqcs-ae", record_nmse=False),
    )
    p0 = e.params
    e.run_round()
    assert _trees_equal(e.params, p0)  # zero aggregate -> zero Adam update
    # residuals: full carry = blocks + 0; recompute client 0's blocks directly
    from repro.core.compression import flatten_to_blocks

    batch = e.data.cohort_batch(0, np.arange(e.clients))
    g0 = e.grad_fn(p0, jax.tree_util.tree_map(lambda x: x[0], batch))
    blocks0, _, _ = flatten_to_blocks(g0, e.n)
    np.testing.assert_array_equal(np.asarray(e.residuals[0]), np.asarray(blocks0))


def test_channel_outage_not_counted_as_participation():
    """A client whose uplink is in outage contributed nothing: the async
    staleness tracker must keep its last *successful* round, not stamp it."""
    e = _engine(
        chan=ChannelConfig(kind="rayleigh", snr_db=10.0, outage_gain=0.7),
        cohort=CohortConfig(method="fedqcs-ae", record_nmse=False),
    )
    s = e.run_round()
    n_out = int(s["cohort"] - s["participating"])
    assert n_out > 0  # P(outage) ~ 0.5/client at the 0.7 gain floor
    assert (e.sched_state.last_round == -1).sum() == n_out
    assert (e.sched_state.last_round == 0).sum() == s["participating"]


@pytest.mark.parametrize("kind", ["fedavg", "fedavgm", "fedadam"])
def test_server_optimizers_learn(kind):
    lr = {"fedavg": 0.3, "fedavgm": 0.03, "fedadam": 0.02}[kind]
    e = _engine(server=ServerOptConfig(kind=kind, lr=lr),
                cohort=CohortConfig(method="fedqcs-ae", record_nmse=False))
    x, y = _dataset(seed=7)
    probe = {"x": jnp.asarray(x[:256]), "y": jnp.asarray(y[:256])}
    before = float(_loss(e.params, probe))
    e.run(12)
    after = float(_loss(e.params, probe))
    assert np.isfinite(after) and after < before, (kind, before, after)
    if kind == "fedavgm":
        assert any(
            float(jnp.max(jnp.abs(m))) > 0 for m in jax.tree_util.tree_leaves(e.server_state["m"])
        )


def test_engine_ea_packed_chunked_round():
    """fedqcs-ea through the engine: the payload carries the packed uint32
    wire words (no uint8 code view in the client pass), and a
    recon_chunk-streamed PS decode (DESIGN.md #Recon-engine) matches the
    monolithic engine round to reconstruction round-off."""
    import dataclasses

    from repro.core.compression import packed_width

    outs = {}
    for chunk in (0, 4):
        fed = dataclasses.replace(FED, recon_chunk=chunk)
        e = _engine(fed_cfg=fed, cohort=CohortConfig(method="fedqcs-ea"))
        payloads, _ = e._client_pass(
            e.params,
            e.data.cohort_batch(0, np.arange(8)),
            e.residuals[jnp.arange(8)],
            jnp.full((8,), 1 / 8),
            jax.vmap(jax.random.PRNGKey)(jnp.arange(8)),
        )
        assert "codes" not in payloads
        assert payloads["words"].dtype == jnp.uint32
        assert payloads["words"].shape[-1] == packed_width(FED.m, FED.bits)
        stats = e.run(2)[-1]
        assert np.isfinite(stats["nmse"]), stats
        outs[chunk] = e.params
    for a, b in zip(jax.tree_util.tree_leaves(outs[0]),
                    jax.tree_util.tree_leaves(outs[4])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_engine_rejects_noisy_channel_for_code_domain_methods():
    with pytest.raises(ValueError, match="ideal"):
        _engine(
            cohort=CohortConfig(method="fedqcs-ea"),
            chan=ChannelConfig(kind="awgn", snr_db=10.0),
        )
    with pytest.raises(ValueError, match="unknown method"):
        _engine(cohort=CohortConfig(method="nope"))


def test_token_client_data_dialect_skew():
    data = TokenClientData(vocab_size=97, batch=4, seq=16, clients=6, alpha=0.01, seed=1)
    b1 = data.cohort_batch(0, np.array([0, 1, 2]))
    b2 = data.cohort_batch(0, np.array([0, 1, 2]))
    assert b1["tokens"].shape == (3, 4, 16)
    assert bool(jnp.all(b1["tokens"] == b2["tokens"]))  # deterministic
    b3 = data.cohort_batch(1, np.array([0, 1, 2]))
    assert not bool(jnp.all(b1["tokens"] == b3["tokens"]))  # fresh per round
    # alpha -> 0: each client's dialect mixture is nearly one-hot
    assert float(data._p.max(axis=1).mean()) > 0.8


# ---------------------------------------------------------------------------
# the acceptance scenario: 1000 clients on the MNIST MLP
# ---------------------------------------------------------------------------


def test_mnist_mlp_vmap_matches_loop_bitexact_small_scale():
    """run_federated (rewired onto the engine) is bit-identical between the
    vmapped cohort path and the per-client loop oracle on the paper model."""
    from repro.paper.mlp import run_federated

    fed = FedQCSConfig(reduction_ratio=3, bits=3, s_ratio=0.1, gamp_iters=8,
                       gamp_variance_mode="scalar")
    kw = dict(steps=2, k_devices=8, fed_cfg=fed, eval_every=1,
              partition="dirichlet", alpha=0.1, channel="awgn", snr_db=10.0)
    rv = run_federated("fedqcs-ae", impl="vmap", **kw)
    rl = run_federated("fedqcs-ae", impl="loop", **kw)
    assert rv.accs == rl.accs and rv.nmses == rl.nmses and rv.losses == rl.losses


def test_mnist_mlp_1000_client_round():
    """The headline scenario: a 1000-client Dirichlet(0.1) federation, 10%
    uniform sampling, AWGN 10 dB uplink, reconstructed through the vmapped
    cohort path on the paper's 784-20-10 MLP."""
    from repro.data import mnist
    from repro.paper.mlp import init_mlp, mlp_grad_fn

    (xtr, ytr, _, _), _ = mnist.load(0)
    parts = partition_indices(
        ytr, 1000, PartitionConfig(kind="dirichlet", alpha=0.1, min_size=2)
    )
    fed = FedQCSConfig(block_size=1591, reduction_ratio=3, bits=3, s_ratio=0.1,
                       gamp_iters=10, gamp_variance_mode="scalar")
    engine = CohortEngine(
        init_mlp(jax.random.PRNGKey(0)),
        mlp_grad_fn,
        ArrayClientData(xtr, ytr, parts, batch_size=1),
        fed_cfg=fed,
        cohort=CohortConfig(method="fedqcs-ae", impl="vmap"),
        sched=SchedulerConfig(kind="uniform", sample_frac=0.1),
        chan=ChannelConfig(kind="awgn", snr_db=10.0),
        server=ServerOptConfig(lr=0.003),
    )
    assert engine.clients == 1000
    stats = engine.run_round()
    assert stats["cohort"] == 100  # 10% of 1000
    assert stats["participating"] == 100
    assert stats["nu_channel"] > 0  # the uplink term reached em_gamp
    assert np.isfinite(stats["nmse"])
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree_util.tree_leaves(engine.params))


# ---------------------------------------------------------------------------
# scheduler property tests + channel edge regimes (streaming-PS hardening)
# ---------------------------------------------------------------------------

try:  # optional dev dependency (pyproject [dev] extra)
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # property tests skip via importorskip
    from hypothesis_stub import hypothesis, st


@hypothesis.given(
    kind=st.sampled_from(["full", "uniform", "async"]),
    clients=st.integers(1, 40),
    sample_frac=st.floats(0.05, 1.0),
    dropout=st.floats(0.0, 1.0),
    decay=st.floats(0.0, 3.0),
    round_idx=st.integers(0, 6),
    seed=st.integers(0, 999),
)
@hypothesis.settings(max_examples=50, deadline=None)
def test_scheduler_weight_invariants(
    kind, clients, sample_frac, dropout, decay, round_idx, seed
):
    """Invariants over every scheduler kind and knob draw: cohort ids are
    unique, rhos are nonnegative and renormalize to exactly 1 (or all-zero on
    a total blackout), and the participation stamps are EXACTLY the rho > 0
    support -- a dropped/outage slot is never stamped as participation."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, 50, size=clients)
    state = SchedulerState.init(clients)
    if round_idx > 0:  # arbitrary prior history, strictly before this round
        state.last_round[:] = rng.integers(-1, round_idx, size=clients)
    prior = state.last_round.copy()
    cfg = SchedulerConfig(
        kind=kind, sample_frac=sample_frac, dropout_prob=dropout,
        staleness_decay=decay, seed=seed,
    )
    ids, rhos, new = select_cohort(cfg, state, round_idx, counts)
    assert len(np.unique(ids)) == len(ids)
    if kind in ("uniform", "async"):
        assert len(ids) == min(max(1, int(np.ceil(sample_frac * clients))), clients)
    assert rhos.shape == ids.shape and np.all(rhos >= 0)
    total = float(rhos.sum())
    assert total == pytest.approx(1.0, abs=1e-5) or total == 0.0
    stamped = np.flatnonzero(new.last_round == round_idx)
    np.testing.assert_array_equal(np.sort(stamped), np.sort(ids[rhos > 0]))
    assert np.intersect1d(stamped, ids[rhos == 0]).size == 0
    untouched = np.setdiff1d(np.arange(clients), ids[rhos > 0])
    np.testing.assert_array_equal(new.last_round[untouched], prior[untouched])


@hypothesis.given(
    decay=st.floats(0.0, 4.0),
    staleness=st.lists(st.floats(0.0, 1e3), min_size=2, max_size=16),
)
@hypothesis.settings(max_examples=50, deadline=None)
def test_staleness_discount_monotone(decay, staleness):
    """The shared discount (async scheduler + streaming late arrivals) is
    monotone non-increasing in staleness, bounded in (0, 1], and the
    identity at staleness 0 or decay 0."""
    s = np.sort(np.asarray(staleness))
    d = staleness_discount(s, decay)
    assert np.all(np.diff(d) <= 1e-12)
    assert np.all((d > 0) & (d <= 1.0))
    assert staleness_discount(np.zeros(1), decay)[0] == 1.0
    np.testing.assert_array_equal(staleness_discount(s, 0.0), 1.0)


def test_awgn_infinite_snr_is_ideal_bitexact():
    """SNR -> inf degrades the awgn uplink to the ideal one bit-exactly
    (zero added variance, everyone alive) -- the sweep's regime boundary."""
    key = jax.random.PRNGKey(3)
    ideal = realize_uplink(ChannelConfig(kind="ideal"), key, 7, 5)
    awgn = realize_uplink(ChannelConfig(kind="awgn", snr_db=np.inf), key, 7, 5)
    assert snr_noise_var(np.inf) == 0.0
    np.testing.assert_array_equal(
        np.asarray(awgn.noise_var), np.asarray(ideal.noise_var)
    )
    np.testing.assert_array_equal(np.asarray(awgn.mask), np.asarray(ideal.mask))


def test_rayleigh_fixed_key_deterministic_across_jit():
    """A fixed key gives the same block-fading draw whether realize_uplink
    runs eagerly or jitted (the frozen config is a static argument) -- the
    determinism the engine's loop/vmap bit-exactness rests on."""
    cfg = ChannelConfig(kind="rayleigh", snr_db=10.0)
    key = jax.random.PRNGKey(7)
    eager = realize_uplink(cfg, key, 64, 3)
    jit_fn = jax.jit(realize_uplink, static_argnums=(0, 2, 3))
    jitted = jit_fn(cfg, key, 64, 3)
    np.testing.assert_array_equal(
        np.asarray(eager.noise_var), np.asarray(jitted.noise_var)
    )
    np.testing.assert_array_equal(np.asarray(eager.mask), np.asarray(jitted.mask))
    again = jit_fn(cfg, key, 64, 3)
    np.testing.assert_array_equal(
        np.asarray(jitted.noise_var), np.asarray(again.noise_var)
    )
