"""GradientLayout: per-tensor block geometry + streamed encode (core/layout.py).

Pins the tentpole invariants of the layout refactor:

  * the default monolithic layout produces BIT-IDENTICAL packed wire words to
    the pre-refactor flatten (inline golden reimplementation below);
  * the segment-streamed encode of a per-tensor layout is bit-identical to
    the one-pass encode of the same layout (every codec stage is per-block);
  * layout <-> tree roundtrips are exact across the registry models, uneven
    leaf sizes, and row_multiple padding (hypothesis properties + eager
    sweeps -- hypothesis is an optional dev dependency, see hypothesis_stub);
  * flat index math that would overflow int32 raises at construction with
    the per-tensor layout named as the fix;
  * the streamed encoder's live-memory bound is the LARGEST segment, and the
    engine's encode_stream path reproduces the monolithic-pass residuals.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (
    BQCSCodec,
    FedQCSConfig,
    blocks_to_tree,
    flatten_to_blocks,
    flatten_to_blocks_batched,
)
from repro.core.layout import INT32_MAX, GradientLayout, as_layout

try:  # optional dev dependency (pyproject [dev] extra)
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # property tests skip via importorskip
    from hypothesis_stub import hypothesis, st

KEY = jax.random.PRNGKey(0)
CFG = FedQCSConfig(block_size=64, reduction_ratio=2, bits=3, gamp_iters=8)


def _tree(sizes, seed=0):
    """Uneven-leaf pytree: dict of 1D/2D float32 leaves of the given sizes."""
    rng = np.random.default_rng(seed)
    out = {}
    for i, s in enumerate(sizes):
        shape = (s,) if (i % 2 == 0 or s < 4) else (s // 2, 2) if s % 2 == 0 else (s,)
        out[f"w{i}"] = jnp.asarray(rng.normal(size=shape), jnp.float32)
    return out


def _golden_flatten(tree, n, row_multiple=1):
    """The PRE-REFACTOR flatten_to_blocks, verbatim: one concat of raveled
    f32 leaves, one trailing zero-pad, reshape.  The monolithic layout must
    reproduce this bit-for-bit."""
    leaves = jax.tree_util.tree_leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    nbar = flat.shape[0]
    nblocks = -(-nbar // n)
    nblocks = -(-nblocks // row_multiple) * row_multiple
    pad = nblocks * n - nbar
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, flat.dtype)])
    return flat.reshape(nblocks, n), nbar


# ---------------------------------------------------------------------------
# monolithic bit-identity: blocks AND packed wire words
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("row_multiple", [1, 4])
def test_monolithic_blocks_bit_identical_to_golden(row_multiple):
    tree = _tree([3, 130, 64, 7, 1000])
    golden, nbar = _golden_flatten(tree, 64, row_multiple)
    blocks, layout, got_nbar = flatten_to_blocks(tree, 64, row_multiple=row_multiple)
    assert got_nbar == nbar and layout.nbar == nbar
    assert isinstance(layout, GradientLayout) and layout.kind == "monolithic"
    np.testing.assert_array_equal(np.asarray(blocks), np.asarray(golden))


def test_monolithic_wire_words_bit_identical_to_golden():
    """The acceptance-criteria pin: packed uint32 wire words off the default
    layout match the pre-refactor encode exactly, bit for bit."""
    codec = BQCSCodec(CFG)
    tree = _tree([67, 512, 9, 300], seed=3)
    golden_blocks, _ = _golden_flatten(tree, CFG.block_size)
    residual = jnp.zeros_like(golden_blocks)
    gw, ga, gres = codec.compress_blocks_packed(golden_blocks, residual)

    payload, layout, new_res = codec.compress_tree(tree, residual)
    assert layout.kind == "monolithic"
    np.testing.assert_array_equal(np.asarray(payload.codes), np.asarray(gw))
    np.testing.assert_array_equal(np.asarray(payload.alpha), np.asarray(ga))
    np.testing.assert_array_equal(np.asarray(new_res), np.asarray(gres))


def test_streamed_wire_bit_identical_to_one_pass():
    """Segment-streamed encode == one-pass encode of the SAME per-tensor
    layout: words, alphas, and error-feedback residuals all bit-identical
    (every codec stage is per-block; rows never straddle segments)."""
    codec = BQCSCodec(CFG)
    tree = _tree([67, 512, 9, 300], seed=4)
    layout = codec.layout_for(tree, per_tensor=True)
    assert len(layout.segments) == 4
    residual = jnp.asarray(
        np.random.default_rng(7).normal(size=(layout.rows, layout.n)), jnp.float32
    )
    one_pass = codec.compress_blocks_packed(layout.to_blocks(tree), residual)
    payload, _, new_res = codec.compress_tree_streamed(tree, residual, layout)
    np.testing.assert_array_equal(np.asarray(payload.codes), np.asarray(one_pass[0]))
    np.testing.assert_array_equal(np.asarray(payload.alpha), np.asarray(one_pass[1]))
    np.testing.assert_array_equal(np.asarray(new_res), np.asarray(one_pass[2]))


# ---------------------------------------------------------------------------
# roundtrips: eager sweep + hypothesis properties + registry models
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["monolithic", "per_tensor"])
@pytest.mark.parametrize("row_multiple", [1, 3])
@pytest.mark.parametrize(
    "sizes", [[1], [5], [64], [3, 130, 64, 7], [1, 1, 1], [200, 1, 33]]
)
def test_roundtrip_sweep(kind, row_multiple, sizes):
    tree = _tree(sizes, seed=sum(sizes))
    if kind == "monolithic":
        layout = GradientLayout.monolithic(tree, 16, row_multiple=row_multiple)
    else:
        layout = GradientLayout.per_tensor(tree, 16, row_multiple=row_multiple)
    blocks = layout.to_blocks(tree)
    assert blocks.shape == (layout.rows, 16)
    assert layout.rows % row_multiple == 0
    back = layout.tree_from_blocks(blocks)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@hypothesis.given(
    sizes=st.lists(st.integers(1, 400), min_size=1, max_size=8),
    n=st.sampled_from([8, 16, 64, 255]),
    row_multiple=st.integers(1, 4),
    per_tensor=st.booleans(),
    group_scalars=st.sampled_from([0, 32]),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_roundtrip_property(sizes, n, row_multiple, per_tensor, group_scalars):
    """layout.to_blocks -> tree_from_blocks is the identity for any leaf-size
    mix x block size x row_multiple, both layout kinds, with and without
    small-leaf coalescing; and the geometry invariants hold (contiguous
    row ownership, per-segment pad < a row-multiple stripe, exact nbar)."""
    tree = _tree(sizes, seed=sum(sizes) + n)
    if per_tensor:
        layout = GradientLayout.per_tensor(
            tree, n, row_multiple=row_multiple, group_scalars=group_scalars
        )
    else:
        layout = GradientLayout.monolithic(tree, n, row_multiple=row_multiple)
    # geometry invariants
    assert layout.nbar == sum(sizes)
    row = 0
    for seg in layout.segments:
        assert seg.row_start == row and seg.rows % row_multiple == 0
        assert seg.pad == seg.rows * n - seg.size and seg.pad < n * row_multiple
        row += seg.rows
    assert row == layout.rows
    assert sorted(
        lid for seg in layout.segments for lid in seg.leaf_ids
    ) == list(range(len(sizes)))
    # roundtrip
    back = layout.tree_from_blocks(layout.to_blocks(tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


FED_COHORT_ARCHS = ["qwen3-0.6b", "mamba2-1.3b", "qwen3-moe-235b-a22b"]


@pytest.mark.parametrize("arch", FED_COHORT_ARCHS)
def test_registry_model_per_tensor_roundtrip(arch):
    """Per-tensor layouts survive real registry-model param trees (nested
    dicts, mixed 1D/2D/3D leaves), with segment decode matching the full
    inverse leaf-for-leaf."""
    from repro.configs.registry import smoke_config
    from repro.models import model as M

    cfg = smoke_config(arch)
    params = M.init_params(cfg, KEY)
    layout = GradientLayout.per_tensor(params, 255, row_multiple=2)
    blocks = layout.to_blocks(params)
    back = layout.tree_from_blocks(blocks)
    assert jax.tree_util.tree_structure(back) == jax.tree_util.tree_structure(params)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # per-segment partial decode reassembles the same tree
    segs = {
        seg.index: blocks[seg.row_slice] for seg in layout.segments
    }
    back2 = layout.tree_from_segments(segs)
    for a, b in zip(jax.tree_util.tree_leaves(back), jax.tree_util.tree_leaves(back2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batched_blocks_match_unbatched():
    tree = _tree([37, 256, 5], seed=9)
    stacked = jax.tree_util.tree_map(lambda x: jnp.stack([x, 2 * x, -x]), tree)
    _, layout, _ = flatten_to_blocks(tree, 32)
    batched, blayout, _ = flatten_to_blocks_batched(stacked, 32)
    assert blayout.rows == layout.rows
    for k in range(3):
        one = jax.tree_util.tree_map(lambda x: x[k], stacked)
        np.testing.assert_array_equal(
            np.asarray(batched[k]), np.asarray(layout.to_blocks(one))
        )
    # per-segment batched view agrees with the full batched grid
    pt = GradientLayout.per_tensor(tree, 32)
    for seg in pt.segments:
        np.testing.assert_array_equal(
            np.asarray(pt.segment_blocks_batched(stacked, seg.index)),
            np.asarray(pt.to_blocks_batched(stacked)[:, seg.row_slice]),
        )


# ---------------------------------------------------------------------------
# int32 guard (mocked large specs -- no arrays allocated)
# ---------------------------------------------------------------------------


def test_int32_guard_monolithic_raises():
    """A 7B-scale model overflows flat int32 index math under the monolithic
    layout: construction must raise, naming the per-tensor fix."""
    if jax.config.read("jax_enable_x64"):
        pytest.skip("x64 enabled: large spans are legal")
    treedef = jax.tree_util.tree_structure([0, 0])
    shapes = [((INT32_MAX // 2, 3), jnp.float32), ((1024,), jnp.float32)]
    with pytest.raises(ValueError, match="per-tensor"):
        GradientLayout.from_shapes(treedef, shapes, 1024)


def test_int32_guard_per_tensor_passes_where_monolithic_fails():
    """Each tensor of a 7B model is individually inside int32 even though the
    model is not -- the per-tensor layout is the documented fix."""
    if jax.config.read("jax_enable_x64"):
        pytest.skip("x64 enabled: large spans are legal")
    treedef = jax.tree_util.tree_structure([0, 0, 0])
    big = INT32_MAX // 2 + 1  # each leaf ~1.07e9 scalars; total ~3.2e9 > 2^31
    shapes = [((big,), jnp.float32)] * 3
    with pytest.raises(ValueError, match="int32"):
        GradientLayout.from_shapes(treedef, shapes, 1024)
    layout = GradientLayout.from_shapes_per_tensor(treedef, shapes, 1024)
    assert layout.nbar == 3 * big > INT32_MAX  # Python ints: no wrap
    assert all(seg.rows * layout.n <= INT32_MAX for seg in layout.segments)
    # a SINGLE over-int32 tensor still raises, segment-locally
    with pytest.raises(ValueError, match="segment"):
        GradientLayout.from_shapes_per_tensor(
            treedef, [((INT32_MAX + 2,), jnp.float32)] * 3, 1024
        )


# ---------------------------------------------------------------------------
# per-segment sparsity budgets + ownership map + live-memory accounting
# ---------------------------------------------------------------------------


def test_per_segment_sparsity_budgets():
    tree = _tree([640, 64, 320], seed=11)
    ratios = {"w0": 0.5, "w1": None, "w2": 0.25}
    layout = GradientLayout.per_tensor(
        tree, 64, s_ratio=lambda name, shape: next(
            v for k, v in ratios.items() if k in name
        )
    )
    assert [seg.s for seg in layout.segments] == [32, None, 16]
    assert layout.segment_s(default_s=6) == [32, 6, 16]
    with pytest.raises(ValueError, match="s_ratio"):
        GradientLayout.per_tensor(tree, 64, s_ratio=lambda n, s: 1.5)
    # budgets force the streamed path through compress_tree, same wire shape
    codec = BQCSCodec(CFG)
    residual = codec.zero_residual(tree, layout)
    payload, _, new_res = codec.compress_tree(tree, residual, layout)
    assert payload.codes.shape[0] == layout.rows
    assert new_res.shape == (layout.rows, 64)


def test_owner_map_per_tensor_exact():
    tree = _tree([100, 64, 3], seed=13)
    layout = GradientLayout.per_tensor(tree, 64)
    owners = layout.owner_map()
    assert set(owners) == {0, 1, 2}
    for lid, (seg_idx, r0, r1) in owners.items():
        seg = layout.segments[seg_idx]
        assert lid in seg.leaf_ids
        assert seg.row_start <= r0 < r1 <= seg.row_start + seg.rows
    # per-tensor: no two leaves share a row
    spans = sorted((r0, r1) for _, r0, r1 in owners.values())
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0


def test_encoder_live_bytes_bound():
    """Streamed live bytes are bounded by the LARGEST segment; monolithic
    pays the whole grid.  This is the invariant BENCH_encode.json records
    and CI validates."""
    tree = _tree([4096, 64, 512, 8], seed=17)
    mono = GradientLayout.monolithic(tree, 64)
    pt = GradientLayout.per_tensor(tree, 64)
    assert pt.rows >= mono.rows  # per-segment padding never shrinks the grid
    assert pt.encoder_live_bytes(streamed=True) == 3 * pt.max_segment_rows * 64 * 4
    assert pt.encoder_live_bytes(streamed=True) < pt.encoder_live_bytes(streamed=False)
    assert pt.max_segment_rows == max(seg.rows for seg in pt.segments)


def test_as_layout_legacy_tuple():
    tree = _tree([33, 20], seed=19)
    _, layout, nbar = flatten_to_blocks(tree, 16)
    legacy = layout.spec  # the old (treedef, shapes) tuple
    rebuilt = as_layout(legacy, n=16)
    assert rebuilt.nbar == nbar and rebuilt.rows == layout.rows
    blocks = layout.to_blocks(tree)
    for a, b in zip(
        jax.tree_util.tree_leaves(blocks_to_tree(blocks, legacy, nbar)),
        jax.tree_util.tree_leaves(blocks_to_tree(blocks, rebuilt)),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="block size"):
        as_layout(legacy)


# ---------------------------------------------------------------------------
# segment-local EA decode (recon_engine.ea_decode_segments)
# ---------------------------------------------------------------------------


def test_ea_decode_segments_matches_whole_grid_and_emits():
    """Segment-local EA decode matches the whole-grid decode up to float
    reassociation (GAMP is per-(worker, block) row, but XLA picks reduction
    orders per batch shape and GAMP iterates on them), and the emit callback
    fires once per segment with exactly that segment's decoded leaves."""
    from repro.core.recon_engine import ea_decode, ea_decode_segments
    from repro.core.reconstruction import gamp_config_from

    codec = BQCSCodec(CFG)
    tree = _tree([130, 64, 40], seed=23)
    layout = codec.layout_for(tree, per_tensor=True)
    rng = np.random.default_rng(29)
    k = 3
    words, alphas = [], []
    for i in range(k):
        scaled = jax.tree_util.tree_map(lambda x, i=i: (i + 1.0) * x, tree)
        w, a, _ = codec.compress_blocks_packed(
            layout.to_blocks(scaled), jnp.zeros((layout.rows, layout.n))
        )
        words.append(w)
        alphas.append(a)
    words = jnp.stack(words)
    alphas = jnp.stack(alphas)
    rhos = jnp.asarray(rng.dirichlet(np.ones(k)), jnp.float32)
    gamp = gamp_config_from(codec)
    whole = ea_decode(codec, words, alphas, rhos, gamp, packed=True)
    emitted = []
    seg_wise = ea_decode_segments(
        codec, words, alphas, rhos, layout, gamp, packed=True,
        emit=lambda seg, leaves: emitted.append((seg.index, leaves)),
    )
    np.testing.assert_allclose(
        np.asarray(seg_wise), np.asarray(whole), rtol=5e-4, atol=1e-5
    )
    assert [i for i, _ in emitted] == [0, 1, 2]
    # emitted leaves reassemble the segment-decoded tree EXACTLY (the leaves
    # came from those same segment solves)
    tree_hat = layout.tree_from_blocks(seg_wise)
    got = {}
    for _, leaves in emitted:
        got.update(leaves)
    for lid, leaf in enumerate(jax.tree_util.tree_leaves(tree_hat)):
        np.testing.assert_array_equal(np.asarray(got[lid]), np.asarray(leaf))


def test_api_reconstruct_emit_segments():
    from repro.core import api

    codec = api.make_codec(CFG)
    tree = _tree([100, 30], seed=31)
    layout = codec.layout_for(tree, per_tensor=True)
    state = api.init_state(codec, tree, layout)
    payload, spec, state = api.compress(codec, tree, state, layout)
    assert spec is layout
    barrier = api.reconstruct(codec, [payload], [1.0], spec,
                              recon=api.ReconSpec(mode="ea"))
    fired = []
    streamed = api.reconstruct(
        codec, [payload], [1.0], spec, recon=api.ReconSpec(mode="ea"),
        emit=lambda seg, leaves: fired.append(seg.index),
    )
    assert fired == [0, 1]
    for a, b in zip(jax.tree_util.tree_leaves(barrier), jax.tree_util.tree_leaves(streamed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-5)
    with pytest.raises(ValueError, match="segment-local"):
        api.reconstruct(codec, [payload], [1.0], spec,
                        recon=api.ReconSpec(mode="ae"), emit=lambda s, l: None)


# ---------------------------------------------------------------------------
# engine integration: encode_stream + per-tensor layout + grad_accum
# ---------------------------------------------------------------------------


def _toy_engine(**kw):
    from repro.fed.engine import ArrayClientData, CohortConfig, CohortEngine
    from repro.fed.partition import PartitionConfig, partition_indices
    from repro.fed.toy import toy_classification, toy_loss, toy_params

    x, y = toy_classification()
    parts = partition_indices(y, 6, PartitionConfig(kind="iid", min_size=4))
    cohort = CohortConfig(**{"method": "fedqcs-ea", **kw.pop("cohort", {})})
    return CohortEngine(
        toy_params(), jax.grad(toy_loss), ArrayClientData(x, y, parts, batch_size=4),
        fed_cfg=FedQCSConfig(block_size=64, reduction_ratio=2, bits=3, gamp_iters=8),
        cohort=cohort, **kw,
    )


def test_engine_encode_stream_matches_one_pass():
    """encode_stream=True over a per-tensor layout leaves the engine in the
    SAME state as the one-pass encode of that layout: identical residuals
    and params after a round (the wire is bit-identical, so everything
    downstream is too)."""
    one = _toy_engine(cohort={"layout": "per_tensor"})
    two = _toy_engine(cohort={"layout": "per_tensor", "encode_stream": True})
    s1 = one.run_round()
    s2 = two.run_round()
    np.testing.assert_array_equal(np.asarray(one.residuals), np.asarray(two.residuals))
    for a, b in zip(jax.tree_util.tree_leaves(one.params), jax.tree_util.tree_leaves(two.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.isfinite(s1["nmse"]) and np.isfinite(s2["nmse"])
    assert np.isclose(s1["nmse"], s2["nmse"], rtol=1e-5)


def test_engine_constructor_hoists_layout_once():
    eng = _toy_engine(cohort={"layout": "per_tensor"})
    assert eng.spec is eng.layout  # one object, shared by every pass
    assert eng.nb == eng.layout.rows and eng.nbar == eng.layout.nbar
    assert len(eng.layout.segments) > 1


def test_engine_grad_accum_runs():
    eng = _toy_engine(
        cohort={"layout": "per_tensor", "encode_stream": True, "grad_accum": 2}
    )
    stats = eng.run_round()
    assert np.isfinite(stats["nmse"])


def test_engine_validation_errors():
    with pytest.raises(ValueError, match="encode_stream"):
        _toy_engine(cohort={"method": "signsgd", "encode_stream": True})
    with pytest.raises(ValueError, match="qcs-dither"):
        _toy_engine(cohort={"method": "qcs-dither", "layout": "per_tensor"})
    with pytest.raises(ValueError, match="grad_accum"):
        _toy_engine(cohort={"grad_accum": 2})
    with pytest.raises(ValueError, match="loop"):
        _toy_engine(cohort={"encode_stream": True, "impl": "loop"})
    with pytest.raises(ValueError, match="layout"):
        _toy_engine(cohort={"layout": "diagonal"})


def test_engine_explicit_layout_with_budgets():
    """An explicit GradientLayout (with per-segment budgets) threads through
    CohortEngine(layout=...), and the budgets require the streamed encode."""
    from repro.fed.toy import toy_params

    layout = GradientLayout.per_tensor(
        toy_params(), 64, s_ratio=lambda name, shape: 0.5 if "w" in name else None
    )
    with pytest.raises(ValueError, match="encode_stream"):
        _toy_engine(layout=layout)
    eng = _toy_engine(layout=layout, cohort={"encode_stream": True})
    stats = eng.run_round()
    assert np.isfinite(stats["nmse"])


def test_engine_round_event_wire_segments():
    """obs round events itemize the uplink per layout segment (per-tensor
    wire accounting), summing to the round's wire_up_bytes."""
    from repro.obs import InMemoryRecorder

    rec = InMemoryRecorder()
    eng = _toy_engine(cohort={"layout": "per_tensor", "encode_stream": True}, obs=rec)
    eng.run_round()
    [event] = [e for e in rec.events if e.get("kind", e.get("type")) or True]
    segs = event["wire_segments"]
    assert len(segs) == len(eng.layout.segments)
    assert sum(s["rows"] for s in segs) == eng.layout.rows
    np.testing.assert_allclose(
        sum(s["bytes"] for s in segs), event["wire_up_bytes"], rtol=1e-6
    )
