"""Reconstruction-engine + GAMP-numerics regression tests (this PR's
bugfixes and the packed/chunked/sharded PS decode, DESIGN.md #Recon-engine):

  * trunc_channel_moments vs a numerical-integration oracle across in-bin /
    one-sided-tail / far-tail / sentinel-bin regimes (pins the far-tail
    condition fix and the tail-accurate bin mass);
  * EM hyperparameter recovery on synthetic Bernoulli-GM data (pins the
    phi-vs-refreshed-mu fix);
  * packed-domain EA decode bit-equivalence vs the uint8 path, XLA and
    fused-kernel, Q in {1, 2, 3, 4, 8} (incl. the Q=3 slack-bit layout);
  * chunked / early-stop / shard_map decode equivalence and the two-phase
    sweep;
  * dequantize-from-packed and the packed Bussgang aggregate.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bussgang, recon_engine
from repro.core.compression import (
    BQCSCodec,
    FedQCSConfig,
    decode_packed,
    pack_codes,
    unpack_codes,
)
from repro.core.gamp import (
    GampConfig,
    _em_update,
    _input_channel,
    _qem_gamp_xla,
    qem_gamp,
    qem_gamp_packed,
    trunc_channel_moments,
)
from repro.core.reconstruction import (
    estimate_and_aggregate,
    estimate_and_aggregate_packed,
)

jax.config.update("jax_platform_name", "cpu")

_trapz = getattr(np, "trapezoid", None) or np.trapz  # numpy 2.x rename


# ---------------------------------------------------------------------------
# truncated-normal channel moments vs numerical integration
# ---------------------------------------------------------------------------


def _trunc_oracle(phat, nu_p, lo, hi):
    """Posterior mean/var of x ~ N(phat, nu_p) truncated to (lo, hi], by
    dense quadrature in f64 (log-weights, so far-tail bins stay exact)."""
    sd = np.sqrt(nu_p)
    a = max((lo - phat) / sd, -60.0)
    b = min((hi - phat) / sd, 60.0)
    t = np.linspace(a, b, 400001, dtype=np.float64)
    logw = -0.5 * t * t
    w = np.exp(logw - logw.max())
    z = _trapz(w, t)
    mean_t = _trapz(w * t, t) / z
    var_t = _trapz(w * (t - mean_t) ** 2, t) / z
    return phat + sd * mean_t, nu_p * var_t


_TRUNC_CASES = {
    # name: (phat, nu_p, lo, hi)
    # phat INSIDE a wide bin, both edges > clip sds away: the fixed far-tail
    # condition must NOT fire (posterior ~ prior); the old min(|a|,|b|) test
    # collapsed the variance to nu_p/amin^2 here.
    "in_bin_wide": (0.3, 0.04, -5.0, 5.0),
    "in_bin_moderate": (0.1, 1.0, -0.5, 0.7),
    # one-sided bins INSIDE the clip (4-8 sd): the exact branch must survive
    # f32 (tail-accurate erfc bin mass; the naive CDF difference loses all
    # signal here).
    "one_sided_5sd": (0.0, 0.04, 1.0, 1.4),
    "one_sided_8sd": (0.0, 0.01, 0.8, 1.2),
    "one_sided_neg": (0.0, 0.04, -1.4, -1.0),
    # bins entirely beyond the clip: asymptotic fallback.
    "far_upper": (0.0, 0.01, 1.2, 1.5),
    "far_lower": (2.0, 0.01, -0.5, 0.2),
    # sentinel (outermost Lloyd-Max) bins, edge at +-4*clip.
    "sentinel_lo": (-0.8, 0.09, -36.0, -0.9817),
    "sentinel_lo_far": (1.5, 0.0025, -36.0, -0.9817),
}


@pytest.mark.parametrize("case", sorted(_TRUNC_CASES))
def test_trunc_channel_moments_vs_integration_oracle(case):
    phat, nu_p, lo, hi = _TRUNC_CASES[case]
    xpost, nu_x = trunc_channel_moments(
        jnp.float32(phat), jnp.float32(nu_p), jnp.float32(lo), jnp.float32(hi)
    )
    x_ref, nu_ref = _trunc_oracle(phat, nu_p, lo, hi)
    sd = np.sqrt(nu_p)
    # mean within 2e-3 sd everywhere (the far fallback's asymptotic error is
    # O(1/a^2) of sd; exact-branch cases sit at f32 round-off)
    assert abs(float(xpost) - x_ref) / sd < 2e-3, (float(xpost), x_ref)
    # variance within 10% (far fallback) / much tighter in-bin
    assert 0.9 <= float(nu_x) / nu_ref <= 1.1, (float(nu_x), nu_ref)


def test_in_bin_wide_posterior_not_collapsed():
    """Regression for the far-tail condition: phat inside a wide bin must
    keep ~the prior variance.  The pre-fix fallback returned nu_p/amin^2 --
    a 600x collapse for this geometry."""
    phat, nu_p, lo, hi = 0.3, 0.04, -5.0, 5.0  # |a|,|b| ~ 25 sds, both sides
    _, nu_x = trunc_channel_moments(
        jnp.float32(phat), jnp.float32(nu_p), jnp.float32(lo), jnp.float32(hi)
    )
    assert float(nu_x) > 0.9 * nu_p, float(nu_x)


def test_trunc_moments_batched_mixed_regimes():
    """The per-entry where() routing holds element-wise on a mixed batch."""
    names = sorted(_TRUNC_CASES)
    p, v, lo, hi = (np.array([_TRUNC_CASES[n][i] for n in names], np.float32)
                    for i in range(4))
    xpost, nu_x = trunc_channel_moments(jnp.asarray(p), jnp.asarray(v),
                                        jnp.asarray(lo), jnp.asarray(hi))
    for i, name in enumerate(names):
        x_ref, nu_ref = _trunc_oracle(*_TRUNC_CASES[name])
        sd = np.sqrt(v[i])
        assert abs(float(xpost[i]) - x_ref) / sd < 2e-3, name
        assert 0.9 <= float(nu_x[i]) / nu_ref <= 1.1, name


# ---------------------------------------------------------------------------
# EM hyperparameter refresh (phi against the refreshed mu)
# ---------------------------------------------------------------------------


def _bg_theta(nb, L, lam0, lam, mu, phi):
    return (
        jnp.full((nb,), lam0, jnp.float32),
        jnp.full((nb, L), lam, jnp.float32),
        jnp.full((nb, L), mu, jnp.float32),
        jnp.full((nb, L), phi, jnp.float32),
    )


def test_em_phi_single_step_uses_refreshed_mu():
    """One EM step from a deliberately-off mean init: the M-step variance is
    the posterior scatter around the SAME-STEP refreshed mu.  Scattering
    around the stale mean adds exactly (mu_new - mu_old)^2 (the cross-term
    vanishes because mu_new IS the posterior-weighted mean) -- the upward
    bias the fix removes.  Pin both the identity and that the fixed update
    is the smaller one."""
    rng = np.random.default_rng(1)
    nb, n = 2, 8192
    mu_t, phi_t = 1.0, 0.04
    nz = rng.random((nb, n)) > 0.5
    g = np.where(nz, rng.normal(mu_t, np.sqrt(phi_t), (nb, n)), 0.0)
    nu_r = 0.01
    rhat = jnp.asarray(g + rng.normal(0, np.sqrt(nu_r), (nb, n)), jnp.float32)
    mu_old = 0.3
    theta = _bg_theta(nb, 1, 0.5, 0.5, mu_old, 0.5)  # mu off by 0.7
    _, _, lp0, lp, mp, pp = _input_channel(rhat, jnp.full((nb, n), nu_r), theta)
    _, _, mu1, phi1 = _em_update(theta, lp0, lp, mp, pp)
    # what the stale-mu update would have returned, from the same posterior
    lam_sum = jnp.maximum(jnp.sum(lp, axis=1), 1e-12)
    phi_stale = jnp.sum(lp * (jnp.square(mu_old - mp) + pp), axis=1) / lam_sum
    bias = np.square(np.asarray(mu1) - mu_old)
    np.testing.assert_allclose(
        np.asarray(phi_stale), np.asarray(phi1) + bias, rtol=1e-4
    )
    assert bias.min() > 0.2  # the init is genuinely off -> the bias is large
    assert float(phi1.max()) < float(phi_stale.min())


def test_em_recovers_bg_hyperparameters():
    """Full EM iteration on synthetic Bernoulli-GM data converges to the
    true (lam0, mu, phi) -- the satellite's recovery contract."""
    rng = np.random.default_rng(0)
    nb, n = 2, 4096
    lam0_t, mu_t, phi_t = 0.5, 1.0, 0.04
    nz = rng.random((nb, n)) > lam0_t
    g = np.where(nz, rng.normal(mu_t, np.sqrt(phi_t), (nb, n)), 0.0)
    nu_r = 0.01
    rhat = jnp.asarray(g + rng.normal(0, np.sqrt(nu_r), (nb, n)), jnp.float32)
    nu_r_arr = jnp.full((nb, n), nu_r, jnp.float32)
    theta = _bg_theta(nb, 1, 0.5, 0.5, 0.3, 0.5)
    for _ in range(200):
        _, _, lp0, lp, mp, pp = _input_channel(rhat, nu_r_arr, theta)
        theta = _em_update(theta, lp0, lp, mp, pp)
    lam0, _, mu, phi = (np.asarray(t) for t in theta)
    np.testing.assert_allclose(lam0, lam0_t, atol=0.05)
    np.testing.assert_allclose(mu, mu_t, rtol=0.05)
    np.testing.assert_allclose(phi, phi_t, rtol=0.25)


# ---------------------------------------------------------------------------
# packed-domain decode
# ---------------------------------------------------------------------------


def _payload(q, k=3, nb=2, n=256, seed=0):
    rng = np.random.default_rng(seed)
    cfg = FedQCSConfig(block_size=n, reduction_ratio=4, bits=q, s_ratio=0.08)
    codec = BQCSCodec(cfg)
    g = np.zeros((k, nb, n), np.float32)
    for i in range(k):
        for j in range(nb):
            idx = rng.choice(n, cfg.s, replace=False)
            g[i, j, idx] = rng.normal(0, 0.1, cfg.s)
    codes, alphas, _ = jax.vmap(codec.compress_blocks)(
        jnp.asarray(g), jnp.zeros((k, nb, n), jnp.float32)
    )
    words = jax.vmap(lambda c: pack_codes(c, q))(codes)
    return codec, codes, words, alphas, jnp.full((k,), 1.0 / k)


@pytest.mark.parametrize("q", [1, 2, 3, 4, 8])
def test_packed_ea_bit_identical_to_uint8_path(q):
    """qem_gamp_packed == qem_gamp on the unpacked view, bit-for-bit, on the
    XLA path AND the fused-kernel path -- incl. Q=3, where each uint32 word
    carries 2 slack bits (10 codes/word)."""
    codec, codes, words, alphas, _ = _payload(q)
    cfg = codec.cfg
    k, nb, m = codes.shape
    flat_c = codes.reshape(k * nb, m)
    flat_w = words.reshape(k * nb, -1)
    flat_a = alphas.reshape(k * nb)
    gamp = GampConfig(iters=8, variance_mode="scalar")
    for use_pallas in (False, True):
        x_u = qem_gamp(flat_c, flat_a, codec.a, codec.quantizer, gamp,
                       use_pallas=use_pallas)
        x_p = qem_gamp_packed(flat_w, flat_a, codec.a, codec.quantizer, gamp,
                              cfg.m, use_pallas=use_pallas)
        np.testing.assert_array_equal(np.asarray(x_u), np.asarray(x_p))


@pytest.mark.parametrize("q", [1, 2, 3, 4, 8])
def test_dequantize_packed_matches_unpacked(q):
    """decode_packed == decode(unpack_codes) -- the psum_dequant wire's
    no-index-view path -- and the packed Bussgang aggregate matches the
    code-domain one (AE path of the gather_codes wire)."""
    codec, codes, words, alphas, rhos = _payload(q)
    cfg = codec.cfg
    deq_p = decode_packed(words, q, cfg.m, codec.quantizer.jnp_levels())
    np.testing.assert_array_equal(
        np.asarray(deq_p), np.asarray(codec.dequantize(codes))
    )
    # 2-D convenience method on the codec
    np.testing.assert_array_equal(
        np.asarray(codec.dequantize_packed(words[0])),
        np.asarray(codec.dequantize(codes[0])),
    )
    y_p = bussgang.aggregate_packed(words, alphas, rhos, codec.quantizer, cfg.m)
    y_u = bussgang.aggregate_codes(codes, alphas, rhos, codec.quantizer)
    np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_u))


def test_unpack_codes_leading_batch_dims():
    rng = np.random.default_rng(3)
    codes = jnp.asarray(rng.integers(0, 8, (4, 5, 30)), jnp.uint8)
    words = jax.vmap(lambda c: pack_codes(c, 3))(codes)  # (4, 5, 3)
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(words, 3, 30)), np.asarray(codes)
    )


# ---------------------------------------------------------------------------
# chunked / sharded / two-phase engine
# ---------------------------------------------------------------------------


def _nmse(a, b):
    return float(jnp.sum((a - b) ** 2) / jnp.maximum(jnp.sum(b**2), 1e-30))


def test_chunked_decode_matches_monolithic():
    """Chunk streaming is output-equivalent to the monolithic batch: packed
    vs unpacked at equal chunking is BIT-identical; chunked vs monolithic is
    NMSE-equivalent (batch-shape GEMM lowerings differ at ulp level, the
    same caveat as the fed engine's loop oracle)."""
    codec, codes, words, alphas, rhos = _payload(3, k=7, nb=3)
    gamp = GampConfig(iters=10, variance_mode="scalar")
    mono = estimate_and_aggregate(codec, codes, alphas, rhos, gamp, chunk=0)
    for chunk in (4, 8, 64):  # padding, even split, chunk > rows
        ch_u = estimate_and_aggregate(codec, codes, alphas, rhos, gamp, chunk=chunk)
        ch_p = estimate_and_aggregate_packed(
            codec, words, alphas, rhos, gamp, chunk=chunk
        )
        np.testing.assert_array_equal(np.asarray(ch_p), np.asarray(ch_u))
        assert _nmse(ch_u, mono) <= 1e-4, chunk


def test_recon_chunk_config_knob():
    """FedQCSConfig.recon_chunk is the default chunking of both EA entry
    points (what the collectives/engine wiring relies on)."""
    codec, codes, words, alphas, rhos = _payload(2, k=5, nb=2)
    gamp = GampConfig(iters=8, variance_mode="scalar")
    chunked_cfg = dataclasses.replace(codec.cfg, recon_chunk=4)
    codec_c = BQCSCodec(chunked_cfg)
    out_cfg = estimate_and_aggregate_packed(codec_c, words, alphas, rhos, gamp)
    out_exp = estimate_and_aggregate_packed(codec, words, alphas, rhos, gamp, chunk=4)
    np.testing.assert_array_equal(np.asarray(out_cfg), np.asarray(out_exp))


def test_early_stop_bitwise_matches_static_trip():
    """GampConfig.early_stop only removes post-freeze no-op iterations: the
    outputs are bit-identical to the static scan."""
    codec, codes, words, alphas, rhos = _payload(2, k=6, nb=2)
    gamp = GampConfig(iters=25, variance_mode="scalar", tol=1e-3)
    es = dataclasses.replace(gamp, early_stop=True)
    out_s = estimate_and_aggregate_packed(codec, words, alphas, rhos, gamp, chunk=4)
    out_e = estimate_and_aggregate_packed(codec, words, alphas, rhos, es, chunk=4)
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(out_e))


def test_sharded_decode_matches_unsharded():
    """shard_map chunk sharding over a ('recon',) mesh is output-equivalent
    to the single-device scan (multi-device thanks to conftest's 8 forced
    host devices)."""
    from jax.sharding import Mesh

    codec, codes, words, alphas, rhos = _payload(2, k=8, nb=2)
    gamp = GampConfig(iters=8, variance_mode="scalar")
    ndev = min(4, len(jax.devices()))
    mesh = Mesh(np.array(jax.devices()[:ndev]), ("recon",))
    out_m = recon_engine.ea_decode(
        codec, words, alphas, rhos, gamp, packed=True, chunk=4, mesh=mesh
    )
    out_1 = recon_engine.ea_decode(
        codec, words, alphas, rhos, gamp, packed=True, chunk=4
    )
    assert _nmse(out_m, out_1) <= 1e-6


def test_two_phase_refines_unconverged_blocks():
    """The two-phase sweep re-solves exactly the blocks whose early-freeze
    flag is still false after the scalar pass, with exact-variance GAMP, and
    leaves converged blocks' scalar estimates untouched."""
    codec, codes, words, alphas, rhos = _payload(3, k=6, nb=2, seed=5)
    cfg = codec.cfg
    k, nb, m = codes.shape
    # few iterations at a loose-ish tol: some blocks freeze, some don't
    gamp = GampConfig(iters=6, variance_mode="scalar", tol=1e-2)
    out, stats = recon_engine.ea_decode_two_phase(
        codec, words, alphas, rhos, gamp, packed=True, chunk=4
    )
    assert out.shape == (nb, cfg.block_size)
    assert 0 <= stats["phase2_rows"] <= stats["rows"] == k * nb
    assert np.isfinite(np.asarray(out)).all()
    # reproduce the expected composition: scalar pass + exact re-solve
    flat_c = codes.reshape(k * nb, m)
    flat_a = alphas.reshape(k * nb)
    ghat, conv, _ = _qem_gamp_xla(flat_c, flat_a, codec.a, codec.quantizer, gamp)
    surv = np.flatnonzero(~np.asarray(conv))
    assert len(surv) == stats["phase2_rows"]
    if len(surv):
        exact = dataclasses.replace(gamp, variance_mode="exact", early_stop=False)
        refined, _, _ = _qem_gamp_xla(
            flat_c[jnp.asarray(surv)], flat_a[jnp.asarray(surv)],
            codec.a, codec.quantizer, exact,
        )
        ghat = ghat.at[jnp.asarray(surv)].set(refined)
    expect = jnp.einsum("k,kbn->bn", rhos, ghat.reshape(k, nb, -1))
    assert _nmse(out, expect) <= 1e-6


def test_dead_rows_converged_immediately():
    """alpha == 0 rows (dead blocks / chunk padding) come back converged and
    exactly zero, so they never gate a chunk's early-stop exit."""
    codec, codes, words, alphas, rhos = _payload(2, k=2, nb=2)
    k, nb, m = codes.shape
    flat_c = codes.reshape(k * nb, m)
    flat_a = alphas.reshape(k * nb).at[1].set(0.0)
    gamp = GampConfig(iters=5, variance_mode="scalar")
    ghat, conv, _ = _qem_gamp_xla(flat_c, flat_a, codec.a, codec.quantizer, gamp)
    assert bool(conv[1])
    assert not np.asarray(ghat[1]).any()
