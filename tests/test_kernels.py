"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode on CPU),
with shape/dtype sweeps + hypothesis property tests."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sensing
from repro.core.quantizer import design_lloyd_max
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# bqcs_encode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb,n,r,q", [
    (16, 256, 4, 3),
    (7, 512, 2, 1),
    (130, 1024, 8, 4),
    (1, 128, 4, 2),
    (33, 384, 3, 6),
])
def test_bqcs_encode_matches_ref(nb, n, r, q):
    rng = np.random.default_rng(nb * n + q)
    m = n // r
    blocks = jnp.asarray(rng.normal(0, 0.1, (nb, n)), jnp.float32)
    blocks = blocks.at[0].set(0.0)  # dead block path
    a = sensing.sensing_matrix(jax.random.PRNGKey(1), m, n)
    quant = design_lloyd_max(q)
    ck, ak = ops.bqcs_encode(blocks, a, quant)
    cr, ar = ref.bqcs_encode_ref(blocks, a.T, quant.jnp_thresholds())
    assert (ck.astype(jnp.int32) == cr).all()
    np.testing.assert_allclose(np.asarray(ak), np.asarray(ar), rtol=1e-6)
    assert int(ck.max()) < 2**q


def test_bqcs_encode_bf16_input():
    rng = np.random.default_rng(0)
    blocks = jnp.asarray(rng.normal(0, 1, (8, 256)), jnp.bfloat16)
    a = sensing.sensing_matrix(jax.random.PRNGKey(1), 64, 256)
    quant = design_lloyd_max(2)
    ck, ak = ops.bqcs_encode(blocks, a, quant)  # wrapper upcasts to f32
    cr, ar = ref.bqcs_encode_ref(blocks.astype(jnp.float32), a.T, quant.jnp_thresholds())
    assert (ck.astype(jnp.int32) == cr).all()


# ---------------------------------------------------------------------------
# block_topk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb,n,s", [(16, 256, 20), (5, 128, 1), (40, 512, 64), (3, 1024, 1000)])
def test_block_topk_matches_ref(nb, n, s):
    rng = np.random.default_rng(nb + n + s)
    blocks = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    sk, rk = ops.block_sparsify(blocks, s)
    sr, rr = ref.block_topk_ref(blocks, s)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))


@hypothesis.given(
    nb=st.integers(1, 12),
    n=st.sampled_from([64, 128, 256]),
    s_frac=st.floats(0.01, 0.9),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_block_topk_properties(nb, n, s_frac, seed):
    """Invariants: sparse+residual == input exactly; kept count in [1, s+ties];
    kept entries dominate dropped entries in magnitude."""
    s = max(1, int(s_frac * n))
    rng = np.random.default_rng(seed)
    blocks = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    sparse, resid = ops.block_sparsify(blocks, s)
    np.testing.assert_array_equal(np.asarray(sparse + resid), np.asarray(blocks))
    sp, rs = np.asarray(sparse), np.asarray(resid)
    for i in range(nb):
        kept = np.abs(sp[i][sp[i] != 0])
        dropped = np.abs(rs[i][rs[i] != 0])
        assert 1 <= kept.size
        if dropped.size and kept.size:
            assert kept.min() >= dropped.max() - 1e-6


# ---------------------------------------------------------------------------
# gamp_step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb,n,r,L", [(8, 256, 4, 3), (4, 128, 2, 2), (32, 512, 4, 4)])
def test_gamp_step_matches_ref(nb, n, r, L):
    rng = np.random.default_rng(nb * n)
    m = n // r
    ghat = jnp.asarray(rng.normal(0, 0.1, (nb, n)), jnp.float32)
    nug = jnp.asarray(rng.uniform(0.01, 0.1, (nb, n)), jnp.float32)
    shat = jnp.asarray(rng.normal(0, 0.1, (nb, m)), jnp.float32)
    theta = jnp.concatenate(
        [
            jnp.full((nb, 1), 0.9),
            jnp.full((nb, L), 0.1 / L),
            jnp.asarray(rng.normal(0, 0.1, (nb, L)), jnp.float32),
            jnp.full((nb, L), 0.01),
        ],
        axis=1,
    )
    y = jnp.asarray(rng.normal(0, 1, (nb, m)), jnp.float32)
    nud = jnp.full((nb, 1), 0.05, jnp.float32)
    a = sensing.sensing_matrix(jax.random.PRNGKey(2), m, n)
    outk = ops.gamp_step(ghat, nug, shat, theta, y, nud, a, n_components=L)
    outr = ref.gamp_step_ref(ghat, nug, shat, theta, y, nud, a, n_components=L)
    for k, rr in zip(outk, outr):
        np.testing.assert_allclose(np.asarray(k), np.asarray(rr), rtol=2e-4, atol=1e-6)


def test_gamp_ae_run_matches_core_em_gamp():
    """Full fixed-trip kernel scan == core scalar-variance em_gamp."""
    from repro.core import bussgang
    from repro.core.compression import BQCSCodec, FedQCSConfig
    from repro.core.gamp import GampConfig, em_gamp

    rng = np.random.default_rng(5)
    cfg = FedQCSConfig(block_size=256, reduction_ratio=3, bits=3, s_ratio=0.08)
    codec = BQCSCodec(cfg)
    g = jnp.asarray(rng.standard_t(4, (16, 256)) * 0.01, jnp.float32)
    c, a, _ = codec.compress_blocks(g, jnp.zeros_like(g))
    rhos = jnp.ones((1,))
    y = bussgang.aggregate_codes(c[None], a[None], rhos, codec.quantizer)
    nu = bussgang.effective_noise_var(a[None], rhos, codec.quantizer)
    en = bussgang.signal_energy(a[None], rhos, cfg.m, 256)
    gh_k = ops.gamp_ae_run(y, nu, codec.a, en, iters=20)
    gh_c = em_gamp(
        y, nu, codec.a,
        GampConfig(iters=20, variance_mode="scalar", tol=0.0),
        init_var=en,
    )
    np.testing.assert_allclose(np.asarray(gh_k), np.asarray(gh_c), rtol=1e-3, atol=1e-6)
