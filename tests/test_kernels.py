"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode on CPU),
with shape/dtype sweeps + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dependency (pyproject [dev] extra)
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:  # property tests skip via importorskip
    from hypothesis_stub import hypothesis, st

from repro.core import sensing
from repro.core.quantizer import design_lloyd_max
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# bqcs_encode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb,n,r,q", [
    (16, 256, 4, 3),
    (7, 512, 2, 1),
    (130, 1024, 8, 4),
    (1, 128, 4, 2),
    (33, 384, 3, 6),
])
def test_bqcs_encode_matches_ref(nb, n, r, q):
    rng = np.random.default_rng(nb * n + q)
    m = n // r
    blocks = jnp.asarray(rng.normal(0, 0.1, (nb, n)), jnp.float32)
    blocks = blocks.at[0].set(0.0)  # dead block path
    a = sensing.sensing_matrix(jax.random.PRNGKey(1), m, n)
    quant = design_lloyd_max(q)
    ck, ak = ops.bqcs_encode(blocks, a, quant)
    cr, ar = ref.bqcs_encode_ref(blocks, a.T, quant.jnp_thresholds())
    assert (ck.astype(jnp.int32) == cr).all()
    np.testing.assert_allclose(np.asarray(ak), np.asarray(ar), rtol=1e-6)
    assert int(ck.max()) < 2**q


def test_bqcs_encode_bf16_input():
    rng = np.random.default_rng(0)
    blocks = jnp.asarray(rng.normal(0, 1, (8, 256)), jnp.bfloat16)
    a = sensing.sensing_matrix(jax.random.PRNGKey(1), 64, 256)
    quant = design_lloyd_max(2)
    ck, ak = ops.bqcs_encode(blocks, a, quant)  # wrapper upcasts to f32
    cr, ar = ref.bqcs_encode_ref(blocks.astype(jnp.float32), a.T, quant.jnp_thresholds())
    assert (ck.astype(jnp.int32) == cr).all()


# ---------------------------------------------------------------------------
# bqcs_encode_fused (single-pass encoder: EF add -> top-S -> encode -> pack)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb,n,m,q", [
    (16, 256, 64, 2),    # even everything
    (7, 256, 97, 4),     # row padding + M % (32//Q) != 0 (97 % 8)
    (130, 512, 100, 3),  # row padding over the tile + Q=3 (10 codes/word)
    (5, 128, 32, 1),     # Q=1: 32 lane groups into one word column
    (9, 256, 31, 8),     # Q=8 + M % 4 != 0
])
def test_bqcs_encode_fused_matches_oracle(nb, n, m, q):
    """Fused kernel == composed oracle (top-S -> encode -> pack): words and
    alpha bit-exact, residual <= 1e-6; includes the all-zero-block row and
    nonzero error-feedback input."""
    rng = np.random.default_rng(nb * n + q)
    blocks = jnp.asarray(rng.normal(0, 0.1, (nb, n)), jnp.float32)
    resid_in = jnp.asarray(rng.normal(0, 0.01, (nb, n)), jnp.float32)
    blocks = blocks.at[0].set(0.0)
    resid_in = resid_in.at[0].set(0.0)  # all-zero carry -> dead block path
    a = sensing.sensing_matrix(jax.random.PRNGKey(1), m, n)
    quant = design_lloyd_max(q)
    s = max(1, n // 10)
    wk, ak, rk = ops.bqcs_encode_fused(blocks, resid_in, a, quant, s)
    wr, ar, rr = ref.bqcs_encode_fused_ref(
        blocks, resid_in, a.T, quant.jnp_thresholds(), s, q
    )
    assert wk.dtype == jnp.uint32
    assert wk.shape == (nb, -(-m // (32 // q)))
    np.testing.assert_array_equal(np.asarray(wk), np.asarray(wr))
    np.testing.assert_array_equal(np.asarray(ak), np.asarray(ar))
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), atol=1e-6)
    assert float(ak[0]) == 0.0  # dead block signals alpha = 0


def test_fused_matches_unfused_compress_blocks():
    """codec.compress_blocks(use_kernels=True) (fused single pass) ==
    use_kernels=False (stage-by-stage XLA): codes bit-exact, alpha to fp
    round-off, residual <= 1e-6 -- and the packed/unpacked views agree."""
    import dataclasses

    from repro.core.compression import BQCSCodec, FedQCSConfig, unpack_codes

    rng = np.random.default_rng(3)
    cfg = FedQCSConfig(
        block_size=256, reduction_ratio=4, bits=3, s_ratio=0.1, use_kernels=True
    )
    codec_k = BQCSCodec(cfg)
    codec_x = BQCSCodec(dataclasses.replace(cfg, use_kernels=False))
    g = jnp.asarray(rng.normal(0, 0.1, (20, 256)), jnp.float32)
    r = jnp.asarray(rng.normal(0, 0.01, (20, 256)), jnp.float32)
    words, a_k, res_k = codec_k.compress_blocks_packed(g, r)
    c_k, a_k2, _ = codec_k.compress_blocks(g, r)
    c_x, a_x, res_x = codec_x.compress_blocks(g, r)
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(words, cfg.bits, cfg.m)), np.asarray(c_k)
    )
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_k2))
    np.testing.assert_array_equal(
        np.asarray(c_k).astype(np.int32), np.asarray(c_x).astype(np.int32)
    )
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_x), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(res_k), np.asarray(res_x), atol=1e-6)


def test_fused_error_feedback_identity():
    """The in-kernel error-feedback update is exact: every entry of the new
    residual is either 0 (kept by top-S) or bit-equal to the carry entry
    (dropped) -- no mass is invented or lost -- and kept magnitudes dominate
    dropped ones (eq. 7 semantics)."""
    rng = np.random.default_rng(9)
    nb, n, m, s = 12, 256, 64, 25
    blocks = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    resid_in = jnp.asarray(rng.normal(0, 0.1, (nb, n)), jnp.float32)
    a = sensing.sensing_matrix(jax.random.PRNGKey(4), m, n)
    _, _, resid_out = ops.bqcs_encode_fused(blocks, resid_in, a, design_lloyd_max(2), s)
    carry = np.asarray(blocks + resid_in)
    resid_out = np.asarray(resid_out)
    dropped_mask = resid_out != 0
    np.testing.assert_array_equal(resid_out[dropped_mask], carry[dropped_mask])
    sparse = np.where(dropped_mask, 0.0, carry)
    for i in range(nb):
        kept = np.abs(sparse[i][sparse[i] != 0])
        dropped = np.abs(resid_out[i][dropped_mask[i]])
        assert kept.size >= 1
        if dropped.size:
            assert kept.min() >= dropped.max() - 1e-6


# ---------------------------------------------------------------------------
# block_topk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb,n,s", [(16, 256, 20), (5, 128, 1), (40, 512, 64), (3, 1024, 1000)])
def test_block_topk_matches_ref(nb, n, s):
    rng = np.random.default_rng(nb + n + s)
    blocks = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    sk, rk = ops.block_sparsify(blocks, s)
    sr, rr = ref.block_topk_ref(blocks, s)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))


@hypothesis.given(
    nb=st.integers(1, 12),
    n=st.sampled_from([64, 128, 256]),
    s_frac=st.floats(0.01, 0.9),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_block_topk_properties(nb, n, s_frac, seed):
    """Invariants: sparse+residual == input exactly; kept count in [1, s+ties];
    kept entries dominate dropped entries in magnitude."""
    s = max(1, int(s_frac * n))
    rng = np.random.default_rng(seed)
    blocks = jnp.asarray(rng.normal(0, 1, (nb, n)), jnp.float32)
    sparse, resid = ops.block_sparsify(blocks, s)
    np.testing.assert_array_equal(np.asarray(sparse + resid), np.asarray(blocks))
    sp, rs = np.asarray(sparse), np.asarray(resid)
    for i in range(nb):
        kept = np.abs(sp[i][sp[i] != 0])
        dropped = np.abs(rs[i][rs[i] != 0])
        assert 1 <= kept.size
        if dropped.size and kept.size:
            assert kept.min() >= dropped.max() - 1e-6


# ---------------------------------------------------------------------------
# gamp_step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb,n,r,L", [(8, 256, 4, 3), (4, 128, 2, 2), (32, 512, 4, 4)])
def test_gamp_step_matches_ref(nb, n, r, L):
    rng = np.random.default_rng(nb * n)
    m = n // r
    ghat = jnp.asarray(rng.normal(0, 0.1, (nb, n)), jnp.float32)
    nug = jnp.asarray(rng.uniform(0.01, 0.1, (nb, n)), jnp.float32)
    shat = jnp.asarray(rng.normal(0, 0.1, (nb, m)), jnp.float32)
    theta = jnp.concatenate(
        [
            jnp.full((nb, 1), 0.9),
            jnp.full((nb, L), 0.1 / L),
            jnp.asarray(rng.normal(0, 0.1, (nb, L)), jnp.float32),
            jnp.full((nb, L), 0.01),
        ],
        axis=1,
    )
    y = jnp.asarray(rng.normal(0, 1, (nb, m)), jnp.float32)
    nud = jnp.full((nb, 1), 0.05, jnp.float32)
    a = sensing.sensing_matrix(jax.random.PRNGKey(2), m, n)
    outk = ops.gamp_step(ghat, nug, shat, theta, y, nud, a, n_components=L)
    outr = ref.gamp_step_ref(ghat, nug, shat, theta, y, nud, a, n_components=L)
    for k, rr in zip(outk, outr):
        np.testing.assert_allclose(np.asarray(k), np.asarray(rr), rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("nb,n,r,L,q", [(8, 256, 4, 3, 3), (4, 128, 2, 2, 2), (32, 512, 4, 4, 4)])
def test_qgamp_step_matches_ref(nb, n, r, L, q):
    rng = np.random.default_rng(nb * n + q)
    m = n // r
    ghat = jnp.asarray(rng.normal(0, 0.1, (nb, n)), jnp.float32)
    nug = jnp.asarray(rng.uniform(0.01, 0.1, (nb, n)), jnp.float32)
    shat = jnp.asarray(rng.normal(0, 0.1, (nb, m)), jnp.float32)
    theta = jnp.concatenate(
        [
            jnp.full((nb, 1), 0.9),
            jnp.full((nb, L), 0.1 / L),
            jnp.asarray(rng.normal(0, 0.1, (nb, L)), jnp.float32),
            jnp.full((nb, L), 0.01),
        ],
        axis=1,
    )
    # Codes must be *consistent* with the state (drawn from the channel
    # model x ~ N(phat, nu_p)): for bins many sigma away from phat the
    # truncated-normal ratios divide by z ~ 1e-12 and amplify ulp-level
    # tiling/fusion differences arbitrarily -- that regime is covered by the
    # far-tail fallback and the full-run NMSE test below, not ulp-matching.
    alpha = jnp.asarray(rng.uniform(0.8, 1.25, (nb, 1)), jnp.float32)
    quant = design_lloyd_max(q)
    a_mat = sensing.sensing_matrix(jax.random.PRNGKey(2), m, n)
    x_obs = alpha * (ghat @ a_mat.T) + jnp.asarray(
        rng.normal(0, 0.1, (nb, m)), jnp.float32
    )
    from repro.core.quantizer import encode

    codes = encode(x_obs, quant).astype(jnp.int32)
    from repro.core.gamp import tau_tables

    lo_tau, hi_tau = tau_tables(quant.jnp_thresholds())
    outk = ops.qgamp_step(ghat, nug, shat, theta, codes, alpha, lo_tau, hi_tau,
                          a_mat, n_components=L)
    outr = ref.qgamp_step_ref(ghat, nug, shat, theta, codes, alpha, lo_tau, hi_tau,
                              a_mat, n_components=L)
    for k, rr in zip(outk, outr):
        np.testing.assert_allclose(np.asarray(k), np.asarray(rr), rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("nb", [5, 16, 40])  # 5 and 40 exercise row padding
def test_qgamp_ea_run_matches_core_qem_gamp(nb):
    """Full fixed-trip EA kernel scan == core scalar-variance qem_gamp within
    1e-4 NMSE, incl. the row-padding edge case (nb not a multiple of TB) and
    the dead-block (alpha == 0) path."""
    from repro.core.compression import BQCSCodec, FedQCSConfig
    from repro.core.gamp import GampConfig, qem_gamp

    rng = np.random.default_rng(7)
    n, s = 256, 20
    g = np.zeros((nb, n), np.float32)
    for i in range(nb):
        idx = rng.choice(n, s, replace=False)
        g[i, idx] = rng.normal(0, 0.1, s)
    g = jnp.asarray(g)
    cfg = FedQCSConfig(block_size=n, reduction_ratio=3, bits=3, s_ratio=s / n)
    codec = BQCSCodec(cfg)
    codes, alpha, _ = codec.compress_blocks(g, jnp.zeros_like(g))
    alpha = alpha.at[2].set(0.0)  # dead block must come out exactly zero
    gh_k = ops.qgamp_ea_run(codes, alpha, codec.a, codec.quantizer.jnp_thresholds(),
                            iters=20)
    gh_c = qem_gamp(codes, alpha, codec.a, codec.quantizer,
                    GampConfig(iters=20, variance_mode="scalar", tol=0.0))
    nmse = float(jnp.sum((gh_k - gh_c) ** 2) / jnp.maximum(jnp.sum(gh_c**2), 1e-30))
    assert nmse <= 1e-4, nmse
    assert not np.asarray(gh_k[2]).any()
    # and the kernel path actually reconstructs (not just matches): NMSE vs g
    live = np.array([i for i in range(nb) if i != 2])
    gh_l, g_l = np.asarray(gh_k)[live], np.asarray(g)[live]
    per_block = np.sum((gh_l - g_l) ** 2, axis=1) / np.sum(g_l**2, axis=1)
    assert np.median(per_block) < 0.1, per_block


def test_estimate_and_aggregate_use_pallas_matches_xla():
    """reconstruct(mode='ea') acceptance: kernel vs pure-XLA path <= 1e-4 NMSE."""
    from repro.core.compression import BQCSCodec, FedQCSConfig
    from repro.core.gamp import GampConfig
    from repro.core.reconstruction import estimate_and_aggregate

    rng = np.random.default_rng(11)
    cfg = FedQCSConfig(block_size=256, reduction_ratio=3, bits=3, s_ratio=0.08)
    codec = BQCSCodec(cfg)
    k, nb = 3, 4
    codes, alphas = [], []
    for _ in range(k):
        b = np.zeros((nb, 256), np.float32)
        for i in range(nb):
            idx = rng.choice(256, cfg.s, replace=False)
            b[i, idx] = rng.normal(0, 0.1, cfg.s)
        c, a, _ = codec.compress_blocks(jnp.asarray(b), jnp.zeros((nb, 256), jnp.float32))
        codes.append(c)
        alphas.append(a)
    rhos = jnp.full((k,), 1.0 / k)
    # Default tol (1e-5): the XLA path early-freezes, the kernel runs fixed
    # trip -- the 1e-4 contract must hold at the *default* config, not just
    # the tol=0 ideal.
    gamp = GampConfig(iters=15, variance_mode="scalar")
    out_k = estimate_and_aggregate(codec, jnp.stack(codes), jnp.stack(alphas), rhos,
                                   gamp=gamp, use_pallas=True)
    out_x = estimate_and_aggregate(codec, jnp.stack(codes), jnp.stack(alphas), rhos,
                                   gamp=gamp, use_pallas=False)
    nmse = float(jnp.sum((out_k - out_x) ** 2) / jnp.maximum(jnp.sum(out_x**2), 1e-30))
    assert nmse <= 1e-4, nmse


def test_gamp_ae_run_matches_core_em_gamp():
    """Full fixed-trip kernel scan == core scalar-variance em_gamp."""
    from repro.core import bussgang
    from repro.core.compression import BQCSCodec, FedQCSConfig
    from repro.core.gamp import GampConfig, em_gamp

    rng = np.random.default_rng(5)
    cfg = FedQCSConfig(block_size=256, reduction_ratio=3, bits=3, s_ratio=0.08)
    codec = BQCSCodec(cfg)
    g = jnp.asarray(rng.standard_t(4, (16, 256)) * 0.01, jnp.float32)
    c, a, _ = codec.compress_blocks(g, jnp.zeros_like(g))
    rhos = jnp.ones((1,))
    y = bussgang.aggregate_codes(c[None], a[None], rhos, codec.quantizer)
    nu = bussgang.effective_noise_var(a[None], rhos, codec.quantizer)
    en = bussgang.signal_energy(a[None], rhos, cfg.m, 256)
    gh_k = ops.gamp_ae_run(y, nu, codec.a, en, iters=20)
    gh_c = em_gamp(
        y, nu, codec.a,
        GampConfig(iters=20, variance_mode="scalar", tol=0.0),
        init_var=en,
    )
    np.testing.assert_allclose(np.asarray(gh_k), np.asarray(gh_c), rtol=1e-3, atol=1e-6)
