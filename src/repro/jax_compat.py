"""Version-compatibility shims for the jax API surface this repo uses.

The codebase targets the current jax API (jax.set_mesh, jax.shard_map with
``axis_names``/``check_vma``, jax.sharding.AxisType); CI and some dev boxes
carry jax 0.4.x, where the same functionality lives under different names:

    jax.set_mesh(mesh)            ->  ``with mesh:`` (Mesh is a context mgr)
    jax.shard_map(axis_names=S)   ->  jax.experimental.shard_map.shard_map
                                      (auto = all mesh axes NOT in S)
    check_vma=...                 ->  check_rep=...

Only the call signatures used by runtime/steps.py are covered -- this is a
shim, not a polyfill of the full API.
"""

from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager activating ``mesh`` for PartitionSpec constraints."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # jax 0.4.x: Mesh itself is the context manager


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=False):
    """jax.shard_map with the subset-manual ``axis_names`` semantics."""
    if hasattr(jax, "shard_map"):
        kwargs = {"check_vma": check_vma}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = {"check_rep": bool(check_vma)}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kwargs["auto"] = auto
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
