"""FedQCS core: the paper's contribution as composable JAX modules.

Submodules: quantizer (Lloyd-Max design), codebook (pluggable quantizer
families: lloyd_max / dithered_uniform / vq + registry), sparsify (block
top-S + error feedback), sensing (shared Gaussian projections), gamp
(EM-GAMP / Q-EM-GAMP), bussgang (Prop. 1 aggregation), compression (BQCS
codec over pytrees), reconstruction (EA / AE strategies), recon_engine
(chunked/sharded PS decode), baselines (SignSGD, QCS-Dither, QCS-QIHT),
api (one-call interface).
"""

from repro.core.api import (  # noqa: F401
    BQCSCodec,
    CompressorState,
    FedQCSConfig,
    compress,
    init_state,
    make_codec,
    reconstruct,
)
