"""One-call FedQCS API over gradient pytrees.

This is the composable module the rest of the framework (and external users)
consume:

    codec = fedqcs.make_codec(FedQCSConfig(...))
    state = fedqcs.init_state(codec, grads_template)
    payload, state = fedqcs.compress(codec, grads, state)      # worker side
    ghat = fedqcs.reconstruct(codec, payloads, rhos, spec,
                              recon=ReconSpec(mode=...))        # PS side

``ReconSpec`` (core/recon_engine.py) is the one value that says HOW the PS
reconstructs -- mode, AE grouping, chunking, kernel routing, and optionally a
received multiple-access channel observation ``(y_eff, nu_eff)`` in place of
the per-payload codes.  The pre-spec ``mode=``/``groups=`` keywords still
work as a deprecated shim for one release.

For the distributed (in-step, cross-pod) path see runtime/collectives.py,
which uses the same codec under shard_map.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional, Sequence

import jax.numpy as jnp

from repro.core import bussgang
from repro.core.compression import (
    BQCSCodec,
    CompressedGradient,
    FedQCSConfig,
    blocks_to_tree,
)
from repro.core.gamp import em_gamp, gamp_health
from repro.core.layout import GradientLayout
from repro.core.recon_engine import ReconSpec, ea_decode_segments
from repro.core.reconstruction import (
    aggregate_and_estimate,
    estimate_and_aggregate_packed,
    gamp_config_from,
)

__all__ = [
    "FedQCSConfig",
    "BQCSCodec",
    "GradientLayout",
    "ReconSpec",
    "make_codec",
    "init_state",
    "compress",
    "reconstruct",
    "CompressorState",
]


@dataclasses.dataclass
class CompressorState:
    """Worker-side persistent state: the error-feedback residual blocks."""

    residual: jnp.ndarray  # (nblocks, N)


def make_codec(cfg: FedQCSConfig) -> BQCSCodec:
    return BQCSCodec(cfg)


def init_state(
    codec: BQCSCodec, grads_template: Any, layout: Optional[GradientLayout] = None
) -> CompressorState:
    return CompressorState(residual=codec.zero_residual(grads_template, layout))


def compress(
    codec: BQCSCodec,
    grads: Any,
    state: CompressorState,
    layout: Optional[GradientLayout] = None,
):
    """Worker side: returns (CompressedGradient, layout-spec, new state).

    The payload's ``codes`` are bit-packed uint32 words -- the actual wire
    format; :func:`reconstruct` unpacks them at the PS boundary.  ``layout``
    selects the block geometry (core/layout.py; default monolithic -- the
    pre-layout wire, bit-identical); per-tensor layouts with per-segment
    sparsity budgets stream segment-by-segment (``compress_tree_streamed``).
    The returned spec IS the layout -- pass it to :func:`reconstruct`."""
    payload, spec, new_res = codec.compress_tree(grads, state.residual, layout)
    return payload, spec, CompressorState(residual=new_res)


def reconstruct(
    codec: BQCSCodec,
    payloads: Sequence[CompressedGradient],
    rhos: Sequence[float],
    spec: Any,
    recon: Optional[ReconSpec] = None,
    mode: Optional[str] = None,
    groups: Optional[int] = None,
    emit=None,  # EA + GradientLayout spec: callback(segment, {leaf id: array})
) -> Any:
    """PS side: fuses K payloads into the reconstructed gradient pytree.

    ``recon`` (a :class:`ReconSpec`) selects the strategy: mode="ea"
    (estimate-and-aggregate, Procedure 2) runs one Q-EM-GAMP per worker
    payload; mode="ae" (aggregate-and-estimate) Bussgang-combines first; an
    AE spec carrying ``channel=(y_eff, nu_eff)`` decodes a received
    multiple-access observation instead of the payload codes (joint
    estimation -- the payloads then contribute only their alphas, for the
    quantization-noise and GAMP-init terms).  Chunking/kernel routing come
    from the spec, deferring to the codec config where unset; the fused
    Pallas kernels engage when resolved use_pallas is set AND
    ``codec.cfg.gamp_variance_mode == 'scalar'`` (see DESIGN.md).

    A spec with ``return_info`` set returns ``(tree, info)`` instead of the
    bare tree: ``info`` carries the solver's decode health -- the per-problem
    ``converged`` flags and ``iters`` counts (a
    :class:`~repro.core.gamp.GampInfo`, (K, nb) on EA, per group-block on
    AE) plus their scalar summary (``gamp_iters_mean`` / ``gamp_iters_max``
    / ``gamp_converged_frac``, live problems only) -- instead of computing
    and discarding it (DESIGN.md #Observability).

    ``spec`` is the layout returned by :func:`compress` (a
    :class:`~repro.core.layout.GradientLayout`; the legacy ``(treedef,
    shapes)`` tuple still works).  With an EA spec and a layout, ``emit``
    turns the decode segment-local (``recon_engine.ea_decode_segments``):
    the callback fires with each segment's decoded leaves as soon as its
    rows solve -- per-tensor decode without waiting for the whole model --
    and the returned tree matches the barrier decode up to float
    reassociation (~1e-4 relative; GAMP iterates on batch-shape-dependent
    reduction orders).

    The pre-spec ``mode=``/``groups=`` keywords are a deprecated shim.
    """
    if recon is None:
        if mode is not None or groups is not None:
            warnings.warn(
                "reconstruct(mode=..., groups=...) is deprecated; pass "
                "recon=ReconSpec(mode=..., groups=...) instead",
                DeprecationWarning,
                stacklevel=2,
            )
        recon = ReconSpec(
            mode=mode if mode is not None else "ae",
            groups=groups if groups is not None else 1,
        )
    elif mode is not None or groups is not None:
        raise TypeError(
            "pass either recon=ReconSpec(...) or the deprecated "
            "mode=/groups= keywords, not both"
        )
    recon = recon.resolve(codec.cfg)
    alphas = jnp.stack([p.alpha for p in payloads])
    rhos = jnp.asarray(rhos, jnp.float32)
    ginfo = None
    live = None
    if emit is not None:
        if recon.mode != "ea" or not isinstance(spec, GradientLayout):
            raise ValueError(
                "segment-local decode (emit=...) needs recon mode 'ea' and a "
                "GradientLayout spec"
            )
        if recon.return_info:
            raise ValueError("emit=... does not carry decode-health info")
        words = jnp.stack([p.codes for p in payloads])
        blocks = ea_decode_segments(
            codec, words, alphas, rhos, spec,
            packed=True, use_pallas=recon.use_pallas, chunk=recon.chunk,
            emit=emit,
        )
        return blocks_to_tree(blocks, spec, payloads[0].nbar)
    if recon.mode == "ea":
        # The payload words pass straight through to the packed
        # reconstruction engine (DESIGN.md #Recon-engine) -- the uint8 index
        # view never materializes on the EA path.
        words = jnp.stack([p.codes for p in payloads])
        blocks = estimate_and_aggregate_packed(
            codec, words, alphas, rhos,
            use_pallas=recon.use_pallas, chunk=recon.chunk,
            with_info=recon.return_info,
        )
        if recon.return_info:
            blocks, ginfo = blocks
            live = alphas > 0  # dead blocks freeze at iteration 0
    elif recon.channel is not None:
        # Joint-estimation decode of one superimposed reception: y_eff is
        # already the Bussgang aggregate estimate (eq. 23 over the air), so
        # only the quantization-noise + channel-noise variances and the
        # GAMP-init energy remain to assemble here (eq. 24 + nu_eff).
        y_eff, nu_eff = recon.channel
        cfg = codec.cfg
        nu = bussgang.effective_noise_var(alphas, rhos, codec.codebook) + nu_eff
        energy = bussgang.signal_energy(alphas, rhos, cfg.m, cfg.block_size)
        blocks = em_gamp(
            y_eff, nu, codec.a, gamp_config_from(codec),
            init_var=energy, use_pallas=recon.use_pallas,
            with_info=recon.return_info,
        )
        if recon.return_info:
            blocks, ginfo = blocks
    else:
        # PS boundary: AE's Bussgang combine still consumes indices; unpack
        # here, once (codec.unpack knows the codebook's index width and
        # code-lane count, which differ from (Q, M) for vq).
        codes = jnp.stack([codec.unpack(p.codes) for p in payloads])
        blocks = aggregate_and_estimate(
            codec, codes, alphas, rhos,
            groups=recon.groups, use_pallas=recon.use_pallas,
            with_info=recon.return_info,
        )
        if recon.return_info:
            blocks, ginfo = blocks
    tree = blocks_to_tree(blocks, spec, payloads[0].nbar)
    if not recon.return_info:
        return tree
    info = {"converged": ginfo.converged, "iters": ginfo.iters}
    info.update(gamp_health(ginfo, live))
    return tree, info
