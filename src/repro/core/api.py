"""One-call FedQCS API over gradient pytrees.

This is the composable module the rest of the framework (and external users)
consume:

    codec = fedqcs.make_codec(FedQCSConfig(...))
    state = fedqcs.init_state(codec, grads_template)
    payload, state = fedqcs.compress(codec, grads, state)      # worker side
    ghat = fedqcs.reconstruct(codec, payloads, rhos, mode=...)  # PS side

For the distributed (in-step, cross-pod) path see runtime/collectives.py,
which uses the same codec under shard_map.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax.numpy as jnp

from repro.core.compression import (
    BQCSCodec,
    CompressedGradient,
    FedQCSConfig,
    blocks_to_tree,
)
from repro.core.reconstruction import (
    aggregate_and_estimate,
    estimate_and_aggregate_packed,
)

__all__ = [
    "FedQCSConfig",
    "BQCSCodec",
    "make_codec",
    "init_state",
    "compress",
    "reconstruct",
    "CompressorState",
]


@dataclasses.dataclass
class CompressorState:
    """Worker-side persistent state: the error-feedback residual blocks."""

    residual: jnp.ndarray  # (nblocks, N)


def make_codec(cfg: FedQCSConfig) -> BQCSCodec:
    return BQCSCodec(cfg)


def init_state(codec: BQCSCodec, grads_template: Any) -> CompressorState:
    return CompressorState(residual=codec.zero_residual(grads_template))


def compress(codec: BQCSCodec, grads: Any, state: CompressorState):
    """Worker side: returns (CompressedGradient, tree-spec, new state).

    The payload's ``codes`` are bit-packed uint32 words -- the actual wire
    format; :func:`reconstruct` unpacks them at the PS boundary."""
    payload, spec, new_res = codec.compress_tree(grads, state.residual)
    return payload, spec, CompressorState(residual=new_res)


def reconstruct(
    codec: BQCSCodec,
    payloads: Sequence[CompressedGradient],
    rhos: Sequence[float],
    spec: Any,
    mode: str = "ae",
    groups: int = 1,
) -> Any:
    """PS side: fuses K payloads into the reconstructed gradient pytree.

    mode="ea" (estimate-and-aggregate, Procedure 2) runs one Q-EM-GAMP per
    worker payload; mode="ae" (aggregate-and-estimate) Bussgang-combines
    first.  Both route through the fused Pallas kernels when
    ``codec.cfg.use_kernels`` is set AND ``codec.cfg.gamp_variance_mode ==
    'scalar'`` (the kernels implement scalar-variance GAMP; exact-variance
    configs keep the XLA path -- see DESIGN.md).
    """
    alphas = jnp.stack([p.alpha for p in payloads])
    rhos = jnp.asarray(rhos, jnp.float32)
    if mode == "ea":
        # The payload words pass straight through to the packed
        # reconstruction engine (DESIGN.md #Recon-engine) -- the uint8 index
        # view never materializes on the EA path.
        words = jnp.stack([p.codes for p in payloads])
        blocks = estimate_and_aggregate_packed(codec, words, alphas, rhos)
    elif mode == "ae":
        # PS boundary: AE's Bussgang combine still consumes indices; unpack
        # here, once (codec.unpack knows the codebook's index width and
        # code-lane count, which differ from (Q, M) for vq).
        codes = jnp.stack([codec.unpack(p.codes) for p in payloads])
        blocks = aggregate_and_estimate(codec, codes, alphas, rhos, groups=groups)
    else:
        raise ValueError(f"unknown mode {mode!r} (want 'ea' or 'ae')")
    return blocks_to_tree(blocks, spec, payloads[0].nbar)
