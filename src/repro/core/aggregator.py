"""Hierarchical partial aggregation of Bussgang/EA sufficient statistics
(DESIGN.md #Streaming-PS).

The barrier PS consumes all K payloads at once: one ``gather_codes``, one
monolithic decode.  This module is the algebra that lets the PS fold payloads
*incrementally*: both reconstruction strategies reduce, on the aggregation
side, to sums that are associative in the cohort --

  * **AE** (aggregate-and-estimate): the Bussgang observation ``y = sum_k
    w_k deq_k`` (eq. 23), the effective-noise accumulator ``nu`` (eq. 24 + the
    channel term), and the GAMP-init energy are all plain sums over clients.
  * **EA** (estimate-and-aggregate): per-client GAMP estimates are summed
    rho-weighted (Procedure 2 step 14) -- the decoded blocks themselves are
    the additive statistic, so decode can run per arrival batch and only the
    running sum stays live.

Weights fold in RAW (pre-normalization): the streamed round does not know the
final participant set until the deadline, so statistics accumulate with the
scheduler's unnormalized weights and :func:`normalized_stats` rescales at
finalization (``y`` is linear in rho -> 1/W; ``nu``/``energy`` are quadratic
-> 1/W^2).  This is algebraically identical to the barrier path's
``rho_k = w_k / W`` weighting; the only difference is f32 reassociation of
the sums, which the streamed-vs-barrier tolerance contract in
``tests/test_stream.py`` pins.

:class:`AggregatorTree` is the carry-save reduction tree the streaming PS
folds into: each tier holds ONE running partial sum and carries to its parent
every ``fanout`` folds, so live PS decode state is O(tree depth) partial
stats -- constant in the registered-client count and logarithmic in the
arrival-batch count -- instead of O(K) payloads.  The tier structure is also
the landing pad for MIMO-MAC partial aggregation (PAPERS.md): a tier's
partial sum is exactly what a superimposed sub-cohort reception produces.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core import bussgang
from repro.core.compression import BQCSCodec

__all__ = [
    "PartialStats",
    "zero_stats",
    "stats_add",
    "ae_batch_stats",
    "mimo_batch_stats",
    "ea_batch_stats",
    "normalized_stats",
    "AggregatorTree",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PartialStats:
    """Additive sufficient statistics of a (sub-)cohort, raw-weighted.

    mode "ae": ``y`` is the (nb, M) Bussgang-weighted dequantized sum,
    ``nu`` the (nb,) effective-noise accumulator (quantization + channel),
    ``energy`` the (nb,) GAMP-init signal energy.
    mode "ea": ``y`` is the (nb, N) weighted sum of per-client GAMP
    estimates; ``nu``/``energy`` stay zero (decode already happened).

    ``wsum`` is the raw-weight total folded so far (the normalizer W) and
    ``count`` the number of contributing (weight > 0) clients.
    """

    mode: str
    y: jnp.ndarray
    nu: jnp.ndarray
    energy: jnp.ndarray
    wsum: jnp.ndarray
    count: jnp.ndarray

    def tree_flatten(self):
        return (self.y, self.nu, self.energy, self.wsum, self.count), self.mode

    @classmethod
    def tree_unflatten(cls, mode, children):
        return cls(mode, *children)

    @property
    def nbytes(self) -> int:
        """Live bytes of one partial stat (the unit of PS decode state)."""
        return sum(
            int(x.size) * x.dtype.itemsize
            for x in (self.y, self.nu, self.energy, self.wsum, self.count)
        )


def zero_stats(mode: str, nb: int, width: int) -> PartialStats:
    """The additive identity: ``width`` is M for "ae", N for "ea"."""
    if mode not in ("ae", "ea"):
        raise ValueError(f"unknown stats mode {mode!r} (choose 'ae' or 'ea')")
    return PartialStats(
        mode,
        jnp.zeros((nb, width), jnp.float32),
        jnp.zeros((nb,), jnp.float32),
        jnp.zeros((nb,), jnp.float32),
        jnp.zeros((), jnp.float32),
        jnp.zeros((), jnp.float32),
    )


def stats_add(a: PartialStats, b: PartialStats) -> PartialStats:
    """Fold two partial stats (associative up to f32 reassociation)."""
    if a.mode != b.mode:
        raise ValueError(f"cannot fold {a.mode!r} stats into {b.mode!r} stats")
    return PartialStats(
        a.mode, a.y + b.y, a.nu + b.nu, a.energy + b.energy,
        a.wsum + b.wsum, a.count + b.count,
    )


def ae_batch_stats(
    codec: BQCSCodec,
    words: jnp.ndarray,  # (B, nb, W) packed wire words of one sub-cohort batch
    alphas: jnp.ndarray,  # (B, nb)
    weights: jnp.ndarray,  # (B,) RAW (unnormalized) aggregation weights
    nu_chan: Optional[jnp.ndarray] = None,  # (B, nb) channel variance
    noise: Optional[jnp.ndarray] = None,  # (B, nb, M) sampled channel noise
) -> PartialStats:
    """AE sufficient statistics of one sub-cohort payload batch.

    Dequantizes straight from the wire words (`decode_packed`: the uint8
    index view never materializes), Bussgang-weights with the RAW weights,
    and returns the batch's additive (y, nu, energy) contribution.  A zero
    weight (padding slot / dropped client) contributes exactly nothing.
    """
    cb = codec.codebook
    m = codec.cfg.m
    deq = cb.decode_packed(words, m)  # (B, nb, M)
    if noise is not None:
        deq = deq + noise
    w = bussgang.bussgang_weight(weights[:, None], alphas, cb)  # (B, nb)
    y = jnp.sum(w[..., None] * deq, axis=0)
    nu = bussgang.effective_noise_var(alphas, weights, cb)
    if nu_chan is not None:
        nu = nu + jnp.sum(jnp.square(w) * nu_chan, axis=0)
    energy = bussgang.signal_energy(alphas, weights, m, codec.cfg.block_size)
    return PartialStats(
        "ae", y, nu, energy,
        jnp.sum(weights), jnp.sum((weights > 0).astype(jnp.float32)),
    )


def mimo_batch_stats(
    codec: BQCSCodec,
    y_eff: jnp.ndarray,  # (nb, M) spatially-combined sub-cohort observation
    nu_mimo: jnp.ndarray,  # (nb,) post-combining channel noise variance
    alphas: jnp.ndarray,  # (B, nb)
    weights: jnp.ndarray,  # (B,) RAW (unnormalized) aggregation weights
) -> PartialStats:
    """AE sufficient statistics of one superimposed sub-cohort reception
    (multiple-access uplink): the channel already summed the batch's
    Bussgang-weighted rows, so ``y_eff`` IS the batch's ``y`` contribution
    and only the per-client quantization-noise/energy accumulators remain to
    compute here (the docstring above: a tier's partial sum is exactly what
    a superimposed sub-cohort reception produces)."""
    cb = codec.codebook
    nu = bussgang.effective_noise_var(alphas, weights, cb) + nu_mimo
    energy = bussgang.signal_energy(alphas, weights, codec.cfg.m, codec.cfg.block_size)
    return PartialStats(
        "ae", y_eff, nu, energy,
        jnp.sum(weights), jnp.sum((weights > 0).astype(jnp.float32)),
    )


def ea_batch_stats(ghat: jnp.ndarray, weights: jnp.ndarray) -> PartialStats:
    """EA sufficient statistics: ``ghat`` is the (B, nb, N) per-client GAMP
    estimates of one arrival batch (decoded via the recon engine's chunk
    streaming), folded as the raw-weighted sum."""
    y = jnp.einsum("k,kbn->bn", weights, ghat)
    nb = ghat.shape[1]
    z = jnp.zeros((nb,), jnp.float32)
    return PartialStats(
        "ea", y, z, z,
        jnp.sum(weights), jnp.sum((weights > 0).astype(jnp.float32)),
    )


def normalized_stats(stats: PartialStats):
    """Rescales raw-weighted sums to the barrier path's rho_k = w_k / W
    weighting: (y / W, nu / W^2, energy / W^2).  An empty round (W == 0)
    normalizes to exact zeros -- the same zero-update the barrier blackout
    path produces."""
    safe = jnp.maximum(stats.wsum, 1e-30)
    inv = jnp.where(stats.wsum > 0, 1.0 / safe, 0.0)
    return stats.y * inv, stats.nu * inv**2, stats.energy * inv**2


class AggregatorTree:
    """Carry-save ``fanout``-ary reduction tree over partial stats.

    Tier 0 absorbs arrival batches; every ``fanout`` folds a tier carries its
    running sum to the parent tier and resets.  Live decode state is one
    partial stat per tier -- O(log_fanout batches) -- and the fold order is a
    deterministic function of the PUSH order alone, so a fixed arrival
    sequence reproduces bit-identical sums regardless of wall-clock
    interleaving.  ``root()`` folds the pending tiers bottom-up (tier 0
    first), matching left-to-right pairwise summation.

    Host-side orchestration object (the pushes themselves are jitted by the
    caller); tracks ``peak_live_bytes``, the constant-memory number the
    streaming bench records.
    """

    def __init__(self, zero: PartialStats, fanout: int = 8):
        if fanout < 2:
            raise ValueError(f"fanout must be >= 2, got {fanout}")
        self.zero = zero
        self.fanout = fanout
        self.tiers: List[List] = []  # per tier: [running stats, folds since carry]
        self.pushed = 0
        self.peak_live_bytes = 0

    @property
    def live_bytes(self) -> int:
        return len(self.tiers) * self.zero.nbytes

    def push(self, stats: PartialStats) -> None:
        self._fold(0, stats)
        self.pushed += 1
        self.peak_live_bytes = max(self.peak_live_bytes, self.live_bytes)

    def _fold(self, tier: int, stats: PartialStats) -> None:
        if tier == len(self.tiers):
            self.tiers.append([self.zero, 0])
        acc = self.tiers[tier]
        acc[0] = stats_add(acc[0], stats)
        acc[1] += 1
        if acc[1] == self.fanout:
            carried = acc[0]
            self.tiers[tier] = [self.zero, 0]
            self._fold(tier + 1, carried)

    def root(self) -> PartialStats:
        """Folds every pending tier into the round total (non-destructive)."""
        total = self.zero
        for acc, _ in self.tiers:
            total = stats_add(total, acc)
        return total
