"""Chunked / sharded packed-domain PS reconstruction engine
(DESIGN.md #Recon-engine).

PR 3 scaled the client side to 1000-client cohorts; this module makes the PS
decode scale the same way.  The EA strategy (the paper's best-NMSE mode,
Procedure 2) is one independent Q-EM-GAMP inversion per (worker, block) --
``K * nb`` problems sharing one sensing matrix.  The monolithic batch solve
(`reconstruction.estimate_and_aggregate` at chunk=0) materializes the whole
``(K*nb, N)`` GAMP state, plus on the XLA path the full ``(K, nb, M)`` uint8
code view, and iterates every problem until the *globally* slowest block
converges.  The engine fixes all three scale terms:

  * **chunking** -- the flat problem batch streams through a ``lax.scan`` in
    fixed-size chunks (``FedQCSConfig.recon_chunk`` rows), so live GAMP state
    is O(chunk * N) regardless of cohort size;
  * **packed-domain decode** -- chunks carry the uint32 wire words straight
    from the collective; the fused kernel unpacks per lane group in VMEM and
    the XLA path unpacks one chunk at a time, so the ``(K, nb, M)`` uint8
    tensor never exists (``qem_gamp_packed``);
  * **early-stop per chunk** -- each chunk's GAMP loop exits when *its own*
    slowest block froze (``GampConfig.early_stop``), converting the
    early-freeze carry into wall-clock instead of masked no-op iterations;
  * **sharding** -- chunks optionally spread over a mesh axis via
    ``jax_compat.shard_map``: the chunk axis is partitioned into CONTIGUOUS
    blocks of nch/ndev chunks per device (PartitionSpec semantics), so the
    dead-row pad chunks appended at the end all land on the last device --
    cheap, since dead rows freeze at iteration 0 and an early-stop chunk of
    only dead rows exits after one iteration.  Every device scans only its
    own chunks.  Do NOT nest this under the 'pod' manual collective -- the
    in-step decode is already sharded by the outer mesh.

The two-phase sweep (`ea_decode_two_phase`) adds a quality mode: a cheap
scalar-variance pass everywhere, then exact-variance GAMP (Procedure 2's
per-entry variances) re-solves only the blocks whose converged flag is still
false.  Phase 2's survivor gather is host-side (data-dependent shapes), so
the two-phase entry point is a host orchestrator around jitted solves -- use
it from drivers (benchmarks, offline decode), not inside a train step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import BQCSCodec
from repro.core.gamp import (
    GampConfig,
    GampInfo,
    _qem_gamp_xla,
    em_gamp,
    qem_gamp,
    qem_gamp_packed,
)

__all__ = [
    "ReconSpec",
    "chunked_rows",
    "ea_solve_flat",
    "ea_decode",
    "ea_decode_segments",
    "ea_decode_two_phase",
    "decode_from_stats",
]


@dataclasses.dataclass(frozen=True)
class ReconSpec:
    """One value describing HOW the PS reconstructs a round.

    Consolidates the stringly knobs that had accreted across
    ``api.reconstruct`` / the collectives / the recon engine (positional
    ``mode``, ``groups``, per-call chunk overrides) into a single spec every
    entry point accepts:

      mode: "ae" (aggregate-and-estimate: Bussgang combine, one EM-GAMP) or
        "ea" (estimate-and-aggregate: per-worker Q-EM-GAMP, rho-sum).
      groups: AE grouping G (ideal uplink only; eq. 25 grouping).
      chunk: recon-engine row chunking; None defers to the codec's
        ``cfg.recon_chunk``.
      use_pallas: fused-kernel routing; None defers to ``cfg.use_kernels``.
      channel: optional received multiple-access observation in place of the
        per-payload codes: a ``(y_eff (nb, M), nu_eff (nb,))`` pair as
        produced by a channel family's ``combine`` hook (fed/channel.py --
        typed loosely here: core stays fed-agnostic).  AE only; the payloads
        then contribute alphas (quantization noise + GAMP init), not codes.
      return_info: also return the solver's decode-health aux (per-block
        converged flags + live-iteration counts, :class:`~repro.core.gamp.
        GampInfo`) instead of discarding it -- ``api.reconstruct`` then
        returns ``(tree, info)``.  Kernel routes report the static
        placeholder info (fixed trip count, no freeze signal).
    """

    mode: str = "ae"
    groups: int = 1
    chunk: Optional[int] = None
    use_pallas: Optional[bool] = None
    channel: Any = None
    return_info: bool = False

    def __post_init__(self):
        if self.mode not in ("ae", "ea"):
            raise ValueError(f"unknown recon mode {self.mode!r} (want 'ea' or 'ae')")
        if self.groups < 1:
            raise ValueError(f"groups must be >= 1, got {self.groups}")
        if self.mode == "ea" and self.channel is not None:
            raise ValueError(
                "a superimposed multiple-access reception has no per-client "
                "codes, so recon mode 'ea' cannot consume a channel "
                "observation (use mode='ae')"
            )
        if self.channel is not None and self.groups != 1:
            raise ValueError("groups != 1 is only defined for exact-code AE")

    def resolve(self, cfg) -> "ReconSpec":
        """Fills the defer-to-codec fields from a FedQCSConfig."""
        return dataclasses.replace(
            self,
            chunk=cfg.recon_chunk if self.chunk is None else self.chunk,
            use_pallas=cfg.use_kernels if self.use_pallas is None else self.use_pallas,
        )


def _pad_rows_zero(arrays, rows: int, target: int):
    """Zero-pads every array's leading axis from ``rows`` to ``target``.
    Zero rows are dead blocks (alpha == 0): the solver freezes them from
    iteration 0 and emits exact zeros, so padding is output-invariant."""
    pad = target - rows
    if pad == 0:
        return arrays
    return tuple(
        jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]) for x in arrays
    )


def chunked_rows(
    solve,
    inputs: Tuple[jnp.ndarray, ...],
    chunk: int,
    out_width: int,
    mesh=None,
    axis_name: str = "recon",
):
    """Streams row-aligned ``inputs`` through ``solve`` in fixed-size chunks.

    ``solve(*chunk_inputs) -> (chunk, out_width)`` runs under a ``lax.scan``
    over ``ceil(rows / chunk)`` chunks (rows zero-padded to the chunk grid --
    dead-block padding, see `_pad_rows_zero`).  With a ``mesh``, the chunk
    axis is additionally sharded over ``axis_name`` via
    ``jax_compat.shard_map``: the chunk count is padded to the axis size and
    each device scans its local chunks; everything ``solve`` closes over
    (sensing matrix, threshold tables) is replicated.

    ``chunk <= 0`` or a chunk covering all rows degrades to one direct call.
    """
    rows = inputs[0].shape[0]
    if chunk <= 0 or (chunk >= rows and mesh is None):
        return solve(*inputs)
    nch = -(-rows // chunk)
    if mesh is not None:
        ndev = mesh.shape[axis_name]
        nch = -(-nch // ndev) * ndev
    padded = _pad_rows_zero(inputs, rows, nch * chunk)
    chunked = tuple(x.reshape((nch, chunk) + x.shape[1:]) for x in padded)

    def scan_chunks(*xs):
        _, out = jax.lax.scan(lambda _, c: (None, solve(*c)), None, xs)
        return out

    if mesh is None:
        out = scan_chunks(*chunked)
    else:
        from jax.sharding import PartitionSpec as P

        from repro import jax_compat

        spec = P(axis_name)
        out = jax_compat.shard_map(
            scan_chunks,
            mesh=mesh,
            in_specs=(spec,) * len(chunked),
            out_specs=spec,
            axis_names={axis_name},
            check_vma=False,
        )(*chunked)
    return out.reshape(nch * chunk, out_width)[:rows]


def ea_solve_flat(
    codec: BQCSCodec,
    obs: jnp.ndarray,  # (rows, M) codes or (rows, W) packed uint32 words
    alpha: jnp.ndarray,  # (rows,)
    gamp: GampConfig,
    *,
    packed: bool,
    use_pallas: bool = False,
    chunk: int = 0,
    mesh=None,
    axis_name: str = "recon",
    with_info: bool = False,
) -> jnp.ndarray:
    """Solves a flat batch of per-(worker, block) Q-EM-GAMP problems ->
    (rows, N) block estimates.  The chunk solver is `qem_gamp_packed` when
    ``packed`` (wire words in, in-VMEM/in-chunk unpack) else `qem_gamp`.

    ``with_info`` returns ``(estimates, GampInfo)`` instead: the per-row
    converged flags and live-iteration counts ride the chunk scan as two
    extra output columns (the same trick as `ea_decode_two_phase`'s flag
    column), so the info costs nothing beyond the columns themselves.
    """
    n = codec.cfg.block_size
    if packed:
        base = lambda o, al: qem_gamp_packed(
            o, al, codec.a, codec.codebook, gamp, codec.cfg.m,
            use_pallas=use_pallas, with_info=with_info,
        )
    else:
        base = lambda o, al: qem_gamp(
            o, al, codec.a, codec.codebook, gamp,
            use_pallas=use_pallas, with_info=with_info,
        )
    if not with_info:
        return chunked_rows(base, (obs, alpha), chunk, n, mesh=mesh, axis_name=axis_name)

    def solve(o, al):
        gh, info = base(o, al)
        return jnp.concatenate(
            [
                gh,
                info.converged.astype(jnp.float32)[:, None],
                info.iters.astype(jnp.float32)[:, None],
            ],
            axis=1,
        )

    stacked = chunked_rows(
        solve, (obs, alpha), chunk, n + 2, mesh=mesh, axis_name=axis_name
    )
    info = GampInfo(stacked[:, n] > 0.5, stacked[:, n + 1].astype(jnp.int32))
    return stacked[:, :n], info


def ea_decode(
    codec: BQCSCodec,
    obs: jnp.ndarray,  # (K, nb, M) uint8 codes or (K, nb, W) uint32 words
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    gamp: Optional[GampConfig] = None,
    *,
    packed: bool,
    use_pallas: bool = False,
    chunk: int = 0,
    mesh=None,
    axis_name: str = "recon",
    spec: Optional[ReconSpec] = None,
    with_info: bool = False,
) -> jnp.ndarray:
    """FedQCS-EA decode through the engine: flatten the (K, nb) problem grid,
    chunk/shard-solve, rho-weight and sum -> (nb, N) aggregated blocks.

    Jit-safe (the chunk stream is a ``lax.scan``); this is what
    `reconstruction.estimate_and_aggregate` / ``_packed`` delegate to.
    A ``spec`` (ReconSpec) overrides the chunk/use_pallas knobs in one value
    (its ``return_info`` implies ``with_info``); with info requested the
    return is ``(blocks, GampInfo)`` whose aux arrays are (K, nb)-shaped.
    """
    from repro.core.reconstruction import gamp_config_from  # deferred: layering

    if spec is not None:
        spec = spec.resolve(codec.cfg)
        chunk, use_pallas = spec.chunk, spec.use_pallas
        with_info = with_info or spec.return_info
    gamp = gamp or gamp_config_from(codec)
    k, nb = obs.shape[:2]
    flat = ea_solve_flat(
        codec,
        obs.reshape((k * nb,) + obs.shape[2:]),
        alphas.reshape(k * nb),
        gamp,
        packed=packed,
        use_pallas=use_pallas,
        chunk=chunk,
        mesh=mesh,
        axis_name=axis_name,
        with_info=with_info,
    )
    if with_info:
        flat, info = flat
        agg = jnp.einsum("k,kbn->bn", rhos, flat.reshape(k, nb, -1))
        return agg, GampInfo(
            info.converged.reshape(k, nb), info.iters.reshape(k, nb)
        )
    return jnp.einsum("k,kbn->bn", rhos, flat.reshape(k, nb, -1))


def ea_decode_segments(
    codec: BQCSCodec,
    obs: jnp.ndarray,  # (K, nb, M) uint8 codes or (K, nb, W) uint32 words
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    layout,  # core.layout.GradientLayout (the round's block geometry)
    gamp: Optional[GampConfig] = None,
    *,
    packed: bool,
    use_pallas: bool = False,
    chunk: int = 0,
    emit=None,  # callback(segment, {leaf id: array}) per decoded segment
) -> jnp.ndarray:
    """Segment-local FedQCS-EA decode: each layout segment's ``(K, rows)``
    block problems solve and aggregate independently, so per-tensor decode
    starts -- and ``emit(segment, leaves)`` fires with that segment's decoded
    leaves -- as soon as its rows arrive, without waiting for the rest of the
    model (a streaming PS receiving segments in backward order updates the
    last layers first).

    Chunk boundaries align to layout segments by construction here: every
    segment is its own chunked solve, so no ``lax.scan`` chunk ever straddles
    two tensors (build per-tensor layouts with ``row_multiple=chunk`` to keep
    those per-segment chunks full).  Each GAMP problem is per-(worker, block)
    row, so the concatenated output matches :func:`ea_decode` over the whole
    grid up to float reassociation (XLA compiles different reduction orders
    for different batch shapes, and GAMP iterates on them -- expect ~1e-4
    relative, not bit-identity).  Host loop over segments around jitted solves --
    call from drivers/PS ingest, not inside jit.  Returns the aggregated
    ``(nb, N)`` block grid.
    """
    if layout.rows != obs.shape[1]:
        raise ValueError(
            f"layout has {layout.rows} block rows, payloads have {obs.shape[1]}"
        )
    parts = []
    for seg in layout.segments:
        agg = ea_decode(
            codec,
            obs[:, seg.row_slice],
            alphas[:, seg.row_slice],
            rhos,
            gamp,
            packed=packed,
            use_pallas=use_pallas,
            chunk=chunk,
        )
        if emit is not None:
            emit(seg, layout.segment_leaves(seg.index, agg))
        parts.append(agg)
    return jnp.concatenate(parts, axis=0)


def ea_decode_two_phase(
    codec: BQCSCodec,
    obs: jnp.ndarray,  # (K, nb, M) uint8 codes or (K, nb, W) uint32 words
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    gamp: Optional[GampConfig] = None,
    *,
    packed: bool,
    chunk: int = 0,
    refine_iters: Optional[int] = None,
    mesh=None,
    axis_name: str = "recon",
) -> Tuple[jnp.ndarray, dict]:
    """Two-phase EA sweep: scalar-variance GAMP everywhere (cheap: 2 GEMMs
    per iteration), then exact-variance GAMP (4 GEMMs, Procedure 2's
    per-entry variances) re-solves ONLY the blocks whose early-freeze flag
    is still false after phase 1.

    Host orchestrator (phase 2 gathers a data-dependent survivor set), so
    call it from drivers, not inside jit.  Returns (aggregated (nb, N)
    blocks, stats dict with phase-2 counts).
    """
    from repro.core.reconstruction import gamp_config_from  # deferred: layering

    gamp = gamp or gamp_config_from(codec)
    k, nb = obs.shape[:2]
    rows = k * nb
    n = codec.cfg.block_size
    flat_obs = obs.reshape((rows,) + obs.shape[2:])
    flat_alpha = alphas.reshape(rows)

    # Phase 1: scalar-variance sweep over every problem, converged flags out.
    # The flags come from _gamp_run's early-freeze carry, so the XLA solver
    # runs phase 1 (the kernel's fixed-trip scan has no freeze signal).
    p1 = dataclasses.replace(gamp, variance_mode="scalar")
    codes_of = (
        (lambda o: codec.unpack(o)) if packed else (lambda o: o)
    )
    def solve_flags(o, al):
        gh, fl, it = _qem_gamp_xla(codes_of(o), al, codec.a, codec.codebook, p1)
        # converged flag + live-iteration count ride as extra output columns
        return jnp.concatenate(
            [gh, fl.astype(jnp.float32)[:, None], it.astype(jnp.float32)[:, None]],
            axis=1,
        )

    stacked = chunked_rows(
        solve_flags, (flat_obs, flat_alpha), chunk, n + 2,
        mesh=mesh, axis_name=axis_name,
    )
    ghat = stacked[:, :n]
    converged = np.asarray(stacked[:, n]) > 0.5
    iters1 = np.asarray(stacked[:, n + 1])

    # Phase 2: exact-variance refinement of the survivors only.
    survivors = np.flatnonzero(~converged)
    if survivors.size:
        p2 = dataclasses.replace(
            gamp,
            variance_mode="exact",
            iters=refine_iters if refine_iters is not None else gamp.iters,
            early_stop=False,
        )
        idx = jnp.asarray(survivors)
        refined, _, _ = jax.jit(
            lambda o, al: _qem_gamp_xla(codes_of(o), al, codec.a, codec.codebook, p2)
        )(flat_obs[idx], flat_alpha[idx])
        ghat = ghat.at[idx].set(refined)
    stats = {
        "rows": rows,
        "phase2_rows": int(survivors.size),
        "phase2_frac": float(survivors.size) / max(rows, 1),
        # decode-health counters (repro.obs): phase-1 effort + the
        # unconverged-survivor count IS phase2_rows, recorded explicitly
        # under the counter's name so run logs stay self-describing.
        "phase1_iters_mean": float(iters1.mean()) if rows else 0.0,
        "unconverged_survivors": int(survivors.size),
    }
    agg = jnp.einsum("k,kbn->bn", rhos, ghat.reshape(k, nb, n))
    return agg, stats


def decode_from_stats(
    codec: BQCSCodec,
    stats,  # core.aggregator.PartialStats (the folded round total)
    gamp: Optional[GampConfig] = None,
    *,
    use_pallas: bool = False,
    with_info: bool = False,
) -> jnp.ndarray:
    """Finalizes a streamed round straight from folded partial sufficient
    statistics (core/aggregator.py; DESIGN.md #Streaming-PS) -> (nb, N)
    aggregated blocks.  ``with_info`` returns ``(blocks, GampInfo | None)``:
    the finalize EM-GAMP's decode health on the "ae" path, None on "ea"
    (whose GAMP ran per ingest batch -- StreamingPS accumulates that).

    "ea" stats already hold the raw-weighted sum of per-client GAMP
    estimates, so finalization is just the 1/W renormalization.  "ae" stats
    hold the Bussgang aggregate's (y, nu, energy) accumulated with RAW
    weights; after the 1/W (linear) and 1/W^2 (quadratic) rescale this is
    bit-for-bit the barrier AE observation up to f32 reassociation of the
    client sums, and one EM-GAMP inversion finishes the decode exactly like
    `reconstruction.aggregate_and_estimate`.  Jit-safe.
    """
    from repro.core.aggregator import normalized_stats  # deferred: layering
    from repro.core.reconstruction import gamp_config_from  # deferred: layering

    y, nu, energy = normalized_stats(stats)
    if stats.mode == "ea":
        return (y, None) if with_info else y
    gamp = gamp or gamp_config_from(codec)
    return em_gamp(
        y, nu, codec.a, gamp, init_var=energy,
        use_pallas=use_pallas, with_info=with_info,
    )
