"""Baseline frameworks the paper compares against (Sec. VI).

  * SignSGD with majority vote [12]: 1 bit/entry, sign + vote + global scale.
  * QCS-Dither [23]: dithered *uniform* quantization after a structured
    (Hadamard x Rademacher) projection; linear (adjoint) estimator at the PS.
  * QCS-QIHT [24][25][36]: BQCS compression, but reconstruction via quantized
    iterative hard thresholding instead of Q-EM-GAMP (needs S known).

All operate on the same (nblocks, N) block view as the FedQCS codec so the
benchmark harness can swap them in one line.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import sparsify
from repro.core.codebook import as_codebook

__all__ = [
    "signsgd_compress",
    "signsgd_aggregate",
    "DitherCodec",
    "qiht_reconstruct",
]


# ---------------------------------------------------------------------------
# SignSGD with majority vote [12]
# ---------------------------------------------------------------------------


def signsgd_compress(blocks: jnp.ndarray) -> jnp.ndarray:
    """Per-entry sign in {-1, +1} (int8 on the wire: 1 bit/entry)."""
    return jnp.where(blocks >= 0, 1, -1).astype(jnp.int8)


def signsgd_aggregate(signs: jnp.ndarray, lr_scale: float = 1.0) -> jnp.ndarray:
    """Majority vote across workers: sign(sum_k sign(g_k)).

    Args: signs (K, nb, N) int8.  Returns (nb, N) f32 in {-1, +1} * lr_scale.
    """
    vote = jnp.sum(signs.astype(jnp.int32), axis=0)
    return jnp.where(vote >= 0, 1.0, -1.0).astype(jnp.float32) * lr_scale


# ---------------------------------------------------------------------------
# QCS-Dither [23]: Hadamard x Rademacher sensing + dithered uniform quant.
# ---------------------------------------------------------------------------


def _fwht(x: jnp.ndarray) -> jnp.ndarray:
    """Fast Walsh-Hadamard transform along the last axis (power-of-2 length),
    un-normalized (H @ x with entries +-1)."""
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"FWHT needs power-of-2 length, got {n}")
    h = 1
    shape = x.shape
    x = x.reshape(-1, n)
    while h < n:
        x = x.reshape(-1, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    return x.reshape(shape)


@dataclasses.dataclass
class DitherCodec:
    """QCS-Dither: y = S H D g (D = random Rademacher diag, H = Hadamard,
    S = row subsampling), dithered uniform quantization of y, linear
    reconstruction g_hat = D H^T S^T y_dq / N.

    The dither u ~ Unif(-delta/2, delta/2) must be shared with the PS (the
    extra overhead the paper criticizes); we regenerate it from a per-step
    seed on both sides, and *account* the overhead in wire_bits.
    """

    n: int
    m: int
    bits: int
    seed: int = 7

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        krad, krow = jax.random.split(key)
        self.rademacher = jnp.where(
            jax.random.bernoulli(krad, 0.5, (self.n,)), 1.0, -1.0
        ).astype(jnp.float32)
        self.rows = jax.random.choice(krow, self.n, (self.m,), replace=False)

    def _project(self, blocks: jnp.ndarray) -> jnp.ndarray:
        z = blocks * self.rademacher[None, :]
        y = _fwht(z) / jnp.sqrt(jnp.asarray(self.n, jnp.float32))
        return y[:, self.rows]  # (nb, M); rows of orthonormal H D

    def _backproject(self, y: jnp.ndarray, nb: int) -> jnp.ndarray:
        full = jnp.zeros((nb, self.n), jnp.float32).at[:, self.rows].set(y)
        z = _fwht(full) / jnp.sqrt(jnp.asarray(self.n, jnp.float32))
        return z * self.rademacher[None, :]

    def compress(self, blocks: jnp.ndarray, key: jax.Array):
        """Returns (codes int32, scale, dither_key).  Uniform quantizer with
        range +-4*std, 2**bits levels, additive dither."""
        y = self._project(blocks)
        scale = jnp.maximum(jnp.std(y, axis=-1, keepdims=True), 1e-12) * 4.0
        delta = 2.0 * scale / (2**self.bits)
        dither = jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5) * delta
        q = jnp.clip(jnp.round((y + dither) / delta), -(2 ** (self.bits - 1)), 2 ** (self.bits - 1) - 1)
        return q.astype(jnp.int32), delta, dither

    def reconstruct(self, codes: jnp.ndarray, delta: jnp.ndarray, dither: jnp.ndarray):
        """Linear estimator: subtract dither, backproject with the adjoint
        (orthonormal rows => least-squares on the sampled subspace), and
        rescale by N/M to unbias the subsampled energy."""
        y = codes.astype(jnp.float32) * delta - dither
        nb = codes.shape[0]
        return self._backproject(y, nb) * (self.n / self.m)


# ---------------------------------------------------------------------------
# QCS-QIHT [36]: quantized iterative hard thresholding.
# ---------------------------------------------------------------------------


def qiht_reconstruct(
    codes: jnp.ndarray,  # (nb, n_codes) codebook indices
    alpha: jnp.ndarray,  # (nb,)
    a: jnp.ndarray,  # (M, N)
    quantizer,  # Codebook of any family (or legacy LloydMaxQuantizer)
    s: int,
    iters: int = 50,
    step: float = 1.0,
) -> jnp.ndarray:
    """QIHT: g <- H_S(g + mu A^T (q_dq - Q(alpha A g)) / alpha), then rescale
    the result so ||g_hat|| matches the norm implied by alpha (as the paper's
    QCS-QIHT baseline does).  Generic over the codebook: the iteration only
    needs decode and quantize-requantize, both part of the Codebook surface."""
    cb = as_codebook(quantizer)
    nb = codes.shape[0]
    m, n = a.shape
    q_dq = cb.decode(codes, m)  # (nb, M)
    alive = alpha > 0
    safe_alpha = jnp.where(alive, alpha, 1.0)[:, None]

    def body(_, g):
        xa = safe_alpha * (g @ a.T)
        resid = q_dq - cb.quantize(xa)
        g = g + step * (resid @ a) / safe_alpha
        g, _ = sparsify.block_sparsify(g, s)
        return g

    g0 = jnp.zeros((nb, n), jnp.float32)
    g = jax.lax.fori_loop(0, iters, body, g0)
    # Norm rescale: true ||g_block|| = sqrt(M)/alpha.
    norms = jnp.maximum(jnp.linalg.norm(g, axis=-1, keepdims=True), 1e-12)
    target = jnp.sqrt(jnp.asarray(m, jnp.float32)) / safe_alpha
    g = g / norms * target
    return jnp.where(alive[:, None], g, 0.0)
