"""PS-side gradient reconstruction strategies (paper Sec. IV, Procedure 1).

  * estimate_and_aggregate (FedQCS-EA, steps 12-14): Q-EM-GAMP per worker,
    then rho-weighted sum.  Best NMSE, complexity O(K B M N I).
  * aggregate_and_estimate (FedQCS-AE, steps 16-20): Bussgang-combine within
    each of G groups, EM-GAMP per group, sum groups.  O(G B M N I).

Both consume the stacked payloads of all K workers:
    codes  (K, nblocks, M) uint8
    alphas (K, nblocks)    f32
    rhos   (K,)            f32   (sum to 1; zero for dead/evicted workers)

Partial participation: a failed worker contributes rho_k = 0 and its codes are
ignored exactly (its Bussgang weight and noise contribution vanish), so losing
a pod degrades gradient quality instead of failing the step.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import bussgang
from repro.core.compression import BQCSCodec
from repro.core.gamp import GampConfig, em_gamp, qem_gamp

__all__ = ["estimate_and_aggregate", "aggregate_and_estimate", "gamp_config_from"]


def gamp_config_from(codec: BQCSCodec, iters: Optional[int] = None) -> GampConfig:
    cfg = codec.cfg
    return GampConfig(
        n_components=cfg.gamp_components,
        iters=iters if iters is not None else cfg.gamp_iters,
        variance_mode=cfg.gamp_variance_mode,
    )


def estimate_and_aggregate(
    codec: BQCSCodec,
    codes: jnp.ndarray,  # (K, nb, M)
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    gamp: Optional[GampConfig] = None,
    use_pallas: Optional[bool] = None,
) -> jnp.ndarray:
    """FedQCS-EA: returns the reconstructed global blocks (nb, N).

    ``use_pallas`` (default: ``codec.cfg.use_kernels``) routes the batched
    Q-EM-GAMP solve through the fused TPU kernel -- scalar-variance, fixed
    trip count; see qem_gamp for the exact semantics of that path.
    """
    gamp = gamp or gamp_config_from(codec)
    if use_pallas is None:
        use_pallas = codec.cfg.use_kernels
    k, nb, m = codes.shape
    # Batch all K*nb recovery problems into one GAMP run (they share A).
    flat_codes = codes.reshape(k * nb, m)
    flat_alpha = alphas.reshape(k * nb)
    ghat = qem_gamp(
        flat_codes, flat_alpha, codec.a, codec.quantizer, gamp,
        use_pallas=use_pallas,
    )
    ghat = ghat.reshape(k, nb, -1)
    return jnp.sum(rhos[:, None, None] * ghat, axis=0)


def aggregate_and_estimate(
    codec: BQCSCodec,
    codes: jnp.ndarray,  # (K, nb, M)
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    groups: int = 1,  # G
    gamp: Optional[GampConfig] = None,
    use_pallas: Optional[bool] = None,
) -> jnp.ndarray:
    """FedQCS-AE: Bussgang-aggregate within groups, EM-GAMP per group, sum.

    ``use_pallas`` (default: ``codec.cfg.use_kernels``) routes the group GAMP
    solves through the fused kernel under the same rules as em_gamp.
    """
    gamp = gamp or gamp_config_from(codec)
    if use_pallas is None:
        use_pallas = codec.cfg.use_kernels
    k, nb, m = codes.shape
    n = codec.cfg.block_size
    if k % groups != 0:
        raise ValueError(f"K={k} not divisible by G={groups}")
    per = k // groups
    q = codec.quantizer
    out = jnp.zeros((nb, n), jnp.float32)
    ys, nus, energies = [], [], []
    for g in range(groups):
        sl = slice(g * per, (g + 1) * per)
        ys.append(bussgang.aggregate_codes(codes[sl], alphas[sl], rhos[sl], q))
        nus.append(bussgang.effective_noise_var(alphas[sl], rhos[sl], q))
        energies.append(bussgang.signal_energy(alphas[sl], rhos[sl], m, n))
    y = jnp.concatenate(ys, axis=0)  # (G*nb, M)
    nu = jnp.concatenate(nus, axis=0)
    energy = jnp.concatenate(energies, axis=0)
    ghat = em_gamp(y, nu, codec.a, gamp, init_var=energy, use_pallas=use_pallas)
    return jnp.sum(ghat.reshape(groups, nb, n), axis=0)
