"""PS-side gradient reconstruction strategies (paper Sec. IV, Procedure 1).

  * estimate_and_aggregate (FedQCS-EA, steps 12-14): Q-EM-GAMP per worker,
    then rho-weighted sum.  Best NMSE, complexity O(K B M N I).
  * aggregate_and_estimate (FedQCS-AE, steps 16-20): Bussgang-combine within
    each of G groups, EM-GAMP per group, sum groups.  O(G B M N I).

Both consume the stacked payloads of all K workers:
    codes  (K, nblocks, M) uint8   -- or, on the packed EA path, the uint32
           wire words (K, nblocks, W) straight from the collective
    alphas (K, nblocks)    f32
    rhos   (K,)            f32   (sum to 1; zero for dead/evicted workers)

The EA solve routes through the chunked/sharded reconstruction engine
(core/recon_engine.py, DESIGN.md #Recon-engine); ``FedQCSConfig.recon_chunk``
bounds how much GAMP state (and unpacked code view) is live at once.

Partial participation: a failed worker contributes rho_k = 0 and its codes are
ignored exactly (its Bussgang weight and noise contribution vanish), so losing
a pod degrades gradient quality instead of failing the step.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import bussgang
from repro.core.compression import BQCSCodec
from repro.core.gamp import GampConfig, em_gamp

__all__ = [
    "estimate_and_aggregate",
    "estimate_and_aggregate_packed",
    "aggregate_and_estimate",
    "gamp_config_from",
]


def gamp_config_from(codec: BQCSCodec, iters: Optional[int] = None) -> GampConfig:
    cfg = codec.cfg
    return GampConfig(
        n_components=cfg.gamp_components,
        iters=iters if iters is not None else cfg.gamp_iters,
        variance_mode=cfg.gamp_variance_mode,
    )


def estimate_and_aggregate(
    codec: BQCSCodec,
    codes: jnp.ndarray,  # (K, nb, M)
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    gamp: Optional[GampConfig] = None,
    use_pallas: Optional[bool] = None,
    chunk: Optional[int] = None,
    with_info: bool = False,
) -> jnp.ndarray:
    """FedQCS-EA: returns the reconstructed global blocks (nb, N); with
    ``with_info`` returns ``(blocks, GampInfo)`` whose per-(worker, block)
    converged flags / iteration counts are (K, nb)-shaped (decode health,
    repro.obs).

    ``use_pallas`` (default: ``codec.cfg.use_kernels``) routes the batched
    Q-EM-GAMP solve through the fused TPU kernel -- scalar-variance, fixed
    trip count; see qem_gamp for the exact semantics of that path.

    ``chunk`` (default: ``codec.cfg.recon_chunk``; 0 = monolithic) streams
    the K*nb problems through the chunked reconstruction engine
    (core/recon_engine.py) so the GAMP state never materializes for more
    than ``chunk`` rows at a time.
    """
    from repro.core import recon_engine  # deferred: engine imports this module

    gamp = gamp or gamp_config_from(codec)
    if use_pallas is None:
        use_pallas = codec.cfg.use_kernels
    if chunk is None:
        chunk = codec.cfg.recon_chunk
    return recon_engine.ea_decode(
        codec, codes, alphas, rhos, gamp,
        packed=False, use_pallas=use_pallas, chunk=chunk, with_info=with_info,
    )


def estimate_and_aggregate_packed(
    codec: BQCSCodec,
    words: jnp.ndarray,  # (K, nb, W) uint32 packed wire words
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    gamp: Optional[GampConfig] = None,
    use_pallas: Optional[bool] = None,
    chunk: Optional[int] = None,
    with_info: bool = False,
) -> jnp.ndarray:
    """Packed-domain FedQCS-EA: consumes the uint32 wire words straight from
    the collective.  The (K, nb, M) uint8 code tensor never materializes:
    the fused kernel unpacks per lane group in VMEM, and the XLA path
    unpacks at most one chunk at a time inside the scan
    (DESIGN.md #Recon-engine).  Bit-identical to
    ``estimate_and_aggregate(unpack_codes(words), ...)``.
    """
    from repro.core import recon_engine  # deferred: engine imports this module

    gamp = gamp or gamp_config_from(codec)
    if use_pallas is None:
        use_pallas = codec.cfg.use_kernels
    if chunk is None:
        chunk = codec.cfg.recon_chunk
    return recon_engine.ea_decode(
        codec, words, alphas, rhos, gamp,
        packed=True, use_pallas=use_pallas, chunk=chunk, with_info=with_info,
    )


def aggregate_and_estimate(
    codec: BQCSCodec,
    codes: jnp.ndarray,  # (K, nb, M)
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    groups: int = 1,  # G
    gamp: Optional[GampConfig] = None,
    use_pallas: Optional[bool] = None,
    with_info: bool = False,
) -> jnp.ndarray:
    """FedQCS-AE: Bussgang-aggregate within groups, EM-GAMP per group, sum.

    ``use_pallas`` (default: ``codec.cfg.use_kernels``) routes the group GAMP
    solves through the fused kernel under the same rules as em_gamp.
    ``with_info`` returns ``(blocks, GampInfo)``; the info arrays are
    (G*nb,)-shaped (one GAMP problem per group-block).
    """
    gamp = gamp or gamp_config_from(codec)
    if use_pallas is None:
        use_pallas = codec.cfg.use_kernels
    k, nb = codes.shape[:2]
    # codes carry n_codes = M / dim lanes; the Bussgang/GAMP math runs in
    # measurement space, so M comes from the config, not the payload shape.
    m = codec.cfg.m
    n = codec.cfg.block_size
    if k % groups != 0:
        raise ValueError(f"K={k} not divisible by G={groups}")
    per = k // groups
    q = codec.codebook
    out = jnp.zeros((nb, n), jnp.float32)
    ys, nus, energies = [], [], []
    for g in range(groups):
        sl = slice(g * per, (g + 1) * per)
        ys.append(bussgang.aggregate_codes(codes[sl], alphas[sl], rhos[sl], q))
        nus.append(bussgang.effective_noise_var(alphas[sl], rhos[sl], q))
        energies.append(bussgang.signal_energy(alphas[sl], rhos[sl], m, n))
    y = jnp.concatenate(ys, axis=0)  # (G*nb, M)
    nu = jnp.concatenate(nus, axis=0)
    energy = jnp.concatenate(energies, axis=0)
    ghat = em_gamp(
        y, nu, codec.a, gamp, init_var=energy,
        use_pallas=use_pallas, with_info=with_info,
    )
    if with_info:
        ghat, info = ghat
        return jnp.sum(ghat.reshape(groups, nb, n), axis=0), info
    return jnp.sum(ghat.reshape(groups, nb, n), axis=0)
