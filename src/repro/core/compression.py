"""BQCS end-to-end gradient codec over pytrees (paper Sec. III).

Pipeline per step, per worker/pod:

    grads (pytree) --flatten+pad--> (nblocks, N) blocks
      + residual (error feedback, eq. 8)
      -> block top-S sparsify (residual out, eq. 7)
      -> project with shared A, scale alpha = sqrt(M)/||.||  (eq. 9)
      -> Lloyd-Max Q-bit encode  (eq. 10)
      -> bit-pack codes into uint32 words (the wire payload)

Wire cost per step per worker: nblocks * (M*Q bits + 32 bits for alpha)
  ~= Q/R bits per gradient entry (Sec. III-B).

The codec is stateless except for the error-feedback residual, which the
caller owns (it lives in the TrainState so it is checkpointed).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sensing, sparsify
from repro.core.quantizer import LloydMaxQuantizer, design_lloyd_max, encode, decode

__all__ = ["FedQCSConfig", "BQCSCodec", "CompressedGradient", "flatten_to_blocks", "blocks_to_tree"]


@dataclasses.dataclass(frozen=True)
class FedQCSConfig:
    """Protocol parameters shared by every worker and the PS."""

    block_size: int = 1024  # N
    reduction_ratio: int = 4  # R = N / M
    bits: int = 2  # Q
    s_ratio: float = 0.1  # S = floor(s_ratio * N) kept per block
    gamp_iters: int = 25
    gamp_components: int = 3  # L
    gamp_variance_mode: str = "exact"
    # "topk" = exact lax.top_k; "bisect" = fixed-iteration threshold search
    # (compares/reductions only).  Use "bisect" in distributed steps: XLA
    # partitions top_k's sort by REPLICATING the operand across the mesh
    # (measured: 30.5 GB/step cross-pod for qwen2-7b -- EXPERIMENTS.md #Perf
    # iteration 3c), while bisect partitions trivially.
    sparsifier: str = "topk"
    seed: int = 1234  # sensing-matrix seed (protocol constant)
    use_kernels: bool = False  # route hot paths through Pallas kernels
    wire_mode: str = "gather_codes"  # or "psum_dequant" (see DESIGN.md)
    # PS reconstruction strategy inside the distributed collectives:
    # "ae" (aggregate-and-estimate, Bussgang combine then one GAMP) or
    # "ea" (estimate-and-aggregate, per-worker Q-EM-GAMP then rho-sum).
    # "ea" needs the per-worker codes, i.e. wire_mode="gather_codes".
    recon_mode: str = "ae"

    @property
    def m(self) -> int:
        return self.block_size // self.reduction_ratio

    @property
    def s(self) -> int:
        return max(1, int(self.s_ratio * self.block_size))

    @property
    def bits_per_entry(self) -> float:
        """Q/R: wire bits per gradient entry (excl. the negligible alphas)."""
        return self.bits / self.reduction_ratio


@dataclasses.dataclass
class CompressedGradient:
    """The wire payload of one worker for one step."""

    codes: jnp.ndarray  # (nblocks, M) uint8 indices (or packed words)
    alpha: jnp.ndarray  # (nblocks,) f32 scales
    nbar: int  # original flat length (for unpadding)

    def wire_bits(self, bits: int) -> int:
        nb, m = self.codes.shape[:2]
        return nb * (m * bits + 32)


# ---------------------------------------------------------------------------
# pytree <-> blocks plumbing
# ---------------------------------------------------------------------------


def flatten_to_blocks(tree: Any, n: int, row_multiple: int = 1) -> Tuple[jnp.ndarray, Any, int]:
    """Concatenates all leaves into one vector, zero-pads to a multiple of N,
    reshapes to (nblocks, N).  ``row_multiple`` additionally pads nblocks up
    to a multiple (so the (data, model) sharding of the block view is even).
    Returns (blocks, treedef-like spec, nbar)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    nbar = flat.shape[0]
    rows = -(-nbar // n)
    rows = -(-rows // row_multiple) * row_multiple
    pad = rows * n - nbar
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(rows, n)
    shapes = [(l.shape, l.dtype) for l in leaves]
    return blocks, (treedef, shapes), nbar


def flatten_to_blocks_batched(tree: Any, n: int, row_multiple: int = 1):
    """Batched variant: every leaf carries a leading ``pods`` axis; returns
    (pods, nblocks, N) blocks plus the UNBATCHED spec (for blocks_to_tree on
    the aggregated result)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    pods = leaves[0].shape[0]
    flat = jnp.concatenate([l.reshape(pods, -1).astype(jnp.float32) for l in leaves], axis=1)
    nbar = flat.shape[1]
    rows = -(-nbar // n)
    rows = -(-rows // row_multiple) * row_multiple
    pad = rows * n - nbar
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pods, pad), flat.dtype)], axis=1)
    blocks = flat.reshape(pods, rows, n)
    shapes = [(l.shape[1:], l.dtype) for l in leaves]
    return blocks, (treedef, shapes), nbar


def blocks_to_tree(blocks: jnp.ndarray, spec: Any, nbar: int) -> Any:
    """Inverse of :func:`flatten_to_blocks`."""
    treedef, shapes = spec
    flat = blocks.reshape(-1)[:nbar]
    leaves = []
    off = 0
    for shape, dtype in shapes:
        size = int(np.prod(shape)) if shape else 1
        leaves.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# bit packing (wire format)
# ---------------------------------------------------------------------------


def pack_codes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Packs Q-bit indices into uint32 words, little-endian within the word.

    (nb, M) uint8 -> (nb, ceil(M / per_word)) uint32, per_word = 32 // bits.
    """
    per_word = 32 // bits
    nb, m = codes.shape
    pad = (-m) % per_word
    if pad:
        codes = jnp.concatenate([codes, jnp.zeros((nb, pad), codes.dtype)], axis=1)
    grouped = codes.reshape(nb, -1, per_word).astype(jnp.uint32)
    shifts = (jnp.arange(per_word, dtype=jnp.uint32) * bits)[None, None, :]
    return jnp.sum(grouped << shifts, axis=-1).astype(jnp.uint32)


def unpack_codes(words: jnp.ndarray, bits: int, m: int) -> jnp.ndarray:
    """Inverse of :func:`pack_codes` -> (nb, m) uint8."""
    per_word = 32 // bits
    shifts = (jnp.arange(per_word, dtype=jnp.uint32) * bits)[None, None, :]
    mask = jnp.uint32((1 << bits) - 1)
    out = ((words[..., None] >> shifts) & mask).astype(jnp.uint8)
    return out.reshape(words.shape[0], -1)[:, :m]


# ---------------------------------------------------------------------------
# The codec
# ---------------------------------------------------------------------------


class BQCSCodec:
    """Stateless BQCS encoder/decoder bound to a FedQCSConfig.

    The sensing matrix and quantizer are derived deterministically from the
    config, so constructing the same codec on every pod yields the same
    protocol -- no matrix ever crosses the wire.
    """

    def __init__(self, cfg: FedQCSConfig):
        self.cfg = cfg
        self.quantizer: LloydMaxQuantizer = design_lloyd_max(cfg.bits)
        key = jax.random.PRNGKey(cfg.seed)
        self._a = sensing.sensing_matrix(key, cfg.m, cfg.block_size)

    @property
    def a(self) -> jnp.ndarray:
        return self._a

    # -- encode ------------------------------------------------------------
    def compress_blocks(self, blocks: jnp.ndarray, residual: jnp.ndarray):
        """(blocks + residual) -> (codes, alpha, new_residual).  Eqs. 7-10."""
        cfg = self.cfg
        carry = blocks + residual
        if cfg.use_kernels:
            from repro.kernels import ops as kops

            sparse, new_residual = kops.block_sparsify(carry, cfg.s)
            codes, alpha = kops.bqcs_encode(sparse, self._a, self.quantizer)
        else:
            if cfg.sparsifier == "bisect":
                sparse, new_residual = sparsify.block_sparsify_threshold(carry, cfg.s)
            else:
                sparse, new_residual = sparsify.block_sparsify(carry, cfg.s)
            x, alpha = sensing.project_blocks(sparse, self._a.T)
            codes = encode(x, self.quantizer)
        return codes, alpha, new_residual

    def compress_tree(self, grads: Any, residual_blocks: jnp.ndarray):
        blocks, spec, nbar = flatten_to_blocks(grads, self.cfg.block_size)
        codes, alpha, new_res = self.compress_blocks(blocks, residual_blocks)
        return CompressedGradient(codes, alpha, nbar), spec, new_res

    def zero_residual(self, grads_like: Any) -> jnp.ndarray:
        blocks, _, _ = flatten_to_blocks(grads_like, self.cfg.block_size)
        return jnp.zeros_like(blocks)

    # -- wire --------------------------------------------------------------
    def pack(self, codes: jnp.ndarray) -> jnp.ndarray:
        return pack_codes(codes, self.cfg.bits)

    def unpack(self, words: jnp.ndarray) -> jnp.ndarray:
        return unpack_codes(words, self.cfg.bits, self.cfg.m)

    # -- decode helpers ------------------------------------------------------
    def dequantize(self, codes: jnp.ndarray) -> jnp.ndarray:
        return decode(codes, self.quantizer)
