"""BQCS end-to-end gradient codec over pytrees (paper Sec. III).

Pipeline per step, per worker/pod:

    grads (pytree) --flatten+pad--> (nblocks, N) blocks
      + residual (error feedback, eq. 8)
      -> block top-S sparsify (residual out, eq. 7)
      -> project with shared A, scale alpha = sqrt(M)/||.||  (eq. 9)
      -> codebook encode (eq. 10; Lloyd-Max / dithered-uniform / vq, see
         core/codebook.py -- the config's ``codebook`` axis)
      -> bit-pack codes into uint32 words (the wire payload)

Wire cost per step per worker: nblocks * (W*32 bits + 32 bits for alpha),
  W = ceil(n_codes / (32//Q)) packed words over n_codes = M / codebook.dim
  index lanes of width Q = ceil(log2 levels) -- ~= Q/(dim*R) bits per
  gradient entry (Sec. III-B), exactly M*Q bits for the scalar families
  whenever Q divides 32 (CompressedGradient.wire_bits derives this from the
  actual word count).

The codec is stateless except for the error-feedback residual, which the
caller owns (it lives in the TrainState so it is checkpointed).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sensing, sparsify
from repro.core.codebook import Codebook, index_bits, make_codebook
from repro.core.layout import GradientLayout

__all__ = [
    "FedQCSConfig",
    "BQCSCodec",
    "CompressedGradient",
    "GradientLayout",
    "flatten_to_blocks",
    "blocks_to_tree",
    "pack_codes",
    "unpack_codes",
    "decode_packed",
    "packed_width",
]


@dataclasses.dataclass(frozen=True)
class FedQCSConfig:
    """Protocol parameters shared by every worker and the PS."""

    block_size: int = 1024  # N
    reduction_ratio: int = 4  # R = N / M
    bits: int = 2  # Q: index bits per code (scalar: per measurement)
    # Quantizer codebook family (core/codebook.py): "lloyd_max" (the paper's
    # Sec. III-A scalar quantizer), "dithered_uniform" (shared-seed dither),
    # or "vq" (FedVQCS-style vq_dim-dimensional vector codebook, one Q-bit
    # code per vq_dim measurements -> Q/vq_dim bits per measurement).
    codebook: str = "lloyd_max"
    vq_dim: int = 2  # d (vq only); must divide M
    vq_levels: int = 0  # vq codebook size L; 0 = 2**bits
    s_ratio: float = 0.1  # S = floor(s_ratio * N) kept per block
    gamp_iters: int = 25
    gamp_components: int = 3  # L
    gamp_variance_mode: str = "exact"
    # "topk" = exact lax.top_k; "bisect" = fixed-iteration threshold search
    # (compares/reductions only).  Use "bisect" in distributed steps: XLA
    # partitions top_k's sort by REPLICATING the operand across the mesh
    # (measured: 30.5 GB/step cross-pod for qwen2-7b -- EXPERIMENTS.md #Perf
    # iteration 3c), while bisect partitions trivially.
    sparsifier: str = "topk"
    seed: int = 1234  # sensing-matrix seed (protocol constant)
    use_kernels: bool = False  # route hot paths through Pallas kernels
    wire_mode: str = "gather_codes"  # or "psum_dequant" (see DESIGN.md)
    # PS reconstruction strategy inside the distributed collectives:
    # "ae" (aggregate-and-estimate, Bussgang combine then one GAMP) or
    # "ea" (estimate-and-aggregate, per-worker Q-EM-GAMP then rho-sum).
    # "ea" needs the per-worker codes, i.e. wire_mode="gather_codes".
    recon_mode: str = "ae"
    # PS-side EA decode chunking (DESIGN.md #Recon-engine): the K*nb block
    # problems stream through a lax.scan in chunks of this many rows, so the
    # GAMP state (and, on the XLA path, the unpacked code view) never
    # materializes for more than one chunk at a time.  0 = monolithic batch.
    recon_chunk: int = 0

    def validate(self) -> "FedQCSConfig":
        """Raises ValueError on incoherent knob combinations, with the fix
        named in the message.  Called by ``BQCSCodec`` (so ``make_codec``
        rejects a bad protocol at construction, not rounds later inside a
        collective); returns self so it chains.  Note R need not divide N --
        M = floor(N / R) is the paper's own Sec. VI blocking (1591 // 3)."""
        if self.block_size < 1 or self.reduction_ratio < 1:
            raise ValueError(
                f"block_size={self.block_size} and reduction_ratio="
                f"{self.reduction_ratio} must both be >= 1"
            )
        if self.m < 1:
            raise ValueError(
                f"reduction_ratio={self.reduction_ratio} leaves no measurements "
                f"(M = {self.block_size} // {self.reduction_ratio} = 0); use "
                f"reduction_ratio <= block_size"
            )
        if not (1 <= self.bits <= 8):
            raise ValueError(f"bits must be in [1, 8], got {self.bits}")
        if not (0.0 < self.s_ratio <= 1.0):
            raise ValueError(f"s_ratio must be in (0, 1], got {self.s_ratio}")
        if self.wire_mode not in ("gather_codes", "psum_dequant"):
            raise ValueError(
                f"unknown wire_mode {self.wire_mode!r} "
                "(choose 'gather_codes' or 'psum_dequant')"
            )
        if self.recon_mode not in ("ae", "ea"):
            raise ValueError(
                f"unknown recon_mode {self.recon_mode!r} (choose 'ae' or 'ea')"
            )
        if self.recon_mode == "ea" and self.wire_mode != "gather_codes":
            raise ValueError(
                "recon_mode='ea' needs the per-worker codes on the PS side, "
                "i.e. wire_mode='gather_codes' (see DESIGN.md); "
                f"got wire_mode={self.wire_mode!r}"
            )
        if self.recon_chunk < 0:
            raise ValueError(f"recon_chunk must be >= 0, got {self.recon_chunk}")
        if self.gamp_variance_mode not in ("exact", "scalar"):
            raise ValueError(
                f"unknown gamp_variance_mode {self.gamp_variance_mode!r} "
                "(choose 'exact' or 'scalar')"
            )
        if self.codebook == "vq" and self.m % self.vq_dim:
            raise ValueError(
                f"vq_dim={self.vq_dim} must divide M={self.m} "
                f"(= block_size // reduction_ratio); pick a vq_dim that "
                f"divides {self.m} or adjust the blocking"
            )
        return self

    @property
    def m(self) -> int:
        return self.block_size // self.reduction_ratio

    @property
    def s(self) -> int:
        return max(1, int(self.s_ratio * self.block_size))

    @property
    def bits_per_entry(self) -> float:
        """Wire index bits per gradient entry (excl. the negligible alphas):
        Q/R for the scalar families, ceil(log2 L)/(d*R) for vq."""
        if self.codebook == "vq":
            width = index_bits(self.vq_levels or (1 << self.bits))
            return width / (self.vq_dim * self.reduction_ratio)
        return self.bits / self.reduction_ratio


@dataclasses.dataclass
class CompressedGradient:
    """The wire payload of one worker for one step.

    ``codes`` is *packed*: uint32 words holding Q-bit codebook indices in
    the canonical lane-group layout (see :func:`pack_codes`), not the uint8
    index view -- what crosses the wire is what this object carries.  The
    words cover ``n_codes = M / codebook.dim`` index lanes of width
    ``bits = ceil(log2 levels)`` each (scalar families: n_codes == M).
    """

    codes: jnp.ndarray  # (nblocks, W) uint32 words, W = packed_width(n_codes, Q)
    alpha: jnp.ndarray  # (nblocks,) f32 scales
    nbar: int  # original flat length (for unpadding)
    m: int  # measurements per block
    bits: int  # Q: index width on the wire

    def wire_bits(self) -> int:
        """Actual bits on the wire, derived from the true packed word count:
        nb * (W * 32 + 32 for alpha).  Counting ``M * Q`` instead would be
        wrong whenever Q does not divide 32 -- Q=3 packs 10 codes per word,
        so each word carries 2 slack bits that still cross the wire."""
        nb, w = self.codes.shape[:2]
        return nb * (w * 32 + 32)


# ---------------------------------------------------------------------------
# pytree <-> blocks plumbing
# ---------------------------------------------------------------------------


def flatten_to_blocks(tree: Any, n: int, row_multiple: int = 1) -> Tuple[jnp.ndarray, Any, int]:
    """Concatenates all leaves into one vector, zero-pads to a multiple of N,
    reshapes to (nblocks, N).  ``row_multiple`` additionally pads nblocks up
    to a multiple (so the (data, model) sharding of the block view is even).
    Returns (blocks, spec, nbar) where the spec is now a monolithic
    :class:`~repro.core.layout.GradientLayout` (bit-identical block output;
    geometry -- sizes, offsets, nbar -- computed in Python ints, see
    core/layout.py for the int32 guard)."""
    layout = GradientLayout.monolithic(tree, n, row_multiple=row_multiple)
    return layout.to_blocks(tree), layout, layout.nbar


def flatten_to_blocks_batched(tree: Any, n: int, row_multiple: int = 1):
    """Batched variant: every leaf carries a leading ``pods`` axis; returns
    (pods, nblocks, N) blocks plus the UNBATCHED spec (for blocks_to_tree on
    the aggregated result)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple((tuple(l.shape[1:]), l.dtype) for l in leaves)
    layout = GradientLayout.from_shapes(treedef, shapes, n, row_multiple=row_multiple)
    return layout.to_blocks_batched(tree), layout, layout.nbar


def blocks_to_tree(blocks: jnp.ndarray, spec: Any, nbar: int | None = None) -> Any:
    """Inverse of :func:`flatten_to_blocks`.  ``spec`` is a
    :class:`~repro.core.layout.GradientLayout` (the ``nbar`` argument is then
    redundant and ignored -- the layout knows its own unpadding) or the
    legacy ``(treedef, shapes)`` tuple."""
    if isinstance(spec, GradientLayout):
        return spec.tree_from_blocks(blocks)
    treedef, shapes = spec
    flat = blocks.reshape(-1)[:nbar]
    leaves = []
    off = 0
    for shape, dtype in shapes:
        size = int(np.prod([int(d) for d in shape], dtype=object)) if shape else 1
        leaves.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# bit packing (wire format)
# ---------------------------------------------------------------------------


def packed_width(m: int, bits: int) -> int:
    """uint32 words per block row on the wire: W = ceil(lanes / (32 // Q)).
    ``m`` counts *code lanes* -- measurements for the scalar families,
    M / d vector-codebook indices for vq -- and ``bits`` is the per-code
    index width ceil(log2 levels)."""
    return -(-m // (32 // bits))


def pack_codes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Packs Q-bit indices into uint32 words -- the canonical wire layout.

    Lane-group interleaved (DESIGN.md #Wire-format): with per_word = 32 //
    bits and W = ceil(M / per_word), measurement ``m`` lives in word
    ``m % W`` at bit offset ``(m // W) * bits``, i.e. word ``w`` holds
    measurements ``{w, W + w, 2W + w, ...}``.  This is the layout the fused
    encoder kernel emits with contiguous static lane-group shifts (a
    consecutive-codes-per-word layout would need an in-kernel transpose).

    (nb, M) uint8 -> (nb, W) uint32.
    """
    per_word = 32 // bits
    nb, m = codes.shape
    w = packed_width(m, bits)
    pad = w * per_word - m
    if pad:
        codes = jnp.concatenate([codes, jnp.zeros((nb, pad), codes.dtype)], axis=1)
    grouped = codes.reshape(nb, per_word, w).astype(jnp.uint32)
    shifts = (jnp.arange(per_word, dtype=jnp.uint32) * bits)[None, :, None]
    # Disjoint bit ranges per group, so the OR-accumulate is a plain sum.
    return jnp.sum(grouped << shifts, axis=1).astype(jnp.uint32)


def _unpack_groups(words: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(..., W) uint32 -> (..., per_word, W) uint32 lane groups (shift/mask)."""
    per_word = 32 // bits
    shifts = (jnp.arange(per_word, dtype=jnp.uint32) * bits).reshape(
        (1,) * (words.ndim - 1) + (per_word, 1)
    )
    mask = jnp.uint32((1 << bits) - 1)
    return (words[..., None, :] >> shifts) & mask


def unpack_codes(words: jnp.ndarray, bits: int, m: int) -> jnp.ndarray:
    """Inverse of :func:`pack_codes`: (..., W) uint32 -> (..., m) uint8.
    Leading batch dims pass through (so stacked (K, nb, W) payloads unpack
    without a vmap)."""
    out = _unpack_groups(words, bits).astype(jnp.uint8)
    return out.reshape(words.shape[:-1] + (-1,))[..., :m]


def decode_packed(
    words: jnp.ndarray, bits: int, m: int, levels: jnp.ndarray
) -> jnp.ndarray:
    """Dequantize straight from the packed wire words: (..., W) uint32 ->
    (..., m) f32 reconstruction levels.  The level lookup indexes the
    shift/masked lane groups directly, so the (..., M) uint8 index view is
    never materialized (the shifted int temporaries fuse into the gather)."""
    idx = _unpack_groups(words, bits).astype(jnp.int32)
    deq = levels[idx]  # (..., per_word, W)
    return deq.reshape(words.shape[:-1] + (-1,))[..., :m]


# ---------------------------------------------------------------------------
# The codec
# ---------------------------------------------------------------------------


_KERNEL_BYPASS_WARNED = False


def _warn_kernel_bypass_once(cfg: FedQCSConfig) -> None:
    """use_kernels=True with gamp_variance_mode='exact' (the default) keeps
    the GAMP solves on the XLA path -- the fused kernels implement
    scalar-variance GAMP only -- which used to happen silently.  Name the
    conflict once per process; the encode kernels are unaffected."""
    global _KERNEL_BYPASS_WARNED
    if _KERNEL_BYPASS_WARNED:
        return
    if cfg.use_kernels and cfg.gamp_variance_mode == "exact":
        _KERNEL_BYPASS_WARNED = True
        warnings.warn(
            "FedQCSConfig(use_kernels=True, gamp_variance_mode='exact'): the "
            "fused GAMP kernels implement scalar-variance GAMP, so every GAMP "
            "reconstruction will keep the pure-XLA path despite "
            "use_kernels=True (the fused encoder still runs).  Set "
            "gamp_variance_mode='scalar' to route reconstruction through the "
            "kernels (see DESIGN.md #Kernels).",
            UserWarning,
            stacklevel=3,
        )


class BQCSCodec:
    """Stateless BQCS encoder/decoder bound to a FedQCSConfig.

    The sensing matrix and quantizer codebook are derived deterministically
    from the config, so constructing the same codec on every pod yields the
    same protocol -- no matrix or table ever crosses the wire.
    """

    def __init__(self, cfg: FedQCSConfig):
        self.cfg = cfg.validate()
        _warn_kernel_bypass_once(cfg)
        self.codebook: Codebook = make_codebook(cfg)
        key = jax.random.PRNGKey(cfg.seed)
        self._a = sensing.sensing_matrix(key, cfg.m, cfg.block_size)

    @property
    def a(self) -> jnp.ndarray:
        return self._a

    @property
    def quantizer(self) -> Codebook:
        """Back-compat alias: the codebook duck-types the old
        LloydMaxQuantizer surface (bits/gamma/psi/kappa/jnp_levels/
        jnp_thresholds for scalar families)."""
        return self.codebook

    @property
    def n_codes(self) -> int:
        """Index lanes per block on the wire: M / codebook.dim."""
        return self.codebook.n_codes(self.cfg.m)

    # -- encode ------------------------------------------------------------
    def compress_blocks_packed(
        self, blocks: jnp.ndarray, residual: jnp.ndarray, s: int | None = None
    ):
        """(blocks + residual) -> (words, alpha, new_residual).  Eqs. 7-10
        plus the wire packing: ``words`` is the (nb, W) uint32 payload in the
        canonical :func:`pack_codes` layout -- this is what crosses the wire.

        With ``use_kernels`` the whole pipeline (error-feedback add, top-S,
        projection, quantization, packing) is ONE fused Pallas pass; the XLA
        path composes the stage functions and packs last.  ``s`` overrides
        the config's global top-S budget (per-segment sparsity budgets of a
        :class:`GradientLayout`); every stage is per-block, so any row
        partition of ``blocks`` encodes bit-identically to the whole.
        """
        cfg = self.cfg
        if cfg.use_kernels:
            from repro.kernels import ops as kops

            return kops.bqcs_encode_fused(
                blocks, residual, self._a, self.codebook, cfg.s if s is None else s
            )
        codes, alpha, new_residual = self._compress_blocks_xla(blocks, residual, s)
        return pack_codes(codes, self.codebook.bits), alpha, new_residual

    def compress_blocks(
        self, blocks: jnp.ndarray, residual: jnp.ndarray, s: int | None = None
    ):
        """(blocks + residual) -> (codes, alpha, new_residual).  Eqs. 7-10.

        Unpacked uint8-index view of :meth:`compress_blocks_packed` for
        PS-side math and analysis; the kernel route still runs the fused
        single-pass encoder and unpacks the words it emits.
        """
        cfg = self.cfg
        if cfg.use_kernels:
            words, alpha, new_residual = self.compress_blocks_packed(blocks, residual, s)
            return self.unpack(words), alpha, new_residual
        return self._compress_blocks_xla(blocks, residual, s)

    def _compress_blocks_xla(
        self, blocks: jnp.ndarray, residual: jnp.ndarray, s: int | None = None
    ):
        cfg = self.cfg
        s = cfg.s if s is None else s
        carry = blocks + residual
        if cfg.sparsifier == "bisect":
            sparse, new_residual = sparsify.block_sparsify_threshold(carry, s)
        else:
            sparse, new_residual = sparsify.block_sparsify(carry, s)
        x, alpha = sensing.project_blocks(sparse, self._a.T)
        return self.codebook.encode(x), alpha, new_residual

    def layout_for(self, grads_like: Any, per_tensor: bool = False, **kwargs) -> GradientLayout:
        """Builds this codec's block layout for a gradient tree: monolithic
        (the default wire geometry, bit-identical to the pre-layout flatten)
        or per-tensor (independently padded leaf segments -- the streaming
        geometry; ``kwargs`` forward to :meth:`GradientLayout.per_tensor`)."""
        n = self.cfg.block_size
        if per_tensor:
            return GradientLayout.per_tensor(grads_like, n, **kwargs)
        return GradientLayout.monolithic(grads_like, n, **kwargs)

    def compress_tree(
        self, grads: Any, residual_blocks: jnp.ndarray,
        layout: GradientLayout | None = None,
    ):
        """Whole-tree encode: blocks per ``layout`` (default: monolithic --
        the pre-layout wire, bit-identical), one encoder pass over the full
        grid.  Per-tensor layouts with uniform sparsity also take this path;
        per-segment ``s`` budgets force the segment loop (same wire bits,
        see :meth:`compress_tree_streamed`)."""
        cfg = self.cfg
        if layout is None:
            layout = GradientLayout.monolithic(grads, cfg.block_size)
        seg_s = layout.segment_s(cfg.s)
        if any(s != cfg.s for s in seg_s):
            return self.compress_tree_streamed(grads, residual_blocks, layout)
        words, alpha, new_res = self.compress_blocks_packed(
            layout.to_blocks(grads), residual_blocks
        )
        payload = CompressedGradient(
            words, alpha, layout.nbar, cfg.m, self.codebook.bits
        )
        return payload, layout, new_res

    def compress_tree_streamed(
        self, grads: Any, residual_blocks: jnp.ndarray, layout: GradientLayout
    ):
        """Segment-streamed encode: drives the (fused) encoder one layout
        segment at a time -- build segment i's blocks from its own leaves,
        encode (with its own top-S budget), carry its error-feedback residual
        rows, discard -- so peak live encoder memory is bounded by the
        LARGEST segment's blocks, not the whole model
        (``layout.encoder_live_bytes``).  Every encoder stage is per-block,
        so the concatenated wire output is BIT-IDENTICAL to the one-pass
        :meth:`compress_tree` over the same layout.

        Returns the same ``(CompressedGradient, layout, new_residual)``
        triple; ``residual_blocks`` is the full ``(rows, N)`` grid and comes
        back the same shape."""
        cfg = self.cfg
        words_parts, alpha_parts, res_parts = [], [], []
        for seg, seg_blocks in layout.iter_segment_blocks(grads):
            w, al, res = self.compress_blocks_packed(
                seg_blocks,
                residual_blocks[seg.row_slice],
                s=seg.s if seg.s is not None else cfg.s,
            )
            words_parts.append(w)
            alpha_parts.append(al)
            res_parts.append(res)
        words = jnp.concatenate(words_parts, axis=0)
        alpha = jnp.concatenate(alpha_parts, axis=0)
        new_res = jnp.concatenate(res_parts, axis=0)
        payload = CompressedGradient(
            words, alpha, layout.nbar, cfg.m, self.codebook.bits
        )
        return payload, layout, new_res

    def zero_residual(self, grads_like: Any, layout: GradientLayout | None = None) -> jnp.ndarray:
        if layout is None:
            layout = GradientLayout.monolithic(grads_like, self.cfg.block_size)
        return jnp.zeros((layout.rows, layout.n), jnp.float32)

    # -- wire --------------------------------------------------------------
    def pack(self, codes: jnp.ndarray) -> jnp.ndarray:
        return pack_codes(codes, self.codebook.bits)

    def unpack(self, words: jnp.ndarray) -> jnp.ndarray:
        """(..., W) words -> (..., n_codes) index view (n_codes = M / dim)."""
        return unpack_codes(words, self.codebook.bits, self.n_codes)

    # -- decode helpers ------------------------------------------------------
    def dequantize(self, codes: jnp.ndarray) -> jnp.ndarray:
        return self.codebook.decode(codes, self.cfg.m)

    def dequantize_packed(self, words: jnp.ndarray) -> jnp.ndarray:
        """Reconstruction values straight from packed wire words (..., W) --
        the index view never materializes on the scalar families (see
        :func:`decode_packed`); vq unpacks indices then reads centroids."""
        return self.codebook.decode_packed(words, self.cfg.m)

    # -- decode health -------------------------------------------------------
    def clip_saturation(self, codes_or_words: jnp.ndarray, packed: bool = True):
        """Fraction of code lanes pinned at an extreme codebook level --
        the quantizer clip-saturation rate (repro.obs decode health).

        Scalar families order their levels, so index 0 / L-1 means the input
        overshot the quantizer's support: a rising rate flags an alpha
        scaling (or Lloyd-Max fit) losing the gradient's tails.  Vector
        codebooks have no level order, so vq reports a constant 0.  Jit-safe
        scalar; padding lanes in packed words are excluded by the unpack
        slice."""
        q = self.codebook
        if q.dim != 1:
            return jnp.zeros(())
        idx = self.unpack(codes_or_words) if packed else codes_or_words
        extreme = (idx == 0) | (idx == q.n_levels - 1)
        return jnp.mean(extreme.astype(jnp.float32))
