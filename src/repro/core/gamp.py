"""EM-GAMP / Q-EM-GAMP (paper Procedure 2) -- batched over gradient blocks.

This is the PS-side reconstruction engine.  Two output channels:

  * quantized (Q-EM-GAMP, estimate-and-aggregate): the observation is the code
    index; for scalar codebooks (Lloyd-Max, dithered-uniform) the channel
    posterior is a truncated-Gaussian moment match between the codebook's
    decision thresholds (eqs. 12-16), with any shared-seed dither applied as
    a per-lane shift of the cell edges; for vector codebooks (vq) no scalar
    cell exists and the solve falls back to the Bussgang-linearized AWGN
    channel built from the codebook's (gamma, psi) -- eqs. 23-24 with K=1.
  * awgn (EM-GAMP, aggregate-and-estimate): the observation is the Bussgang
    linearized aggregate q_tilde = A g + d, d ~ N(0, nu I) (eqs. 23-24);
    channel posterior is the Gaussian product rule.

The input channel is the Bernoulli Gaussian-mixture prior (eq. 11) with
EM-learned hyperparameters theta = (lam0, {lam_l, mu_l, phi_l}) (eq. 17).

Everything is batched over the leading ``nblocks`` axis so each GAMP step is
two (or four, in exact-variance mode) ``(nblocks, N) x (N, M)`` GEMMs -- the
MXU-friendly layout.  A single sensing matrix A is shared by every block
(protocol property, see sensing.py).

Variance modes:
  * "exact":   per-entry nu_p / nu_r via GEMMs with A**2 (paper Procedure 2).
  * "scalar":  iid-ensemble approximation |A_mn|^2 ~= 1/M, reducing the
               variance GEMMs to row-sums (2 GEMMs per iteration instead of 4).
               This is the standard large-system GAMP simplification and is the
               production default (see EXPERIMENTS.md #Perf).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.codebook import as_codebook

__all__ = [
    "GampConfig",
    "GampInfo",
    "GampState",
    "qem_gamp",
    "qem_gamp_packed",
    "em_gamp",
    "make_init_theta",
    "tau_tables",
    "block_prior_energy",
    "norm_guard",
    "gamp_health",
]

_EPS = 1e-12
_TRUNC_CLIP = 9.0  # standardize-clip for truncated-normal stability in f32


@dataclasses.dataclass(frozen=True)
class GampConfig:
    """Hyperparameters of the (Q-)EM-GAMP solver."""

    n_components: int = 3  # L, Gaussian-mixture components
    iters: int = 25  # I_GAMP (fixed trip count: jit/scan-friendly)
    tol: float = 1e-5  # tau_GAMP early-freeze tolerance
    damping: float = 1.0  # 1.0 = undamped (paper); <1 damps ghat updates
    variance_mode: str = "exact"  # "exact" | "scalar"
    em: bool = True  # run EM hyperparameter learning (step 15)
    lam0_init: float = 0.9  # initial zero-probability (paper Sec. VI)
    # Early termination: exit the GAMP loop (lax.while_loop) as soon as every
    # block in the batch has hit the early-freeze tolerance, instead of
    # running the full static trip count.  Converged blocks are frozen either
    # way, so the outputs are identical -- this only changes how many no-op
    # iterations are spent after the last block freezes.  Keep False inside
    # distributed steps (data-dependent trip counts make per-pod work ragged,
    # DESIGN.md #Kernels); the chunked PS decode (DESIGN.md #Recon-engine)
    # turns it on so each chunk stops at its own slowest block.
    early_stop: bool = False


class GampState(tuple):
    """(ghat, nu_g, shat, theta, converged, iters) -- opaque scan carry."""


class GampInfo(NamedTuple):
    """Per-block decode-health counters of one GAMP solve (jit-safe aux).

    converged: (nb,) bool -- early-freeze flag (True = the block hit the
      tolerance before the trip cap; dead alpha == 0 rows count converged).
    iters: (nb,) int32 -- iterations the block was live for (its
      iterations-to-converge when the flag is set, else the trip cap).
    Kernel-path solves have no freeze signal (fixed trip count), so their
    info reports the static ``cfg.iters`` with every block converged --
    callers that need true counts keep the XLA path.
    """

    converged: jnp.ndarray
    iters: jnp.ndarray

    @staticmethod
    def static(nb: int, iters: int) -> "GampInfo":
        """The fixed-trip-count placeholder the kernel routes report."""
        return GampInfo(
            converged=jnp.ones((nb,), bool),
            iters=jnp.full((nb,), iters, jnp.int32),
        )


# ---------------------------------------------------------------------------
# Prior (input channel): Bernoulli Gaussian-mixture.
# ---------------------------------------------------------------------------


def make_init_theta(nblocks: int, L: int, sigma: jnp.ndarray, lam0: float = 0.9):
    """Paper's initialization (Sec. VI): mixture means spread over the signal
    range, uniform weights on the non-zero part.

    Args:
      nblocks: number of blocks (leading batch axis).
      L: number of Gaussian components.
      sigma: (nblocks,) per-block signal scale (sqrt of per-entry energy).
      lam0: initial sparsity (P[g == 0]).
    """
    sigma = jnp.asarray(sigma, jnp.float32)
    gmax = 3.0 * sigma[:, None]  # +-3 sigma covers the init range
    gmin = -gmax
    ls = jnp.arange(1, L + 1, dtype=jnp.float32)[None, :]
    mu = gmin + (2.0 * ls - 1.0) / (2.0 * L) * (gmax - gmin)
    phi = jnp.broadcast_to(((gmax - gmin) / L) ** 2 / 12.0, mu.shape)
    lam = jnp.full((nblocks, L), (1.0 - lam0) / L, jnp.float32)
    lam0v = jnp.full((nblocks,), lam0, jnp.float32)
    return (lam0v, lam, mu, phi)


def _gaussian_pdf(x, mean, var):
    var = jnp.maximum(var, _EPS)
    return jnp.exp(-0.5 * jnp.square(x - mean) / var) / jnp.sqrt(2.0 * jnp.pi * var)


def _input_channel(rhat, nu_r, theta):
    """Posterior mean/var of g given rhat = g + N(0, nu_r), g ~ BG(theta).

    Returns (ghat, nu_g, lam_post0, lam_post, mu_post, phi_post) -- the
    posterior pieces are reused by the EM update (eq. 17).
    Shapes: rhat/nu_r (nb, N); theta components (nb,)/(nb, L).
    """
    lam0, lam, mu, phi = theta
    nu_r = jnp.maximum(nu_r, _EPS)
    r = rhat[..., None]  # (nb, N, 1)
    v = nu_r[..., None]  # (nb, N, 1)
    muc = mu[:, None, :]  # (nb, 1, L)
    phic = phi[:, None, :]
    lamc = lam[:, None, :]
    beta0 = lam0[:, None] * _gaussian_pdf(rhat, 0.0, nu_r)  # (nb, N)
    beta = lamc * _gaussian_pdf(r, muc, v + phic)  # (nb, N, L)
    denom = jnp.maximum(beta0 + jnp.sum(beta, axis=-1), _EPS)
    lam_post0 = beta0 / denom
    lam_post = beta / denom[..., None]
    mu_post = (r * phic + muc * v) / jnp.maximum(v + phic, _EPS)
    phi_post = v * phic / jnp.maximum(v + phic, _EPS)
    ghat = jnp.sum(lam_post * mu_post, axis=-1)
    second = jnp.sum(lam_post * (phi_post + jnp.square(mu_post)), axis=-1)
    nu_g = jnp.maximum(second - jnp.square(ghat), _EPS)
    return ghat, nu_g, lam_post0, lam_post, mu_post, phi_post


def _em_update(theta, lam_post0, lam_post, mu_post, phi_post):
    """EM hyperparameter refresh (step 15 / eq. 17), batched per block."""
    n = lam_post.shape[1]
    lam0_new = jnp.mean(lam_post0, axis=1)
    lam_sum = jnp.sum(lam_post, axis=1)  # (nb, L)
    lam_new = lam_sum / n
    safe = jnp.maximum(lam_sum, _EPS)
    mu_new = jnp.sum(lam_post * mu_post, axis=1) / safe
    # The M-step variance is the posterior scatter around the REFRESHED mean
    # (the same-step mu_new, eq. 17) -- scattering around the previous mean
    # adds (mu_new - mu_old)^2 of spurious spread to every component, biasing
    # phi upward each EM step.
    phi_new = (
        jnp.sum(lam_post * (jnp.square(mu_new[:, None, :] - mu_post) + phi_post), axis=1)
        / safe
    )
    # Renormalize weights to sum to one (guards fp drift) and keep every
    # weight strictly inside (0, 1): a component collapsing to exactly zero
    # can never be revived by EM and destabilizes the posterior ratios.
    lam0_new = jnp.clip(lam0_new, 1e-6, 1.0 - 1e-6)
    lam_new = jnp.maximum(lam_new, 1e-8)
    total = lam0_new + jnp.sum(lam_new, axis=-1)
    total = jnp.maximum(total, _EPS)
    return (lam0_new / total, lam_new / total[:, None], mu_new, jnp.maximum(phi_new, _EPS))


# ---------------------------------------------------------------------------
# Output channels.
# ---------------------------------------------------------------------------


def _trunc_z(ac, bc):
    """Bin mass Phi(bc) - Phi(ac) (ac <= bc), accurate in BOTH tails in f32.

    The naive difference of CDFs cancels catastrophically once the bin sits
    entirely in a tail: Phi(5) and Phi(7) agree to ~1e-7 absolute, which is
    the f32 resolution near 1.0, so one-sided bins beyond ~4.5 sd lose all
    signal well BEFORE the far-tail fallback takes over at _TRUNC_CLIP sds.
    Complementary erfc forms keep the mass as a difference of *small*
    numbers: upper tail (ac > 0) uses Phic(ac) - Phic(bc); everything else
    uses Phi as 0.5 erfc(-x/sqrt2), exact for the lower tail and within one
    ulp-of-1 for straddling bins (where z is O(1) anyway).
    """
    inv_sqrt2 = 1.0 / jnp.sqrt(2.0).astype(ac.dtype)
    z_up = 0.5 * (jax.lax.erfc(ac * inv_sqrt2) - jax.lax.erfc(bc * inv_sqrt2))
    z_dn = 0.5 * (jax.lax.erfc(-bc * inv_sqrt2) - jax.lax.erfc(-ac * inv_sqrt2))
    return jnp.where(ac > 0, z_up, z_dn)


def _npdf(x):
    return jnp.exp(-0.5 * jnp.square(x)) / jnp.sqrt(2.0 * jnp.pi).astype(x.dtype)


def _quantized_channel(phat, nu_p, codes, lo_tau, hi_tau, shift=None):
    """Truncated-Gaussian posterior of x ~ N(phat, nu_p) given
    x in (lo_tau[code] - shift, hi_tau[code] - shift]  (eqs. 12-16).

    ``shift`` is the codebook's per-lane subtractive dither (or None): the
    encoder observed ``x + u`` in the bin, so x itself lies in the bin
    translated by -u -- the exact channel applies to the dithered-uniform
    family with nothing but this edge translation.

    Numerically hardened: when the prior N(phat, nu_p) puts ~zero mass in the
    observed bin (|standardized boundary| large), the exact ratio formulas
    lose all signal in f32 (0/0 -> "no correction"), which is a positive
    feedback loop that diverges GAMP.  In that regime the true posterior
    concentrates at the bin boundary nearest to phat, so we fall back to
    projecting phat into the bin with a small tail variance ~ nu_p / a^2 --
    the correct asymptotic truncated-normal moments.
    """
    nu_p = jnp.maximum(nu_p, _EPS)
    lo = lo_tau[codes.astype(jnp.int32)]
    hi = hi_tau[codes.astype(jnp.int32)]
    if shift is not None:
        lo = lo - shift
        hi = hi - shift
    return trunc_channel_moments(phat, nu_p, lo, hi)


def trunc_channel_moments(phat, nu_p, lo, hi):
    """Truncated-normal moment match on precomputed per-entry bin edges
    (the body of _quantized_channel after the code->edge lookup).  Shared
    with the fused kernel (kernels/qgamp_step.py), which fetches lo/hi via a
    one-hot contraction instead of a gather; everything from here on is
    plain jnp and must stay the single source of the channel numerics.
    nu_p must already be clamped positive.
    """
    sd = jnp.sqrt(nu_p)
    a = (lo - phat) / sd
    b = (hi - phat) / sd
    # Far-tail detection: the bin lies ENTIRELY > TRUNC_CLIP sds to one side
    # of phat (a > clip: whole bin above; b < -clip: whole bin below).  A
    # min(|a|,|b|) > clip test would also fire when phat sits *inside* a wide
    # bin (a < -clip < clip < b) -- there the true posterior is ~ the prior,
    # and the fallback's collapsed tail variance nu_p/amin^2 would overweight
    # shat and risk divergence (the exact branch handles that case fine:
    # z ~ 1, ratios ~ 0).
    far = (a > _TRUNC_CLIP) | (b < -_TRUNC_CLIP)
    ac = jnp.clip(a, -_TRUNC_CLIP, _TRUNC_CLIP)
    bc = jnp.clip(b, -_TRUNC_CLIP, _TRUNC_CLIP)
    z = jnp.maximum(_trunc_z(ac, bc), 1e-30)
    pa, pb = _npdf(ac), _npdf(bc)
    ratio1 = (pa - pb) / z
    ratio2 = (ac * pa - bc * pb) / z
    xpost_exact = phat + sd * ratio1
    nu_exact = nu_p * jnp.maximum(1.0 + ratio2 - jnp.square(ratio1), 1e-8)
    # Asymptotic fallback: mean just inside the nearest boundary, tail var.
    amin = jnp.minimum(jnp.abs(a), jnp.abs(b))
    edge = jnp.clip(phat, lo, hi)  # projection onto the bin
    inward = jnp.where(phat < lo, 1.0, -1.0)  # direction into the bin
    xpost_far = edge + inward * sd / jnp.maximum(amin, 1.0)
    nu_far = nu_p / jnp.maximum(jnp.square(amin), 1.0)
    xpost = jnp.where(far, xpost_far, xpost_exact)
    nu_x = jnp.where(far, nu_far, nu_exact)
    # Posterior variance can never exceed the prior variance.
    nu_x = jnp.minimum(nu_x, nu_p)
    return xpost, nu_x


def _awgn_channel(phat, nu_p, y, nu_d):
    """Gaussian product posterior for y = x + N(0, nu_d) (paper Sec. IV-B)."""
    nu_p = jnp.maximum(nu_p, _EPS)
    nu_d = jnp.maximum(nu_d, _EPS)
    xpost = (phat * nu_d + y * nu_p) / (nu_p + nu_d)
    nu_x = nu_p * nu_d / (nu_p + nu_d)
    return xpost, nu_x


# ---------------------------------------------------------------------------
# Protocol constants shared with the fused-kernel drivers (kernels/ops.py).
# These three definitions ARE the kernel/XLA equivalence contract -- keep the
# single source of truth here.
# ---------------------------------------------------------------------------


def tau_tables(taus: jnp.ndarray):
    """Interior scalar-codebook thresholds (L - 1,) -> (lo_tau, hi_tau)
    bin-edge tables (L,) with +-4*_TRUNC_CLIP sentinels standing in for
    +-inf (Lloyd-Max and dithered-uniform alike)."""
    big = jnp.asarray([4.0 * _TRUNC_CLIP], jnp.float32)
    taus = jnp.asarray(taus, jnp.float32)
    return jnp.concatenate([-big, taus]), jnp.concatenate([taus, big])


def block_prior_energy(alpha: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Per-entry prior energy from the transmitted scale:
    E[g_n^2] = ||g||^2 / N = M / (N alpha^2); 1.0 for dead blocks."""
    alive = alpha > 0
    safe = jnp.where(alive, alpha, 1.0)
    return jnp.where(alive, m / (n * jnp.square(safe)), 1.0)


def norm_guard(ghat: jnp.ndarray, exp_norm: jnp.ndarray) -> jnp.ndarray:
    """Clip a reconstructed block to 2x its expected norm: a diverged AMP
    fixed point can only manifest as an inflated estimate, so this protects
    the rare per-block divergence without touching converged blocks."""
    est_norm = jnp.linalg.norm(ghat, axis=-1)
    scale = jnp.minimum(1.0, 2.0 * exp_norm / jnp.maximum(est_norm, 1e-30))
    return ghat * scale[:, None]


# ---------------------------------------------------------------------------
# The GAMP loop.
# ---------------------------------------------------------------------------


def _gamp_run(
    out_channel,
    a: jnp.ndarray,  # (M, N)
    alpha: jnp.ndarray,  # (nb,) effective per-block scaling of A
    init_var: jnp.ndarray,  # (nb,) per-entry prior energy of g
    cfg: GampConfig,
    nblocks: int,
    n: int,
    m: int,
):
    a_t = a.T  # (N, M)
    a2 = jnp.square(a)  # (M, N)
    a2_t = a2.T
    alpha = jnp.asarray(alpha, jnp.float32)
    alive = alpha > 0.0
    safe_alpha = jnp.where(alive, alpha, 1.0)
    al2 = jnp.square(safe_alpha)[:, None]

    sigma = jnp.sqrt(jnp.maximum(init_var, _EPS))
    theta0 = make_init_theta(nblocks, cfg.n_components, sigma, cfg.lam0_init)
    ghat0 = jnp.zeros((nblocks, n), jnp.float32)
    nu_g0 = jnp.broadcast_to(jnp.maximum(init_var, _EPS)[:, None], (nblocks, n)).astype(
        jnp.float32
    )
    shat0 = jnp.zeros((nblocks, m), jnp.float32)

    scalar_var = cfg.variance_mode == "scalar"

    def body(carry):
        ghat, nu_g, shat, theta, conv_prev, iters = carry
        ghat_old = ghat
        # Count the iteration for every block still live at its start: the
        # final count is iterations-to-converge for frozen blocks and the
        # trip cap for the rest (one int32 add -- numerics untouched).
        iters = iters + (~conv_prev).astype(jnp.int32)
        if scalar_var:
            nu_p = al2 / m * jnp.sum(nu_g, axis=-1, keepdims=True)  # (nb, 1)
            nu_p = jnp.broadcast_to(nu_p, (nblocks, m))
        else:
            nu_p = al2 * (nu_g @ a2_t)  # (nb, M)
        nu_p = jnp.maximum(nu_p, _EPS)
        phat = safe_alpha[:, None] * (ghat @ a_t) - nu_p * shat
        xpost, nu_x = out_channel(phat, nu_p)
        shat_new = (xpost - phat) / nu_p
        nu_s = jnp.maximum((1.0 - nu_x / nu_p) / nu_p, _EPS)
        if scalar_var:
            nu_r = 1.0 / jnp.maximum(
                al2 / m * jnp.sum(nu_s, axis=-1, keepdims=True), _EPS
            )
            nu_r = jnp.broadcast_to(nu_r, (nblocks, n))
        else:
            nu_r = 1.0 / jnp.maximum(al2 * (nu_s @ a2), _EPS)
        rhat = ghat + nu_r * (safe_alpha[:, None] * (shat_new @ a))
        ghat_new, nu_g_new, lp0, lp, mp, pp = _input_channel(rhat, nu_r, theta)
        theta_new = _em_update(theta, lp0, lp, mp, pp) if cfg.em else theta
        if cfg.damping < 1.0:
            d = cfg.damping
            ghat_new = d * ghat_new + (1.0 - d) * ghat_old
            shat_new = d * shat_new + (1.0 - d) * shat
            nu_g_new = d * nu_g_new + (1.0 - d) * nu_g
        delta = jnp.sum(jnp.square(ghat_new - ghat_old), axis=-1)
        ref = jnp.maximum(jnp.sum(jnp.square(ghat_old), axis=-1), _EPS)
        # Sticky early-freeze carry: once a block hits the tolerance it stays
        # frozen (a frozen block recomputes the identical candidate, so the
        # flag could never un-set anyway -- carrying it makes that explicit
        # and gives the caller a per-block convergence signal).
        converged = conv_prev | (delta < cfg.tol * ref)
        # Early-freeze: blocks that converged stop moving entirely (the
        # paper's break, expressed scan-compatibly with a static trip count).
        keepc = converged[:, None]
        ghat_new = jnp.where(keepc, ghat_old, ghat_new)
        nu_g_new = jnp.where(keepc, nu_g, nu_g_new)
        shat_new = jnp.where(keepc, shat, shat_new)
        theta_new = jax.tree_util.tree_map(
            lambda new, old: jnp.where(
                converged.reshape((-1,) + (1,) * (new.ndim - 1)), old, new
            ),
            theta_new,
            theta,
        )
        return (ghat_new, nu_g_new, shat_new, theta_new, converged, iters)

    # Dead rows (alpha == 0: empty blocks, chunk padding) are frozen from
    # iteration 0: their final ghat is zeroed below either way, and they must
    # not gate the early-stop exit of a chunk they merely pad.
    conv0 = ~alive
    state0 = (ghat0, nu_g0, shat0, theta0, conv0, jnp.zeros((nblocks,), jnp.int32))
    if cfg.early_stop and cfg.tol > 0.0:
        # Data-dependent trip count: stop as soon as the whole batch froze.
        # Identical outputs to the static scan (frozen blocks are no-ops);
        # see GampConfig.early_stop for where this is allowed.
        def cond(carry):
            i, state = carry
            return (i < cfg.iters) & ~jnp.all(state[4])

        _, (ghat, nu_g, _, theta, converged, iters) = jax.lax.while_loop(
            cond, lambda c: (c[0] + 1, body(c[1])), (jnp.int32(0), state0)
        )
    else:
        (ghat, nu_g, _, theta, converged, iters), _ = jax.lax.scan(
            lambda c, _: (body(c), None), state0, None, length=cfg.iters
        )
    ghat = jnp.where(alive[:, None], ghat, 0.0)
    return ghat, nu_g, theta, converged, iters


def gamp_health(info: GampInfo, live: Optional[jnp.ndarray] = None):
    """Jit-safe scalar summary of a GampInfo batch for the telemetry layer
    (repro.obs): mean/max live iterations and the early-stop (converged-
    before-cap) fraction, over the ``live`` problem mask (default: all).
    Returns a dict of f32 scalars -- safe to merge into a stats pytree.
    """
    conv = info.converged.reshape(-1).astype(jnp.float32)
    iters = info.iters.reshape(-1).astype(jnp.float32)
    if live is None:
        lf = jnp.ones_like(iters)
    else:
        lf = live.reshape(-1).astype(jnp.float32)
    nlive = jnp.maximum(jnp.sum(lf), 1.0)
    return {
        "gamp_iters_mean": jnp.sum(iters * lf) / nlive,
        "gamp_iters_max": jnp.max(iters * lf),
        "gamp_converged_frac": jnp.sum(conv * lf) / nlive,
    }


def _kernel_dispatch_ok(cfg: GampConfig) -> bool:
    """The fused kernels implement scalar-variance undamped GAMP at a fixed
    trip count; any other config keeps the XLA path (see qem_gamp)."""
    return cfg.variance_mode == "scalar" and cfg.damping == 1.0 and not cfg.early_stop


def _qem_gamp_xla(codes, alpha, a, quantizer, cfg):
    """Pure-XLA Q-EM-GAMP solve; returns (guarded ghat, per-block converged
    flags, per-block live-iteration counts) -- the flags feed the two-phase
    refinement sweep and the counters feed the decode-health telemetry
    (core/recon_engine.py, repro.obs).

    Codebook dispatch: scalar families run the exact truncated-posterior
    channel on the codebook's cell edges (dither = per-lane edge shift); a
    vector codebook has no scalar cells, so the observation is Bussgang-
    linearized into an AWGN channel (eqs. 23-24 with K=1) and the same GAMP
    loop runs on it."""
    cb = as_codebook(quantizer)
    if cb.dim > 1:
        return _vq_ea_xla(codes, alpha, a, cb, cfg)
    nb, m = codes.shape
    n = a.shape[1]
    lo_tau, hi_tau = tau_tables(cb.jnp_thresholds())
    alive = alpha > 0
    init_var = block_prior_energy(alpha, m, n)
    out = partial(
        _quantized_channel, codes=codes, lo_tau=lo_tau, hi_tau=hi_tau,
        shift=cb.jnp_dither(),
    )
    ghat, _, _, converged, iters = _gamp_run(
        lambda p, v: out(p, v), a, alpha, init_var, cfg, nb, n, m
    )
    # The PS *knows* the true block norm (||g|| = sqrt(M)/alpha is
    # transmitted), so the guard clips against it exactly.
    true_norm = jnp.where(alive, jnp.sqrt(jnp.float32(m)) / jnp.where(alive, alpha, 1.0), 0.0)
    return norm_guard(ghat, true_norm), converged | ~alive, iters


def _vq_ea_xla(codes, alpha, a, cb, cfg: GampConfig):
    """Per-worker EA solve for a vector codebook: Bussgang-linearize the
    dequantized observation, Q(alpha A g) = gamma alpha A g + d with
    cov(d) = (psi - gamma^2) I, normalize by gamma*alpha, and run the AWGN
    channel -- structurally eq. 23-24 with a single worker.  Returns
    (guarded ghat, converged flags, iteration counts), matching
    _qem_gamp_xla."""
    m = a.shape[0]
    n = a.shape[1]
    nb = codes.shape[0]
    alive = alpha > 0
    safe = jnp.where(alive, alpha, 1.0)
    deq = cb.decode(codes, m)  # (nb, M)
    y = jnp.where(alive[:, None], deq / (cb.gamma * safe[:, None]), 0.0)
    nu = jnp.where(alive, cb.kappa / jnp.square(safe), 1.0)[:, None]
    init_var = block_prior_energy(alpha, m, n)
    out = lambda p, v: _awgn_channel(p, v, y, nu)
    # alpha is absorbed into y, so the GAMP scaling is 1 for live rows; the
    # 0/1 mask keeps dead rows frozen from iteration 0 exactly as before.
    ghat, _, _, converged, iters = _gamp_run(
        out, a, alive.astype(jnp.float32), init_var, cfg, nb, n, m
    )
    true_norm = jnp.where(alive, jnp.sqrt(jnp.float32(m)) / safe, 0.0)
    return norm_guard(ghat, true_norm), converged | ~alive, iters


def _ea_kernel_ok(cb, cfg: GampConfig) -> bool:
    """The fused qgamp_step kernel consumes scalar cell-edge tables with no
    per-lane shift, so it serves exactly the undithered scalar codebooks
    (Lloyd-Max today); dithered cells and vector codebooks keep their XLA /
    AE-kernel routes."""
    return _kernel_dispatch_ok(cfg) and cb.dim == 1 and cb.dither is None


def _vq_ea_kernel(codes, alpha, a, cb, cfg: GampConfig):
    """Kernel route for the vq EA fallback: the Bussgang-linearized channel
    is exactly the AE kernel's AWGN channel, so the solve scans the fused
    gamp_step kernel (ops.gamp_ae_run) on the normalized observation."""
    from repro.kernels import ops as kops  # deferred: kernels are optional

    m = a.shape[0]
    alive = alpha > 0
    safe = jnp.where(alive, alpha, 1.0)
    deq = cb.decode(codes, m)
    y = jnp.where(alive[:, None], deq / (cb.gamma * safe[:, None]), 0.0)
    nu = jnp.where(alive, cb.kappa / jnp.square(safe), 1.0)
    init_var = block_prior_energy(alpha, m, a.shape[1])
    ghat = kops.gamp_ae_run(
        y, nu, a, init_var,
        n_components=cfg.n_components, iters=cfg.iters, em=cfg.em,
        lam0=cfg.lam0_init,
    )
    # gamp_ae_run's norm guard uses sqrt(init_var * N) == sqrt(M)/alpha, the
    # true transmitted norm; dead rows still need the explicit zero.
    return jnp.where(alive[:, None], ghat, 0.0)


def qem_gamp(
    codes: jnp.ndarray,  # (nb, n_codes) code indices
    alpha: jnp.ndarray,  # (nb,) transmitted scale factors
    a: jnp.ndarray,  # (M, N) sensing matrix
    quantizer,  # Codebook (or legacy LloydMaxQuantizer)
    cfg: GampConfig,
    use_pallas: bool = False,
    with_info: bool = False,
) -> jnp.ndarray:
    """Q-EM-GAMP (Procedure 2): MMSE estimate of each block from its codes.

    Returns (nb, N) reconstructed blocks (pre-concatenation); with
    ``with_info`` the return is ``(blocks, GampInfo)`` -- per-block converged
    flags and live-iteration counts (static placeholders on kernel routes,
    see :class:`GampInfo`).

    ``use_pallas`` routes the solve through the fused TPU kernels: the
    quantized-channel kernel (ops.qgamp_ea_run) for undithered scalar
    codebooks, the AWGN kernel (ops.gamp_ae_run) for the vq fallback; the
    dithered family keeps the XLA path (its cell edges shift per lane).  The
    kernels implement scalar-variance GAMP (the large-system simplification
    the production configs run, EXPERIMENTS.md #Perf) at a fixed trip count
    with no early-freeze (static work for the scheduler, DESIGN.md), so the
    dispatch only takes effect when ``cfg.variance_mode == 'scalar'`` and
    ``cfg.damping == 1.0`` (undamped, no early-stop) -- other configs keep
    the XLA path rather than silently switching reconstruction algorithms.
    ``tol`` is the one accepted deviation: the kernel's fixed trip count vs
    the XLA path's early-freeze differ by well under the 1e-4 NMSE contract
    (pinned by tests/test_kernels.py at the default tol).
    """
    cb = as_codebook(quantizer)
    static_info = GampInfo.static(codes.shape[0], cfg.iters)
    if use_pallas and _kernel_dispatch_ok(cfg) and cb.dim > 1:
        ghat = _vq_ea_kernel(codes, alpha, a, cb, cfg)
        return (ghat, static_info) if with_info else ghat
    if use_pallas and _ea_kernel_ok(cb, cfg):
        from repro.kernels import ops as kops  # deferred: kernels are optional

        ghat = kops.qgamp_ea_run(
            codes, alpha, a, cb.jnp_thresholds(),
            n_components=cfg.n_components, iters=cfg.iters, em=cfg.em,
            lam0=cfg.lam0_init,
        )
        return (ghat, static_info) if with_info else ghat
    ghat, converged, iters = _qem_gamp_xla(codes, alpha, a, cb, cfg)
    return (ghat, GampInfo(converged, iters)) if with_info else ghat


def qem_gamp_packed(
    words: jnp.ndarray,  # (nb, W) uint32 packed wire words (pack_codes layout)
    alpha: jnp.ndarray,  # (nb,) transmitted scale factors
    a: jnp.ndarray,  # (M, N) sensing matrix
    quantizer,  # Codebook (or legacy LloydMaxQuantizer)
    cfg: GampConfig,
    m: int,  # true measurement count M (words carry >= M/dim index lanes)
    use_pallas: bool = False,
    with_info: bool = False,
) -> jnp.ndarray:
    """Packed-domain Q-EM-GAMP: consumes the uint32 wire words directly.

    On the (undithered scalar) kernel path the words stream into the fused
    qgamp_step kernel, which unpacks per lane group in VMEM -- the (nb, M)
    uint8 index tensor never exists in HBM.  The XLA path (and the other
    codebook families) unpack just-in-time at the solve (so under the
    chunked decode of core/recon_engine.py at most one chunk's index view is
    live at a time).  Bit-identical to
    ``qem_gamp(unpack_codes(words, Q, n_codes), ...)`` in every mode.
    """
    cb = as_codebook(quantizer)
    static_info = GampInfo.static(words.shape[0], cfg.iters)
    if use_pallas and _ea_kernel_ok(cb, cfg):
        from repro.kernels import ops as kops  # deferred: kernels are optional

        ghat = kops.qgamp_ea_run_packed(
            words, alpha, a, cb.jnp_thresholds(),
            bits=cb.bits, m=m,
            n_components=cfg.n_components, iters=cfg.iters, em=cfg.em,
            lam0=cfg.lam0_init,
        )
        return (ghat, static_info) if with_info else ghat
    from repro.core.compression import unpack_codes  # deferred: layering

    codes = unpack_codes(words, cb.bits, cb.n_codes(m))
    if use_pallas and _kernel_dispatch_ok(cfg) and cb.dim > 1:
        ghat = _vq_ea_kernel(codes, alpha, a, cb, cfg)
        return (ghat, static_info) if with_info else ghat
    ghat, converged, iters = _qem_gamp_xla(codes, alpha, a, cb, cfg)
    return (ghat, GampInfo(converged, iters)) if with_info else ghat


def em_gamp(
    y: jnp.ndarray,  # (nb, M) linear observations  y = A g + noise
    noise_var: jnp.ndarray,  # (nb,) AWGN variance per block (eq. 24)
    a: jnp.ndarray,  # (M, N)
    cfg: GampConfig,
    init_var: Optional[jnp.ndarray] = None,  # (nb,) per-entry signal energy
    use_pallas: bool = False,
    with_info: bool = False,
) -> jnp.ndarray:
    """EM-GAMP on a noisy *unquantized* observation (aggregate-and-estimate).

    Returns (nb, N) reconstructed (already rho-weighted, aggregated) blocks;
    with ``with_info`` the return is ``(blocks, GampInfo)`` under the same
    semantics as qem_gamp (static placeholder info on the kernel route).
    ``use_pallas`` dispatches to the fused kernel (ops.gamp_ae_run) under the
    same rules as qem_gamp: scalar-variance configs only, fixed trip count.
    """
    nb, m = y.shape
    n = a.shape[1]
    if init_var is None:
        # E per-entry energy of g from the observation: E||y||^2 = R E||g||^2
        # per entry... ||y||^2/M ~= ||g||^2/M (A has unit column-energy rows:
        # E|Ag|_m^2 = ||g||^2/M), so ||g||^2 ~= ||y||^2 and per-entry = /N.
        init_var = jnp.maximum(jnp.sum(jnp.square(y), axis=-1) - m * noise_var, _EPS) / n
    if use_pallas and _kernel_dispatch_ok(cfg):
        from repro.kernels import ops as kops  # deferred: kernels are optional

        ghat = kops.gamp_ae_run(
            y, noise_var, a, jnp.asarray(init_var, jnp.float32),
            n_components=cfg.n_components, iters=cfg.iters, em=cfg.em,
            lam0=cfg.lam0_init,
        )
        return (ghat, GampInfo.static(nb, cfg.iters)) if with_info else ghat
    alpha = jnp.ones((nb,), jnp.float32)
    nvar = jnp.asarray(noise_var, jnp.float32)[:, None]
    out = lambda p, v: _awgn_channel(p, v, y, nvar)
    ghat, _, _, converged, iters = _gamp_run(
        out, a, alpha, jnp.asarray(init_var, jnp.float32), cfg, nb, n, m
    )
    # Expected ||g_sum||^2 = init_var * N (see norm_guard).
    ghat = norm_guard(ghat, jnp.sqrt(jnp.maximum(init_var * n, 0.0)))
    return (ghat, GampInfo(converged, iters)) if with_info else ghat
