"""Sensing matrices for the dimension-reduction stage (paper Sec. III-A).

The paper draws A in R^{M x N} iid N(0, 1/M) -- the classical RIP ensemble --
and *shares the same A across all devices, blocks, and steps* (it is part of
the protocol, like the quantizer codebook).  We therefore generate A from a
fixed seed so every pod / the PS can materialize it independently without any
communication.

Two layouts are provided:
  * ``sensing_matrix``      -> A   (M, N), paper orientation (y = A g).
  * ``sensing_matrix_t``    -> A^T (N, M), the GEMM-friendly layout used by the
    batched path ``Y = G @ A^T`` with G (nblocks, N).

``scale_factor`` computes alpha_{k,b} = sqrt(M)/||g_block|| (eq. 9 discussion),
which normalizes every projected entry to ~ N(0,1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "sensing_matrix",
    "sensing_matrix_t",
    "scale_factor",
    "project_blocks",
]


def sensing_matrix(key: jax.Array, m: int, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """A in R^{m x n}, entries iid N(0, 1/m)."""
    return jax.random.normal(key, (m, n), dtype=dtype) / jnp.sqrt(jnp.asarray(m, dtype))


def sensing_matrix_t(key: jax.Array, m: int, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """A^T in R^{n x m} (same entries as :func:`sensing_matrix`)."""
    return sensing_matrix(key, m, n, dtype).T


def scale_factor(blocks: jnp.ndarray, m: int, eps: float = 1e-20) -> jnp.ndarray:
    """alpha per block: sqrt(M) / ||g_block||, (nblocks,).

    Zero blocks get alpha = 0 (their projection is zero anyway and the
    receiver treats alpha==0 as an empty block).
    """
    norms = jnp.linalg.norm(blocks, axis=-1)
    return jnp.where(norms > eps, jnp.sqrt(jnp.asarray(m, blocks.dtype)) / norms, 0.0)


def project_blocks(blocks: jnp.ndarray, a_t: jnp.ndarray) -> jnp.ndarray:
    """x = alpha * (A @ g) for every block, batched as one GEMM.

    Args:
      blocks: (nblocks, N) sparse gradient blocks.
      a_t: (N, M) transposed sensing matrix.

    Returns:
      (x, alpha): (nblocks, M) unit-variance projections and (nblocks,) scales.
    """
    m = a_t.shape[1]
    alpha = scale_factor(blocks, m)
    x = (blocks @ a_t) * alpha[:, None]
    return x, alpha
