"""Block sparsification with error feedback (paper Sec. III-A, eqs. 7-8).

The gradient vector is split into blocks of size N; each block keeps only its
top-S magnitude entries.  The dropped mass is *not* lost: it is returned as a
residual that the caller accumulates into the next step's gradient
(``g_bar^{(t+1)} = grad^{(t+1)} + Delta^{(t+1)}``), the standard error-feedback
mechanism the paper adopts from Amiri & Gunduz.

All functions operate on a stacked ``(nblocks, N)`` view so that every block is
processed by one vectorized primitive (XLA-friendly; no per-block Python loop).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["block_topk_mask", "block_sparsify", "block_sparsify_threshold"]


def block_topk_mask(blocks: jnp.ndarray, s: int) -> jnp.ndarray:
    """Boolean mask of the top-``s`` magnitude entries per block.

    Args:
      blocks: (nblocks, N) gradient blocks.
      s: number of entries to keep per block (static).

    Returns:
      (nblocks, N) bool mask with exactly ``s`` True per row (ties broken by
      jax.lax.top_k's deterministic ordering).
    """
    n = blocks.shape[-1]
    if s >= n:
        return jnp.ones(blocks.shape, dtype=bool)
    mag = jnp.abs(blocks)
    _, idx = jax.lax.top_k(mag, s)  # (nblocks, s)
    mask = jnp.zeros(blocks.shape, dtype=bool)
    rows = jnp.arange(blocks.shape[0])[:, None]
    return mask.at[rows, idx].set(True)


def block_sparsify(blocks: jnp.ndarray, s: int):
    """BlockSparse(.): keeps top-S per block; returns (sparse, residual).

    ``sparse + residual == blocks`` exactly (error-feedback identity, eq. 7).
    """
    mask = block_topk_mask(blocks, s)
    sparse = jnp.where(mask, blocks, 0.0)
    return sparse, blocks - sparse


def block_sparsify_threshold(blocks: jnp.ndarray, s: int, bisect_iters: int = 24):
    """Threshold-selection variant: per-block magnitude threshold found by
    bisection instead of an exact top-k sort.

    This is the TPU-native formulation used by the Pallas kernel
    (``kernels/block_topk``): it avoids data-dependent gather/scatter, using
    only reductions and compares.  Keeps *approximately* S entries per block
    (exact when magnitudes are distinct up to the bisection resolution).

    Returns (sparse, residual) like :func:`block_sparsify`.
    """
    mag = jnp.abs(blocks)
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum(mag >= mid, axis=-1, keepdims=True)
        # too many survivors -> raise threshold; too few -> lower it.
        lo = jnp.where(count > s, mid, lo)
        hi = jnp.where(count > s, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, bisect_iters, body, (lo, hi))
    thresh = hi  # smallest examined threshold keeping <= s entries
    mask = mag >= thresh
    # Guarantee at least one survivor per block (max always kept).
    mask = mask | (mag == jnp.max(mag, axis=-1, keepdims=True))
    sparse = jnp.where(mask, blocks, 0.0)
    return sparse, blocks - sparse
