"""Per-tensor gradient block geometry (DESIGN.md #Layout).

Everything upstream of the codec used to assume the gradient is resident as
ONE ``(nblocks, N)`` array: ``flatten_to_blocks`` concatenated every leaf
into a single flat vector before the first block ever reached the encoder.
Nothing in the QCS math requires that -- the paper's sparsify / project /
quantize stages are all defined *per block* -- and FedVQCS (Oh et al., 2022)
as well as Tang et al.'s compressed-sensing distributed SGD both partition
the parameter vector into independently compressed segments.  This module
makes that partition first-class:

  * :class:`GradientLayout` owns the pytree <-> block-grid geometry that was
    previously implicit in the ``(treedef, shapes, nbar)`` spec tuple: which
    leaves feed which block rows (the ownership map), per-segment zero
    padding, and optional per-segment sparsity budgets replacing the single
    global ``s_ratio``.
  * The **monolithic** layout (one segment = every leaf concatenated, padded
    once at the end) reproduces the pre-layout flatten BIT-FOR-BIT -- it is
    the default everywhere, so existing wire output is unchanged.
  * The **per-tensor** layout gives each leaf (or leaf-group -- small leaves
    coalesce up to ``group_scalars``) its own independently padded run of
    block rows.  Because every codec stage is per-block and block rows never
    straddle segments, a per-tensor layout can be *streamed*: encode segment
    i, discard its blocks, move on -- peak encoder live memory is bounded by
    the LARGEST segment's blocks instead of the whole model (the
    ``benchmarks/run.py --only encode`` streamed-vs-monolithic rows measure
    exactly this bound).  Decode is equally segment-local: a segment's rows
    invert to its leaves without waiting for the other segments
    (``recon_engine.ea_decode_segments``).

All geometry -- sizes, offsets, row counts -- is computed in PYTHON INTS at
layout construction, so a 7B+ parameter model cannot silently wrap int32
(the old ``flatten_to_blocks`` risk).  Flat index math that must run on
device is guarded: a segment whose padded scalar span exceeds int32 range
raises at construction unless jax x64 is enabled, with the per-tensor layout
named as the fix (each tensor of a 7B model is individually well inside
int32 even though the model is not).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "LayoutSegment",
    "GradientLayout",
    "as_layout",
    "INT32_MAX",
]

INT32_MAX = 2**31 - 1


def _leaf_size(shape) -> int:
    """Python-int scalar count of one leaf (math.prod, never numpy int32)."""
    return math.prod(int(d) for d in shape) if shape else 1


def _check_int32(span: int, what: str) -> None:
    """Flat device-side index math (reshape/slice iotas) wraps past int32
    unless jax x64 is on.  Raise with the fix named rather than corrupting
    silently."""
    if span <= INT32_MAX:
        return
    if jax.config.read("jax_enable_x64"):
        return
    raise ValueError(
        f"{what} spans {span} scalars > int32 max {INT32_MAX}: flat index "
        "math would overflow.  Use a per-tensor GradientLayout (each "
        "segment then only needs its own tensor's span) or enable "
        "jax_enable_x64."
    )


@dataclasses.dataclass(frozen=True)
class LayoutSegment:
    """One independently padded run of block rows.

    ``leaf_ids`` index the layout's flat leaf list; the segment's scalars are
    those leaves raveled and concatenated in leaf order, zero-padded by
    ``pad`` to exactly ``rows * n``.  ``s`` is the per-block top-S budget the
    encoder applies to this segment's rows (None = the codec config's global
    ``s``).  All fields are Python ints -- no device math at geometry time.

    ``offsets`` (None for whole-leaf segments) marks a SLICED segment built
    by the ``split`` hook: entry j says this segment owns leaf
    ``leaf_ids[j]``'s flat scalars ``[offsets[j], offsets[j] + sizes[j])``
    rather than the whole leaf.  A stacked ``(L, ...)`` parameter can then be
    partitioned into per-layer-chunk segments so a backward-interleaved
    producer emits each chunk's segment as soon as its cotangents exist
    (DESIGN.md #Interleave) -- reassembly concatenates a leaf's pieces back
    in offset order.
    """

    index: int
    name: str
    leaf_ids: Tuple[int, ...]
    sizes: Tuple[int, ...]  # per-leaf scalar counts
    size: int  # sum(sizes)
    rows: int  # block rows owned
    row_start: int  # first row in the layout's global block grid
    pad: int  # zero scalars appended (rows * n - size)
    s: Optional[int] = None  # per-segment top-S override (None = global)
    offsets: Optional[Tuple[int, ...]] = None  # per-leaf flat start (sliced)

    @property
    def row_slice(self) -> slice:
        return slice(self.row_start, self.row_start + self.rows)

    @property
    def leaf_offsets(self) -> Tuple[int, ...]:
        """Per-leaf flat start offsets (0s for whole-leaf segments)."""
        return self.offsets if self.offsets is not None else (0,) * len(self.leaf_ids)


@dataclasses.dataclass(frozen=True)
class GradientLayout:
    """The pytree <-> block-grid spec: treedef + leaf shapes + segments.

    This object *is* the "spec" the codec / engine / API pass around
    (``blocks_to_tree`` accepts it directly); the legacy ``(treedef,
    shapes)`` tuple is still accepted everywhere for back compat.
    Hashable/immutable, safe to close over in jitted functions: all array
    work happens in :meth:`to_blocks` / :meth:`tree_from_blocks`, driven by
    static Python geometry.
    """

    n: int  # block size N
    row_multiple: int
    treedef: Any
    shapes: Tuple[Tuple[Tuple[int, ...], Any], ...]  # per-leaf (shape, dtype)
    segments: Tuple[LayoutSegment, ...]
    nbar: int  # total scalars across all leaves (pre-padding, Python int)
    kind: str = "monolithic"  # or "per_tensor"

    # -- construction --------------------------------------------------------

    @classmethod
    def monolithic(cls, tree: Any, n: int, row_multiple: int = 1) -> "GradientLayout":
        """One segment covering every leaf, padded once at the end -- the
        pre-layout ``flatten_to_blocks`` geometry, bit-identical."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shapes = tuple((tuple(l.shape), l.dtype) for l in leaves)
        return cls.from_shapes(treedef, shapes, n, row_multiple=row_multiple)

    @classmethod
    def from_shapes(
        cls,
        treedef: Any,
        shapes: Sequence[Tuple[Tuple[int, ...], Any]],
        n: int,
        row_multiple: int = 1,
    ) -> "GradientLayout":
        """Monolithic layout from abstract (shape, dtype) specs -- no arrays
        needed, so geometry (and the int32 guard) is testable at any scale."""
        shapes = tuple((tuple(s), d) for s, d in shapes)
        sizes = tuple(_leaf_size(s) for s, _ in shapes)
        nbar = sum(sizes)
        rows = -(-nbar // n)
        rows = -(-rows // row_multiple) * row_multiple
        _check_int32(rows * n, "monolithic layout")
        seg = LayoutSegment(
            index=0,
            name="all",
            leaf_ids=tuple(range(len(shapes))),
            sizes=sizes,
            size=nbar,
            rows=rows,
            row_start=0,
            pad=rows * n - nbar,
        )
        return cls(
            n=n, row_multiple=row_multiple, treedef=treedef, shapes=shapes,
            segments=(seg,), nbar=nbar, kind="monolithic",
        )

    @classmethod
    def per_tensor(
        cls,
        tree: Any,
        n: int,
        row_multiple: int = 1,
        s_ratio: Optional[Callable[[str, Tuple[int, ...]], Optional[float]]] = None,
        group_scalars: int = 0,
        split: Optional[Callable[[str, Tuple[int, ...]], Optional[Sequence[int]]]] = None,
    ) -> "GradientLayout":
        """One segment per leaf, each independently padded to the block grid.

        ``group_scalars`` > 0 coalesces consecutive small leaves into one
        segment until the group reaches that many scalars (padding overhead
        for a model full of tiny biases would otherwise be one part-empty
        block per leaf).  ``s_ratio(name, shape) -> float | None`` assigns a
        per-segment sparsity budget (None = the codec config's global
        ``s_ratio``); for a grouped segment the first leaf's ratio wins.

        ``split(name, shape) -> [p0, p1, ...] | None`` partitions a leaf
        along axis 0 into parts of those row counts (must sum to shape[0]);
        each part becomes its OWN sliced segment named ``name[a:b]``, never
        coalesced with neighbours.  This aligns segment boundaries with the
        layer chunks a backward-interleaved producer emits (DESIGN.md
        #Interleave).  ``s_ratio`` is consulted with the base leaf name, so
        every part of a split leaf inherits the leaf's budget.
        """
        leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
        treedef = jax.tree_util.tree_structure(tree)
        shapes = tuple((tuple(l.shape), l.dtype) for _, l in leaves_with_path)
        names = [jax.tree_util.keystr(p) or f"leaf{i}"
                 for i, (p, _) in enumerate(leaves_with_path)]
        return cls.from_shapes_per_tensor(
            treedef, shapes, n, row_multiple=row_multiple,
            names=names, s_ratio=s_ratio, group_scalars=group_scalars,
            split=split,
        )

    @classmethod
    def from_shapes_per_tensor(
        cls,
        treedef: Any,
        shapes: Sequence[Tuple[Tuple[int, ...], Any]],
        n: int,
        row_multiple: int = 1,
        names: Optional[Sequence[str]] = None,
        s_ratio: Optional[Callable[[str, Tuple[int, ...]], Optional[float]]] = None,
        group_scalars: int = 0,
        split: Optional[Callable[[str, Tuple[int, ...]], Optional[Sequence[int]]]] = None,
    ) -> "GradientLayout":
        """Abstract-spec variant of :meth:`per_tensor` (see there)."""
        shapes = tuple((tuple(s), d) for s, d in shapes)
        sizes = [_leaf_size(s) for s, _ in shapes]
        names = list(names) if names is not None else [f"leaf{i}" for i in range(len(shapes))]
        # units: (leaf_id, flat offset, flat size, display name, groupable) --
        # a whole leaf (groupable), or one axis-0 slice of a split leaf
        # (never coalesced: its boundaries are the interleave chunk bounds)
        units: List[Tuple[int, int, int, str, bool]] = []
        for i, size in enumerate(sizes):
            shape = shapes[i][0]
            parts = split(names[i], shape) if split is not None else None
            if parts is None:
                units.append((i, 0, size, names[i], True))
                continue
            parts = [int(p) for p in parts]
            if not shape or any(p <= 0 for p in parts) or sum(parts) != shape[0]:
                raise ValueError(
                    f"split for {names[i]!r} must partition axis 0 "
                    f"(shape {shape}): got parts {parts}"
                )
            stride = size // shape[0]
            lo = 0
            for p in parts:
                units.append(
                    (i, lo * stride, p * stride, f"{names[i]}[{lo}:{lo + p}]", False)
                )
                lo += p
        # coalesce consecutive groupable units into groups >= group_scalars
        groups: List[List[Tuple[int, int, int, str, bool]]] = []
        cur: List[Tuple[int, int, int, str, bool]] = []
        cur_size = 0
        for u in units:
            if not u[4]:  # split part: flush the open group, stand alone
                if cur:
                    groups.append(cur)
                    cur, cur_size = [], 0
                groups.append([u])
                continue
            cur.append(u)
            cur_size += u[2]
            if cur_size >= max(group_scalars, 1):
                groups.append(cur)
                cur, cur_size = [], 0
        if cur:
            if groups and group_scalars > 0 and groups[-1][0][4]:
                groups[-1].extend(cur)  # trailing stub rides the last group
            else:
                groups.append(cur)
        segments: List[LayoutSegment] = []
        row_start = 0
        for gi, ids in enumerate(groups):
            gsize = sum(u[2] for u in ids)
            rows = -(-gsize // n)
            rows = -(-rows // row_multiple) * row_multiple
            _check_int32(rows * n, f"layout segment {ids[0][3]!r}")
            s = None
            if s_ratio is not None:
                # base leaf name, so split parts inherit the leaf's budget
                lid0 = ids[0][0]
                ratio = s_ratio(names[lid0], shapes[lid0][0])
                if ratio is not None:
                    if not (0.0 < ratio <= 1.0):
                        raise ValueError(
                            f"per-segment s_ratio for {names[lid0]!r} must be "
                            f"in (0, 1], got {ratio}"
                        )
                    s = max(1, int(ratio * n))
            sliced = any(off != 0 or sz != sizes[lid] for lid, off, sz, _, _ in ids)
            segments.append(
                LayoutSegment(
                    index=gi,
                    name=ids[0][3] if len(ids) == 1
                    else f"{ids[0][3]}+{len(ids) - 1}",
                    leaf_ids=tuple(u[0] for u in ids),
                    sizes=tuple(u[2] for u in ids),
                    size=gsize,
                    rows=rows,
                    row_start=row_start,
                    pad=rows * n - gsize,
                    s=s,
                    offsets=tuple(u[1] for u in ids) if sliced else None,
                )
            )
            row_start += rows
        return cls(
            n=n, row_multiple=row_multiple, treedef=treedef, shapes=shapes,
            segments=tuple(segments), nbar=sum(sizes), kind="per_tensor",
        )

    # -- geometry ------------------------------------------------------------

    @property
    def rows(self) -> int:
        """Total block rows across all segments (the global nb)."""
        return sum(seg.rows for seg in self.segments)

    @property
    def max_segment_rows(self) -> int:
        """Largest segment's rows -- the streamed encoder's live-memory bound."""
        return max((seg.rows for seg in self.segments), default=0)

    @property
    def spec(self) -> Tuple[Any, list]:
        """The legacy ``(treedef, shapes)`` tuple this layout subsumes."""
        return (self.treedef, list(self.shapes))

    def segment_s(self, default_s: int) -> List[int]:
        """Per-segment top-S budgets with the global default filled in."""
        return [seg.s if seg.s is not None else default_s for seg in self.segments]

    def owner_map(self) -> Dict[int, Tuple[int, int, int]]:
        """leaf id -> (segment index, first row touched, last row touched + 1)
        in the GLOBAL block grid.  Exact ownership for per-tensor layouts; for
        the monolithic layout leaves share rows at their boundaries (a block
        straddles leaves), so ranges may overlap.  A split leaf spans several
        segments: the reported segment index is the first touching it and the
        row range covers every piece."""
        out: Dict[int, Tuple[int, int, int]] = {}
        for seg in self.segments:
            off = 0
            for lid, size in zip(seg.leaf_ids, seg.sizes):
                r0 = seg.row_start + off // self.n
                r1 = seg.row_start + (max(off + size - 1, off)) // self.n + 1
                if lid in out:
                    p_seg, p0, p1 = out[lid]
                    out[lid] = (p_seg, min(p0, r0), max(p1, r1))
                else:
                    out[lid] = (seg.index, r0, r1)
                off += size
        return out

    def encoder_live_bytes(self, streamed: bool) -> int:
        """f32 block-domain bytes the encoder holds live at once: blocks +
        error-feedback residual in + residual out, for the whole grid
        (monolithic encode) or the largest segment (streamed encode).  This
        is the bound ``benchmarks/run.py --only encode`` records and CI pins."""
        rows = self.max_segment_rows if streamed else self.rows
        return 3 * rows * self.n * 4

    # -- array ops (tree -> blocks) -------------------------------------------

    def _segment_flat(self, leaves: Sequence[jnp.ndarray], seg: LayoutSegment,
                      batch: int = 0) -> jnp.ndarray:
        """Ravels + concatenates + zero-pads one segment's leaves (leading
        ``batch`` axes pass through).  For sliced segments only the owned
        ``[offset, offset + size)`` flat span of each leaf is taken."""
        lead = leaves[seg.leaf_ids[0]].shape[:batch] if seg.leaf_ids else ()
        parts = []
        for i, size, off in zip(seg.leaf_ids, seg.sizes, seg.leaf_offsets):
            flat = leaves[i].reshape(lead + (-1,)).astype(jnp.float32)
            if off != 0 or size != flat.shape[-1]:
                flat = jax.lax.slice_in_dim(flat, off, off + size, axis=-1)
            parts.append(flat)
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)
        if seg.pad:
            flat = jnp.concatenate(
                [flat, jnp.zeros(lead + (seg.pad,), flat.dtype)], axis=-1
            )
        return flat

    def segment_blocks(self, tree: Any, index: int) -> jnp.ndarray:
        """One segment's ``(rows, N)`` block view, built from ITS leaves only
        -- the whole-model flat vector never materializes.  This is the
        streamed encoder's unit of work."""
        leaves = jax.tree_util.tree_leaves(tree)
        seg = self.segments[index]
        return self._segment_flat(leaves, seg).reshape(seg.rows, self.n)

    def segment_blocks_batched(self, tree: Any, index: int) -> jnp.ndarray:
        """Batched :meth:`segment_blocks`: every leaf carries a leading
        clients/pods axis; returns ``(batch, rows, N)`` for one segment."""
        leaves = jax.tree_util.tree_leaves(tree)
        seg = self.segments[index]
        batch = leaves[seg.leaf_ids[0]].shape[0]
        return self._segment_flat(leaves, seg, batch=1).reshape(batch, seg.rows, self.n)

    def iter_segment_blocks(self, tree: Any) -> Iterator[Tuple[LayoutSegment, jnp.ndarray]]:
        """Yields (segment, (rows, N) blocks) in row order -- the per-tensor
        streaming iterator the encoder consumes one leaf-group at a time."""
        leaves = jax.tree_util.tree_leaves(tree)
        for seg in self.segments:
            yield seg, self._segment_flat(leaves, seg).reshape(seg.rows, self.n)

    def to_blocks(self, tree: Any) -> jnp.ndarray:
        """Full ``(rows, N)`` block grid.  Monolithic layouts reproduce the
        pre-layout ``flatten_to_blocks`` output bit-for-bit (single concat,
        single trailing pad); per-tensor layouts concatenate their
        independently padded segments in row order."""
        leaves = jax.tree_util.tree_leaves(tree)
        flats = [self._segment_flat(leaves, seg) for seg in self.segments]
        flat = flats[0] if len(flats) == 1 else jnp.concatenate(flats, axis=-1)
        return flat.reshape(self.rows, self.n)

    def to_blocks_batched(self, tree: Any) -> jnp.ndarray:
        """Batched variant: every leaf carries a leading pods/clients axis;
        returns ``(pods, rows, N)``."""
        leaves = jax.tree_util.tree_leaves(tree)
        pods = leaves[0].shape[0]
        flats = [self._segment_flat(leaves, seg, batch=1) for seg in self.segments]
        flat = flats[0] if len(flats) == 1 else jnp.concatenate(flats, axis=-1)
        return flat.reshape(pods, self.rows, self.n)

    # -- array ops (blocks -> tree) -------------------------------------------

    def _leaves_from_flat(self, flat: jnp.ndarray, seg: LayoutSegment) -> List[jnp.ndarray]:
        leaves = []
        off = 0
        for lid, size in zip(seg.leaf_ids, seg.sizes):
            shape, dtype = self.shapes[lid]
            leaves.append(flat[off : off + size].reshape(shape).astype(dtype))
            off += size
        return leaves

    def _segment_pieces(
        self, flat: jnp.ndarray, seg: LayoutSegment
    ) -> Iterator[Tuple[int, int, jnp.ndarray]]:
        """(leaf id, leaf flat offset, 1-D piece) for one segment's unpadded
        flat scalars -- the generic inverse unit covering both whole-leaf and
        sliced segments."""
        off = 0
        for lid, size, loff in zip(seg.leaf_ids, seg.sizes, seg.leaf_offsets):
            yield lid, loff, flat[off : off + size]
            off += size

    def _assemble_leaves(
        self, pieces: Dict[int, List[Tuple[int, jnp.ndarray]]]
    ) -> List[Optional[jnp.ndarray]]:
        """Leaf list from (offset, flat piece) contributions: pieces of a
        split leaf concatenate back in offset order and must tile it
        exactly."""
        out: List[Optional[jnp.ndarray]] = [None] * len(self.shapes)
        for lid, plist in pieces.items():
            shape, dtype = self.shapes[lid]
            size = _leaf_size(shape)
            plist.sort(key=lambda t: t[0])
            cursor = 0
            for off, p in plist:
                if off != cursor:
                    raise ValueError(
                        f"leaf {lid} pieces do not tile: expected offset "
                        f"{cursor}, got {off} (missing or overlapping slice)"
                    )
                cursor += int(p.shape[-1])
            if cursor != size:
                raise ValueError(
                    f"leaf {lid} pieces cover {cursor} of {size} scalars"
                )
            flat = plist[0][1] if len(plist) == 1 else jnp.concatenate(
                [p for _, p in plist], axis=-1
            )
            out[lid] = flat.reshape(shape).astype(dtype)
        return out

    def tree_from_blocks(self, blocks: jnp.ndarray) -> Any:
        """Inverse of :meth:`to_blocks` (unpad per segment, reshape leaves;
        split-leaf pieces concatenate back in offset order)."""
        pieces: Dict[int, List[Tuple[int, jnp.ndarray]]] = {}
        for seg in self.segments:
            flat = blocks[seg.row_slice].reshape(-1)
            for lid, loff, p in self._segment_pieces(flat, seg):
                pieces.setdefault(lid, []).append((loff, p))
        return jax.tree_util.tree_unflatten(
            self.treedef, self._assemble_leaves(pieces)
        )

    def segment_leaves(self, index: int, seg_blocks: jnp.ndarray) -> Dict[int, jnp.ndarray]:
        """Decodes ONE segment's ``(rows, N)`` blocks into its leaves
        (leaf id -> array) without the other segments -- per-tensor decode
        can start before the rest of the model arrives.  Sliced segments own
        leaf fragments, not leaves; they have no whole-leaf decode."""
        seg = self.segments[index]
        if seg.offsets is not None:
            raise ValueError(
                f"segment {seg.name!r} owns leaf slices (split layout); "
                "whole leaves only exist once every piece is present -- "
                "use tree_from_segments/tree_from_blocks"
            )
        flat = seg_blocks.reshape(-1)
        return dict(zip(seg.leaf_ids, self._leaves_from_flat(flat, seg)))

    def tree_from_segments(self, seg_blocks: Dict[int, jnp.ndarray]) -> Any:
        """Assembles the full tree from per-segment block arrays (every
        segment must be present; use :meth:`segment_leaves` for partial
        decode)."""
        pieces: Dict[int, List[Tuple[int, jnp.ndarray]]] = {}
        for index, blocks in seg_blocks.items():
            seg = self.segments[index]
            flat = blocks.reshape(-1)
            for lid, loff, p in self._segment_pieces(flat, seg):
                pieces.setdefault(lid, []).append((loff, p))
        missing = [i for i in range(len(self.shapes)) if i not in pieces]
        if missing:
            raise ValueError(f"tree_from_segments missing leaves {missing}")
        return jax.tree_util.tree_unflatten(
            self.treedef, self._assemble_leaves(pieces)
        )


def as_layout(spec: Any, n: Optional[int] = None, row_multiple: int = 1):
    """Normalizes a spec to a GradientLayout: layouts pass through; the
    legacy ``(treedef, shapes)`` tuple builds a monolithic layout (``n``
    required then)."""
    if isinstance(spec, GradientLayout):
        return spec
    treedef, shapes = spec
    if n is None:
        raise ValueError("legacy (treedef, shapes) spec needs the block size n")
    shapes = tuple((tuple(s), d) for s, d in shapes)
    return GradientLayout.from_shapes(treedef, shapes, n, row_multiple=row_multiple)
