"""Lloyd-Max scalar quantizer optimized for N(0,1) (paper Sec. III-A).

The quantizer is designed *once* (numpy, at config time) for the standard
normal distribution and shared by every device/pod and the PS -- exactly the
property the paper exploits to avoid per-step signalling: the BQCS scaling
``alpha = sqrt(M)/||g||`` makes every projected entry ~ N(0,1), so a single
codebook serves all (k, b, t).

Also computes the Bussgang constants of Proposition 1:

    gamma_Q = E[Q(X) X]   (eq. 21)   -- linear gain
    psi_Q   = E[Q(X)^2]   (eq. 22)   -- second moment
    kappa_Q = (psi_Q - gamma_Q^2) / gamma_Q^2   -- normalized distortion power
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

__all__ = [
    "LloydMaxQuantizer",
    "design_lloyd_max",
    "encode",
    "decode",
    "quantize",
]

_SQRT2 = math.sqrt(2.0)
_INV_SQRT_2PI = 1.0 / math.sqrt(2.0 * math.pi)
# Vectorized erf built ONCE at import: np.vectorize re-walks its dispatch
# machinery on every construction, and _Phi sits inside the design_lloyd_max
# fixed-point loop (hundreds of iterations per design; the VQ/dither designs
# of core/codebook.py make config-time design hotter still).
_ERF = np.vectorize(math.erf)


def _phi(x: np.ndarray) -> np.ndarray:
    """Standard normal pdf (numpy, design-time only)."""
    return _INV_SQRT_2PI * np.exp(-0.5 * np.square(x))


def _Phi(x: np.ndarray) -> np.ndarray:
    """Standard normal cdf (numpy, design-time only)."""
    return 0.5 * (1.0 + _ERF(np.asarray(x, dtype=np.float64) / _SQRT2))


@dataclasses.dataclass(frozen=True)
class LloydMaxQuantizer:
    """An optimal (MMSE) scalar quantizer for N(0,1).

    Attributes:
      bits: Q, number of bits; 2**Q output levels.
      levels: (2**Q,) reconstruction points q_i, ascending.
      thresholds: (2**Q - 1,) interior decision thresholds tau_1..tau_{2^Q-1}
        (tau_0 = -inf and tau_{2^Q} = +inf are implicit).
      gamma: Bussgang gain gamma_Q (eq. 21).
      psi: output second moment psi_Q (eq. 22).
    """

    bits: int
    levels: np.ndarray
    thresholds: np.ndarray
    gamma: float
    psi: float

    @property
    def n_levels(self) -> int:
        return 1 << self.bits

    @property
    def kappa(self) -> float:
        """kappa_Q = (psi - gamma^2)/gamma^2, the distortion-to-signal ratio
        after Bussgang normalization (appears in Thm 1 / eq. 24)."""
        return (self.psi - self.gamma**2) / (self.gamma**2)

    @property
    def distortion(self) -> float:
        """MSE of the quantizer for a unit-variance Gaussian input:
        E[(Q(X)-X)^2] = 1 - 2 gamma + psi; equals psi - gamma^2... for the
        Lloyd-Max fixed point gamma == psi so this is 1 - gamma."""
        return 1.0 - 2.0 * self.gamma + self.psi

    def jnp_levels(self, dtype=jnp.float32) -> jnp.ndarray:
        return jnp.asarray(self.levels, dtype=dtype)

    def jnp_thresholds(self, dtype=jnp.float32) -> jnp.ndarray:
        return jnp.asarray(self.thresholds, dtype=dtype)


def design_lloyd_max(bits: int, iters: int = 0, tol: float = 1e-12) -> LloydMaxQuantizer:
    """Designs the Lloyd-Max quantizer for N(0,1) via fixed-point iteration.

    Alternates the two optimality conditions until convergence:
      tau_i = (q_i + q_{i+1}) / 2                       (nearest-neighbor)
      q_i   = E[X | tau_{i-1} < X <= tau_i]             (centroid)
            = (phi(tau_{i-1}) - phi(tau_i)) / (Phi(tau_i) - Phi(tau_{i-1}))
    """
    if not (1 <= bits <= 8):
        raise ValueError(f"bits must be in [1, 8], got {bits}")
    n = 1 << bits
    if not iters:
        iters = 300 * n  # fixed-point convergence slows with level count
    # Initialize levels at Gaussian quantiles (good starting point).
    probs = (np.arange(n, dtype=np.float64) + 0.5) / n
    # Inverse normal CDF via binary search (no scipy available).
    levels = np.array([_norm_ppf(p) for p in probs], dtype=np.float64)
    prev = levels.copy()
    for _ in range(iters):
        taus = 0.5 * (levels[:-1] + levels[1:])
        lo = np.concatenate([[-np.inf], taus])
        hi = np.concatenate([taus, [np.inf]])
        num = _phi(np.where(np.isfinite(lo), lo, 0.0)) * np.isfinite(lo) - _phi(
            np.where(np.isfinite(hi), hi, 0.0)
        ) * np.isfinite(hi)
        den = _Phi(hi) - _Phi(lo)
        levels = num / np.maximum(den, 1e-300)
        if np.max(np.abs(levels - prev)) < tol:
            break
        prev = levels.copy()
    taus = 0.5 * (levels[:-1] + levels[1:])

    # Bussgang constants (eqs. 21, 22) with tau_0=-inf, tau_{2^Q}=+inf.
    lo = np.concatenate([[-np.inf], taus])
    hi = np.concatenate([taus, [np.inf]])
    phi_lo = np.where(np.isfinite(lo), _phi(np.where(np.isfinite(lo), lo, 0.0)), 0.0)
    phi_hi = np.where(np.isfinite(hi), _phi(np.where(np.isfinite(hi), hi, 0.0)), 0.0)
    gamma = float(np.sum(levels * (phi_lo - phi_hi)))
    psi = float(np.sum(np.square(levels) * (_Phi(hi) - _Phi(lo))))
    return LloydMaxQuantizer(
        bits=bits,
        levels=levels.astype(np.float64),
        thresholds=taus.astype(np.float64),
        gamma=gamma,
        psi=psi,
    )


def _norm_ppf(p: float, lo: float = -12.0, hi: float = 12.0) -> float:
    """Inverse standard normal CDF by bisection (design-time only)."""
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if _Phi(np.array(mid)) < p:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def encode(x: jnp.ndarray, quantizer: LloydMaxQuantizer) -> jnp.ndarray:
    """Maps real inputs to code indices in [0, 2**Q).  Shape-preserving."""
    taus = quantizer.jnp_thresholds(jnp.result_type(x, jnp.float32))
    # searchsorted: index i such that taus[i-1] < x <= taus[i].
    return jnp.searchsorted(taus, x, side="left").astype(jnp.uint8)


def decode(codes: jnp.ndarray, quantizer: LloydMaxQuantizer, dtype=jnp.float32) -> jnp.ndarray:
    """Maps code indices back to reconstruction levels q_i."""
    levels = quantizer.jnp_levels(dtype)
    return levels[codes.astype(jnp.int32)]


def quantize(x: jnp.ndarray, quantizer: LloydMaxQuantizer) -> jnp.ndarray:
    """Q(x): quantize-dequantize in one go (used by baselines/analysis)."""
    return decode(encode(x, quantizer), quantizer, dtype=x.dtype)
