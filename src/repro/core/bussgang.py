"""Bussgang linearization and aggregate-and-estimate combining (Sec. IV-B).

Proposition 1: for a codebook designed for the standard normal (MMSE
condition) and x ~ N(0, I),

    Q(x) = gamma_Q * x + d,   E[d] = 0,  cov(d) = (psi_Q - gamma_Q^2) I,
    d uncorrelated with x.

Therefore the weighted sum of *dequantized* codes

    q_tilde = sum_k rho_k / (gamma_Q alpha_k) * q_k
            = A (sum_k rho_k g_k) + d_tilde                      (eq. 23)

is a *linear* AWGN observation of the aggregated gradient with

    nu = (psi_Q - gamma_Q^2)/gamma_Q^2 * sum_k (rho_k/alpha_k)^2  (eq. 24).

Everything here is generic over the codebook family (core/codebook.py): the
paper proves Prop. 1 for Lloyd-Max, but the derivation only needs the
codebook's (gamma, psi) moments, which every family computes at design time
-- for the d-dim vq codebook the per-entry moments follow from the isotropy
of N(0, I_d) (gamma = E[<Q(x), x>]/d).  This generic linearization is also
what the EA decoder falls back to for codebooks without scalar cells.

The linearity is what makes the cross-pod collective a plain sum: on hardware,
`q_tilde` is produced by a `psum` over the pod axis of locally-scaled
dequantized codes (see runtime/collectives.py).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.codebook import as_codebook

__all__ = [
    "bussgang_weight",
    "aggregate_codes",
    "aggregate_packed",
    "effective_noise_var",
    "signal_energy",
]


def bussgang_weight(rho: jnp.ndarray, alpha: jnp.ndarray, quantizer):
    """Per-(worker, block) combining weight rho_k / (gamma_Q alpha_{k,b}).

    ``quantizer``: any Codebook (or legacy LloydMaxQuantizer).
    alpha == 0 (empty block) contributes weight 0.
    """
    safe = jnp.where(alpha > 0, alpha, 1.0)
    w = rho / (as_codebook(quantizer).gamma * safe)
    return jnp.where(alpha > 0, w, 0.0)


def aggregate_codes(
    codes: jnp.ndarray,  # (K, nb, n_codes) uint8 codes from K workers
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    quantizer,  # Codebook or legacy LloydMaxQuantizer
) -> jnp.ndarray:
    """q_tilde (nb, M): the Bussgang-weighted aggregate of eq. 23."""
    cb = as_codebook(quantizer)
    deq = cb.decode(codes)  # (K, nb, M)
    w = bussgang_weight(rhos[:, None], alphas, cb)  # (K, nb)
    return jnp.sum(w[..., None] * deq, axis=0)


def aggregate_packed(
    words: jnp.ndarray,  # (K, nb, W) uint32 packed wire words from K workers
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    quantizer,  # Codebook or legacy LloydMaxQuantizer
    m: int,
) -> jnp.ndarray:
    """q_tilde (nb, M) straight from the packed wire payload: the scalar
    families index reconstruction levels through the shift/masked lane
    groups (compression.decode_packed) so the (K, nb, M) uint8 code view
    never materializes at the PS boundary; vq unpacks indices and reads
    centroids.  The index width is the codebook's own ``bits``.
    Numerically identical to ``aggregate_codes(unpack_codes(words), ...)``."""
    cb = as_codebook(quantizer)
    deq = cb.decode_packed(words, m)  # (K, nb, M)
    w = bussgang_weight(rhos[:, None], alphas, cb)  # (K, nb)
    return jnp.sum(w[..., None] * deq, axis=0)


def effective_noise_var(
    alphas: jnp.ndarray,  # (K, nb)
    rhos: jnp.ndarray,  # (K,)
    quantizer,  # Codebook or legacy LloydMaxQuantizer
) -> jnp.ndarray:
    """nu_{g,b} (nb,): AWGN variance of the effective distortion (eq. 24)."""
    safe = jnp.where(alphas > 0, alphas, 1.0)
    terms = jnp.where(alphas > 0, (rhos[:, None] / safe) ** 2, 0.0)
    return as_codebook(quantizer).kappa * jnp.sum(terms, axis=0)


def signal_energy(alphas: jnp.ndarray, rhos: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Per-entry energy of the aggregated block, used for GAMP init:
    E[(g_sum)_n^2] ~= sum_k rho_k^2 ||g_k||^2 / N = sum_k rho_k^2 M/alpha_k^2 / N.
    (Cross terms vanish in expectation for independent worker gradients.)
    """
    safe = jnp.where(alphas > 0, alphas, 1.0)
    terms = jnp.where(alphas > 0, (rhos[:, None] ** 2) * m / jnp.square(safe), 0.0)
    return jnp.sum(terms, axis=0) / n
