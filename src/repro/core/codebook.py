"""Pluggable quantizer codebooks (the paper's Sec. III-A lifted to an axis).

The paper fixes ONE codebook -- the Lloyd-Max scalar quantizer designed for
N(0,1) -- and exploits that the BQCS scaling ``alpha = sqrt(M)/||g||`` makes
every projected entry ~ N(0,1), so a single config-time design serves every
(worker, block, round) with zero signalling.  That property is a property of
the *scaling*, not of Lloyd-Max: any codebook designed once for the standard
normal inherits it.  This module makes the codebook a protocol axis:

  * ``lloyd_max``         -- the paper's quantizer (core/quantizer.py) behind
                             the interface with zero behavior change: same
                             searchsorted encode, same thresholds, same
                             Bussgang constants, bit-identical wire.
  * ``dithered_uniform``  -- shared-seed subtractive-dither uniform quantizer
                             (the QCS-Dither [23] family promoted from a
                             baseline into the real BQCS wire path).  The
                             per-lane dither is a protocol constant derived
                             from the config seed, so -- unlike the paper's
                             criticism of QCS-Dither -- nothing extra crosses
                             the wire.
  * ``vq``                -- FedVQCS-style (arXiv:2204.07692) d-dimensional
                             vector codebook: k-means on N(0,1)^d at config
                             time; one code indexes d measurements, so the
                             wire drops to ceil(log2 L)/d bits/measurement.

Every implementation duck-types the ``LloydMaxQuantizer`` surface the rest of
the repo already consumes (``bits``/``gamma``/``psi``/``kappa``/
``jnp_levels``/``jnp_thresholds``) and adds the generic codec surface
(``encode``/``decode``/``decode_packed``/``quantize``/``n_codes``) plus the
channel hooks the PS needs: scalar families expose cell boundaries (so the
exact truncated-Gaussian Q-EM-GAMP channel of eqs. 12-16 applies, with the
dither as a per-lane edge shift); ``vq`` reports
``supports_exact_channel = False`` and the EA solver falls back to the
Bussgang-linearized AWGN channel, which ``bussgang.py`` already derives
generically from (gamma, psi).

Wire accounting: a codebook packs ``n_codes(M) = M / dim`` indices of width
``bits = ceil(log2 n_levels)`` each -- ``core.compression.pack_codes`` is
already generic over both, so the packed layout is one definition for every
family.

Designs are numpy at config time (like design_lloyd_max); the jnp tables they
produce are what crosses into jit.  New families register via
:func:`register_codebook_family` and become available to every layer
(codec, kernels, GAMP channel, collectives, fed engine) without touching any
of them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import (
    LloydMaxQuantizer,
    _phi,
    design_lloyd_max,
)

__all__ = [
    "Codebook",
    "ScalarCodebook",
    "VectorCodebook",
    "make_codebook",
    "register_codebook_family",
    "as_codebook",
    "vq_nearest",
    "design_dithered_uniform",
    "design_vq",
    "index_bits",
]

CODEBOOK_FAMILIES: Dict[str, Callable] = {}


def register_codebook_family(name: str, builder: Callable) -> None:
    """Registers ``builder(cfg) -> Codebook`` under ``cfg.codebook == name``.
    This is the plugin point: a trained/adaptive/entropy-coded codebook lands
    as one builder function, and every layer downstream picks it up."""
    CODEBOOK_FAMILIES[name] = builder


def index_bits(n_levels: int) -> int:
    """Wire width of one code index: ceil(log2 n_levels), >= 1."""
    return max(1, (n_levels - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class Codebook:
    """Common protocol surface; see module docstring for the contract.

    Attributes:
      family: registry name ("lloyd_max" / "dithered_uniform" / "vq" / ...).
      bits: wire width of one code index, ceil(log2 n_levels).
      dim: measurements per code (1 = scalar; d for vector quantization).
      n_levels: codebook size L (NOT necessarily 2**bits).
      gamma: Bussgang gain E[<Q(x), x>]/dim for x ~ N(0, I_dim)  (eq. 21).
      psi: output second moment E[||Q(x)||^2]/dim                (eq. 22).
    """

    family: str
    bits: int
    dim: int
    n_levels: int
    gamma: float
    psi: float

    @property
    def kappa(self) -> float:
        """(psi - gamma^2)/gamma^2: normalized distortion power (Thm 1)."""
        return (self.psi - self.gamma**2) / (self.gamma**2)

    @property
    def bits_per_entry(self) -> float:
        """Index bits per *measurement* on the wire (excl. word slack)."""
        return self.bits / self.dim

    @property
    def supports_exact_channel(self) -> bool:
        """True iff the EA decoder can run the exact truncated-posterior
        quantized channel (scalar cells); False -> Bussgang AWGN fallback."""
        return self.dim == 1

    def n_codes(self, m: int) -> int:
        """Code lanes for m measurements (m must divide by dim)."""
        if m % self.dim:
            raise ValueError(
                f"codebook dim {self.dim} must divide the measurement count {m}"
            )
        return m // self.dim

    # subclasses implement: encode / decode / decode_packed
    def quantize(self, x: jnp.ndarray) -> jnp.ndarray:
        """Q(x): quantize-dequantize (used by QIHT and analysis)."""
        return self.decode(self.encode(x), x.shape[-1])


# ---------------------------------------------------------------------------
# Scalar families (dim = 1): threshold encode, level-table decode.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScalarCodebook(Codebook):
    """Scalar codebook: L levels, L-1 interior decision thresholds, and an
    optional per-measurement-lane subtractive dither (protocol constant).

    Encode: ``searchsorted(thresholds, y + dither)`` -- identical to the
    pre-refactor quantizer.encode when dither is None.
    Decode: ``levels[code] - dither``.
    """

    levels: np.ndarray = None  # (L,) ascending reconstruction points
    thresholds: np.ndarray = None  # (L - 1,) interior decision thresholds
    dither: Optional[np.ndarray] = None  # (m,) per-lane dither or None

    def jnp_levels(self, dtype=jnp.float32) -> jnp.ndarray:
        return jnp.asarray(self.levels, dtype=dtype)

    def jnp_thresholds(self, dtype=jnp.float32) -> jnp.ndarray:
        return jnp.asarray(self.thresholds, dtype=dtype)

    def jnp_dither(self, dtype=jnp.float32) -> Optional[jnp.ndarray]:
        if self.dither is None:
            return None
        return jnp.asarray(self.dither, dtype=dtype)

    def encode(self, y: jnp.ndarray) -> jnp.ndarray:
        taus = self.jnp_thresholds(jnp.result_type(y, jnp.float32))
        if self.dither is not None:
            y = y + self.jnp_dither(taus.dtype)
        return jnp.searchsorted(taus, y, side="left").astype(jnp.uint8)

    def decode(self, codes: jnp.ndarray, m: Optional[int] = None, dtype=jnp.float32):
        deq = self.jnp_levels(dtype)[codes.astype(jnp.int32)]
        if self.dither is not None:
            deq = deq - self.jnp_dither(dtype)
        return deq if m is None else deq[..., :m]

    def decode_packed(self, words: jnp.ndarray, m: int, dtype=jnp.float32):
        """Dequantize straight from packed wire words (the lane-group level
        lookup of compression.decode_packed); the index view never
        materializes."""
        from repro.core.compression import decode_packed  # deferred: layering

        deq = decode_packed(words, self.bits, m, self.jnp_levels(dtype))
        if self.dither is not None:
            deq = deq - self.jnp_dither(dtype)[:m]
        return deq


# ---------------------------------------------------------------------------
# Vector family (dim = d > 1): nearest-centroid encode, table decode.
# ---------------------------------------------------------------------------


def vq_nearest(y: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """Nearest-centroid indices for grouped measurements.

    ``y`` is (..., M) with M = d * G in the **j-major lane layout**:
    measurement lane ``j*G + g`` is dimension j of group g (G = M // d) --
    contiguous per-dimension lane slices, the same static-slice idiom as the
    packed wire's lane groups, so the fused encoder kernel computes the
    identical scores with ``y[:, j*G:(j+1)*G]`` slices and no transpose.

    Scoring: argmax_l <y_g, c_l> - ||c_l||^2 / 2 (equivalent to min
    distance); ties break to the LOWEST index, and the accumulation order
    (j = 0 carries the -||c||^2/2 term, then j = 1..d-1) is the single
    definition both the XLA path and the kernel follow, so interpret-mode
    kernel runs are bit-identical to this function.
    """
    n_lev, d = centroids.shape
    g = y.shape[-1] // d
    y3 = y.reshape(y.shape[:-1] + (d, g))
    cn = 0.5 * jnp.sum(centroids * centroids, axis=1)  # (L,)
    sc = y3[..., 0, :, None] * centroids[:, 0] - cn  # (..., G, L)
    for j in range(1, d):
        sc = sc + y3[..., j, :, None] * centroids[:, j]
    mx = jnp.max(sc, axis=-1, keepdims=True)
    lvl = jnp.arange(n_lev, dtype=jnp.int32)
    return jnp.min(jnp.where(sc == mx, lvl, n_lev), axis=-1)


@dataclasses.dataclass(frozen=True)
class VectorCodebook(Codebook):
    """FedVQCS-style d-dim vector codebook over N(0,1)^d.

    One code indexes ``dim`` measurements (j-major lane layout, see
    :func:`vq_nearest`), so the wire carries ``bits/dim`` bits per
    measurement.  No exact scalar-cell channel exists (the cells are
    d-dimensional Voronoi regions); the EA decoder falls back to the
    Bussgang-linearized AWGN channel built from (gamma, psi).
    """

    centroids: np.ndarray = None  # (L, d)

    def jnp_centroids(self, dtype=jnp.float32) -> jnp.ndarray:
        return jnp.asarray(self.centroids, dtype=dtype)

    def encode(self, y: jnp.ndarray) -> jnp.ndarray:
        codes = vq_nearest(y, self.jnp_centroids(jnp.result_type(y, jnp.float32)))
        return codes.astype(jnp.uint8 if self.n_levels <= 256 else jnp.int32)

    def decode(self, codes: jnp.ndarray, m: Optional[int] = None, dtype=jnp.float32):
        c = self.jnp_centroids(dtype)
        deq = c[codes.astype(jnp.int32)]  # (..., G, d)
        deq = jnp.swapaxes(deq, -1, -2)  # (..., d, G): j-major lane layout
        deq = deq.reshape(codes.shape[:-1] + (codes.shape[-1] * self.dim,))
        return deq if m is None else deq[..., :m]

    def decode_packed(self, words: jnp.ndarray, m: int, dtype=jnp.float32):
        from repro.core.compression import unpack_codes  # deferred: layering

        return self.decode(unpack_codes(words, self.bits, self.n_codes(m)), m, dtype)


# ---------------------------------------------------------------------------
# Designs (numpy, config time -- shared protocol constants, like
# design_lloyd_max).
# ---------------------------------------------------------------------------


def _as_lloyd_max_codebook(q: LloydMaxQuantizer) -> ScalarCodebook:
    return ScalarCodebook(
        family="lloyd_max",
        bits=q.bits,
        dim=1,
        n_levels=q.n_levels,
        gamma=q.gamma,
        psi=q.psi,
        levels=q.levels,
        thresholds=q.thresholds,
        dither=None,
    )


def design_dithered_uniform(
    bits: int, m: int, seed: int, clip: float = 4.0
) -> ScalarCodebook:
    """Uniform mid-rise quantizer over [-clip, clip] with shared-seed
    subtractive dither u ~ Unif(-delta/2, delta/2) per measurement lane.

    The dither decorrelates the quantization error from the signal (the
    classical dithered-quantization property QCS-Dither [23] relies on);
    regenerating it from the protocol seed on both sides removes the
    signalling overhead the paper criticizes.  Bussgang constants are
    computed by numerical integration over (x ~ N(0,1), u) at design time.
    """
    if not (1 <= bits <= 8):
        raise ValueError(f"bits must be in [1, 8], got {bits}")
    n = 1 << bits
    delta = 2.0 * clip / n
    levels = -clip + delta * (np.arange(n, dtype=np.float64) + 0.5)
    thresholds = -clip + delta * np.arange(1, n, dtype=np.float64)

    # gamma = E[(q(x+u) - u) x], psi = E[(q(x+u) - u)^2]: trapezoid over a
    # fine x-grid weighted by the standard normal pdf, averaged over a
    # midpoint u-grid (exact in the u-average limit; the grids are design-
    # time numpy and the constants are protocol-stable).
    xs = np.linspace(-9.0, 9.0, 6001)
    wx = _phi(xs)
    wx /= np.sum(wx)
    us = (np.arange(33, dtype=np.float64) + 0.5) / 33.0 * delta - 0.5 * delta
    v = xs[:, None] + us[None, :]
    idx = np.clip(np.floor((v + clip) / delta), 0, n - 1).astype(np.int64)
    qxu = levels[idx] - us[None, :]
    q_mean = np.mean(qxu, axis=1)
    gamma = float(np.sum(wx * xs * q_mean))
    psi = float(np.sum(wx * np.mean(np.square(qxu), axis=1)))

    rng = np.random.default_rng((int(seed), 0xD17E))
    dither = rng.uniform(-0.5 * delta, 0.5 * delta, size=m)
    return ScalarCodebook(
        family="dithered_uniform",
        bits=bits,
        dim=1,
        n_levels=n,
        gamma=gamma,
        psi=psi,
        levels=levels,
        thresholds=thresholds,
        dither=dither.astype(np.float64),
    )


def design_vq(
    n_levels: int,
    dim: int,
    seed: int,
    n_samples: int = 1 << 16,
    iters: int = 60,
) -> VectorCodebook:
    """k-means (Lloyd's algorithm) codebook for N(0, I_dim), deterministic in
    the seed.  Empty cells reseed to the sample farthest from its centroid.
    Bussgang constants come from a fresh held-out sample (in-sample moments
    would be optimistically biased toward gamma == psi)."""
    if dim < 2:
        raise ValueError(f"vq dim must be >= 2 (use a scalar family for d=1), got {dim}")
    if not (2 <= n_levels <= 256):
        raise ValueError(f"vq levels must be in [2, 256], got {n_levels}")
    rng = np.random.default_rng((int(seed), 0x7ECB))
    x = rng.standard_normal((n_samples, dim))
    c = x[rng.choice(n_samples, n_levels, replace=False)].copy()
    for _ in range(iters):
        d2 = np.sum(np.square(x[:, None, :] - c[None, :, :]), axis=-1)  # (S, L)
        assign = np.argmin(d2, axis=1)
        counts = np.bincount(assign, minlength=n_levels)
        for j in range(dim):
            sums = np.bincount(assign, weights=x[:, j], minlength=n_levels)
            c[:, j] = np.where(counts > 0, sums / np.maximum(counts, 1), c[:, j])
        if (counts == 0).any():
            worst = np.argsort(-d2[np.arange(n_samples), assign])
            for i, l in enumerate(np.flatnonzero(counts == 0)):
                c[l] = x[worst[i]]
    # Held-out Bussgang moments.
    xh = rng.standard_normal((n_samples, dim))
    d2 = np.sum(np.square(xh[:, None, :] - c[None, :, :]), axis=-1)
    q = c[np.argmin(d2, axis=1)]
    gamma = float(np.mean(np.sum(q * xh, axis=1)) / dim)
    psi = float(np.mean(np.sum(np.square(q), axis=1)) / dim)
    return VectorCodebook(
        family="vq",
        bits=index_bits(n_levels),
        dim=dim,
        n_levels=n_levels,
        gamma=gamma,
        psi=psi,
        centroids=c,
    )


# ---------------------------------------------------------------------------
# Registry + config entry point.
# ---------------------------------------------------------------------------


def _build_lloyd_max(cfg) -> ScalarCodebook:
    return _as_lloyd_max_codebook(design_lloyd_max(cfg.bits))


def _build_dithered_uniform(cfg) -> ScalarCodebook:
    return design_dithered_uniform(cfg.bits, cfg.m, cfg.seed)


def _build_vq(cfg) -> VectorCodebook:
    n_levels = cfg.vq_levels or (1 << cfg.bits)
    if cfg.m % cfg.vq_dim:
        raise ValueError(
            f"vq_dim={cfg.vq_dim} must divide M={cfg.m} "
            f"(block_size // reduction_ratio)"
        )
    return design_vq(n_levels, cfg.vq_dim, cfg.seed)


register_codebook_family("lloyd_max", _build_lloyd_max)
register_codebook_family("dithered_uniform", _build_dithered_uniform)
register_codebook_family("vq", _build_vq)


def make_codebook(cfg) -> Codebook:
    """Builds the protocol codebook named by ``cfg.codebook`` (FedQCSConfig).
    Deterministic in the config, so every pod and the PS derive the same
    tables independently -- no table ever crosses the wire."""
    try:
        builder = CODEBOOK_FAMILIES[cfg.codebook]
    except KeyError:
        raise ValueError(
            f"unknown codebook {cfg.codebook!r} "
            f"(registered: {sorted(CODEBOOK_FAMILIES)})"
        ) from None
    return builder(cfg)


def as_codebook(obj) -> Codebook:
    """Adapts legacy LloydMaxQuantizer instances (tests, benchmarks, external
    callers) to the Codebook surface; Codebooks pass through."""
    if isinstance(obj, Codebook):
        return obj
    if isinstance(obj, LloydMaxQuantizer):
        return _as_lloyd_max_codebook(obj)
    raise TypeError(f"not a codebook or quantizer: {type(obj)!r}")
