"""Whisper-base [arXiv:2212.04356]: enc-dec; conv frontend stubbed (frame
embeddings provided by input_specs)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    is_encoder_decoder=True, n_encoder_layers=6, frontend="audio_frames",
    tie_embeddings=True,
)
