"""DeepSeek-V3 671B [arXiv:2412.19437]: MLA, 1 shared + 256 routed top-8, MTP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,  # first 3 dense layers
    vocab_size=129280, rope_theta=1e4,
    n_experts=256, n_experts_per_tok=8, moe_d_ff=2048,
    n_shared_experts=1, shared_d_ff=2048, first_dense_layers=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    mtp=True,
)
