"""Qwen2-VL-7B [arXiv:2409.12191]: M-RoPE backbone; vision frontend stubbed
(input_specs provides patch embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24),
)
