"""Architecture registry: ``--arch <id>`` lookup + reduced smoke configs."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.configs.base import ModelConfig

ARCHS: Dict[str, str] = {
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "command-r-35b": "repro.configs.command_r_35b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "whisper-base": "repro.configs.whisper_base",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[arch]).CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (small layers/width/
    experts/vocab, as the assignment prescribes)."""
    cfg = get_config(arch)
    common = dict(
        vocab_size=256,
        d_model=64,
        d_ff=128,
        remat_policy="none",
        dtype="float32",
    )
    if cfg.family in ("dense", "moe", "vlm"):
        upd = dict(
            common,
            n_layers=2,
            n_heads=4,
            n_kv_heads=2,
            d_head=16,
        )
        if cfg.family == "vlm":
            upd["mrope_sections"] = (4, 2, 2)
        if cfg.is_moe:
            upd.update(
                n_experts=8,
                n_experts_per_tok=2,
                moe_d_ff=32,
                first_dense_layers=min(1, cfg.first_dense_layers),
                n_shared_experts=cfg.n_shared_experts,
                shared_d_ff=32 if cfg.n_shared_experts else 0,
            )
        if cfg.use_mla:
            upd.update(
                n_layers=2,
                q_lora_rank=32,
                kv_lora_rank=16,
                qk_nope_head_dim=16,
                qk_rope_head_dim=8,
                v_head_dim=16,
                d_head=0,
            )
        return dataclasses.replace(cfg, **upd)
    if cfg.family == "ssm":
        return dataclasses.replace(
            cfg, **common, n_layers=2, ssm_state=16, ssm_head_dim=16, ssm_chunk=16
        )
    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg,
            **common,
            n_layers=4,
            attn_every=2,
            n_heads=4,
            n_kv_heads=4,
            d_head=16,
            ssm_state=16,
            ssm_head_dim=16,
            ssm_chunk=16,
        )
    if cfg.family == "audio":
        return dataclasses.replace(
            cfg, **common, n_layers=2, n_encoder_layers=2, n_heads=4, n_kv_heads=4, d_head=16
        )
    raise ValueError(cfg.family)
