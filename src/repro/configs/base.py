"""ModelConfig: one dataclass describing every architecture in the zoo."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # --- attention flavor ---
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # --- MoE ---
    n_experts: int = 0
    n_experts_per_tok: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # deepseek: first k layers are dense FFN

    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False  # multi-token-prediction auxiliary head

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_kernel: int = 4
    attn_every: int = 0  # hybrid: shared attn block after every k ssm layers

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    frontend: str = "none"  # none | audio_frames | vision_patches

    # --- training ---
    remat_policy: str = "minimal"  # none | minimal | full
    dtype: str = "bfloat16"
    # Fully unroll layer scans (cost-probe mode: XLA's cost_analysis counts a
    # while-loop body once, so roofline probes compile shallow UNROLLED
    # variants and extrapolate; see benchmarks/roofline.py).
    unroll_layers: bool = False

    # --- serving contract ---
    supports_decode: bool = True
    subquadratic: bool = False  # eligible for long_500k

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops accounting)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += d * v
        dh = self.head_dim
        for _ in range(1):  # per-layer cost x n_layers below
            pass
        if self.family in ("dense", "moe", "vlm"):
            per = 0
            if self.use_mla:
                per += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.qk_rope_head_dim
                )
                per += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                per += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.v_head_dim
                )
                per += self.n_heads * self.v_head_dim * d
            else:
                per += d * self.n_heads * dh  # wq
                per += 2 * d * self.n_kv_heads * dh  # wk, wv
                per += self.n_heads * dh * d  # wo
            if self.is_moe:
                per_expert = 3 * d * self.moe_d_ff
                per_moe = self.n_experts * per_expert + d * self.n_experts
                per_moe += self.n_shared_experts * 3 * d * (self.shared_d_ff or self.moe_d_ff)
                dense_per = 3 * d * self.d_ff
                total += self.first_dense_layers * dense_per
                total += (self.n_layers - self.first_dense_layers) * per_moe
                total += self.n_layers * per
            else:
                per += 3 * d * self.d_ff
                total += self.n_layers * per
        elif self.family == "ssm":
            di, ds, hh = self.d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * ds + hh)  # in_proj (z,x,B,C,dt)
            per += di * d  # out_proj
            per += self.ssm_conv_kernel * (di + 2 * ds)
            total += self.n_layers * per
        elif self.family == "hybrid":
            di, ds, hh = self.d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * ds + hh) + di * d + self.ssm_conv_kernel * (di + 2 * ds)
            total += self.n_layers * per
            # one shared attention+mlp block
            total += 2 * d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
            total += 3 * d * self.d_ff
        elif self.family == "audio":
            dh = self.head_dim
            enc_per = 2 * d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh + 2 * d * self.d_ff
            dec_per = enc_per + 2 * d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh
            total += self.n_encoder_layers * enc_per + self.n_layers * dec_per
        if self.mtp:
            total += 3 * d * self.d_ff + 4 * d * self.n_heads * self.head_dim
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        per_moe_active = (
            self.n_experts_per_tok * 3 * d * self.moe_d_ff
            + d * self.n_experts
            + self.n_shared_experts * 3 * d * (self.shared_d_ff or self.moe_d_ff)
        )
        per_moe_full = (
            self.n_experts * 3 * d * self.moe_d_ff
            + d * self.n_experts
            + self.n_shared_experts * 3 * d * (self.shared_d_ff or self.moe_d_ff)
        )
        moe_layers = self.n_layers - self.first_dense_layers
        return self.param_count() - moe_layers * (per_moe_full - per_moe_active)
