"""The paper's own experiment config (Sec. VI): 784-20-10 MLP, K=30 non-IID
devices, Adam(lr=0.003), (R,Q)=(3,3), S_ratio=0.1, B=10 blocks."""
from repro.core.compression import FedQCSConfig

K_DEVICES = 30
N_BAR = 15_910  # 784*20 + 20 + 20*10 + 10
N_BLOCKS = 10
BLOCK_SIZE = 1591
LR = 0.003

FED_CONFIG = FedQCSConfig(
    block_size=BLOCK_SIZE,
    reduction_ratio=3,
    bits=3,
    s_ratio=0.1,
    gamp_iters=25,
)
