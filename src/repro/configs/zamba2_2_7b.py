"""Zamba2-2.7B [arXiv:2411.15242]: Mamba2 backbone + shared attention block."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=10240, vocab_size=32000, rope_theta=1e4,
    ssm_state=64, ssm_head_dim=64, attn_every=6,
    subquadratic=True,
)
