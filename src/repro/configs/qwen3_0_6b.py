"""Qwen3 0.6B [hf:Qwen/Qwen3-0.6B; assignment spec]: qk_norm, GQA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=3072, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    tie_embeddings=True,
)
