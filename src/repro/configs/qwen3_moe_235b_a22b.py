"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-235B-A22B family; assignment spec]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    n_experts=128, n_experts_per_tok=8, moe_d_ff=1536,
)
