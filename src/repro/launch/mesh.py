"""Production mesh construction.

Single pod:  (data=16, model=16)           -- 256 chips (one v5e pod).
Multi-pod:   (pod=2, data=16, model=16)    -- 512 chips across 2 pods.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 wants explicit axis_types (Auto = GSPMD-partitioned); older
    # jax (0.4.x) has no AxisType and every axis is implicitly auto.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(pods: int = 2, data: int = 2, model: int = 2):
    """Small mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=pods*data*model)."""
    return _make_mesh((pods, data, model), ("pod", "data", "model"))


def make_single_device_mesh():
    """1x1x1 mesh: lets every code path (shard_map, specs) run on one CPU."""
    return _make_mesh((1, 1, 1), ("pod", "data", "model"))
