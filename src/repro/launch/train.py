"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --fedqcs --pods 2

On real hardware this binary runs unchanged per-host (jax.distributed
handles process groups); in this container it runs reduced configs on
simulated devices.  Wires together: config registry, synthetic data,
FedQCS train step, checkpointing with auto-resume, straggler/failure
handling via the participation vector, and periodic eval.
"""

import os

if "XLA_FLAGS" not in os.environ:  # simulated devices for the debug mesh
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.checkpoint.checkpointer import Checkpointer  # noqa: E402
from repro.configs.registry import ARCHS, get_config, smoke_config  # noqa: E402
from repro.core.compression import FedQCSConfig  # noqa: E402
from repro.data.synthetic import TokenDataset  # noqa: E402
from repro.launch.mesh import make_debug_mesh, make_production_mesh  # noqa: E402
from repro.optim.adam import OptConfig  # noqa: E402
from repro.runtime import steps  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fedqcs", action="store_true")
    ap.add_argument("--R", type=int, default=3)
    ap.add_argument("--Q", type=int, default=3)
    ap.add_argument("--s-ratio", type=float, default=0.05)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 2x16x16 mesh (needs 512 devices)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--int8-opt-state", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (
        make_production_mesh(multi_pod=args.pods > 1)
        if args.production_mesh
        else make_debug_mesh(args.pods, 2, 2)
    )
    fed = (
        FedQCSConfig(block_size=255, reduction_ratio=args.R, bits=args.Q,
                     s_ratio=args.s_ratio, gamp_iters=15,
                     gamp_variance_mode="scalar")
        if args.fedqcs
        else None
    )
    opt = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                    decay_steps=max(args.steps, 100),
                    state_dtype="int8" if args.int8_opt_state else "float32")
    ds = TokenDataset(cfg.vocab_size, batch=args.batch, seq=args.seq, seed=0)

    state = steps.init_train_state(cfg, opt, fed, jax.random.PRNGKey(0), n_pods=args.pods)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} params={n_params:,} mesh={dict(mesh.shape)} "
          f"fedqcs={'on' if fed else 'off'}"
          + (f" ({fed.bits_per_entry:.2f} bits/entry)" if fed else ""))

    ckpt = Checkpointer(args.ckpt_dir or f"runs/ckpt_{cfg.name}", keep=2)
    start = 0
    if ckpt.latest_step() is not None:
        state, start = ckpt.restore(state)
        print(f"[train] resumed from step {start}")
    step_fn = steps.make_train_step(cfg, opt, fed, mesh, donate=False)

    t0 = time.time()
    for t in range(start, args.steps):
        state, metrics = step_fn(state, ds.get_batch(t))
        if t % args.log_every == 0 or t == args.steps - 1:
            print(f"step {t:5d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time()-t0):.0f}s)")
        if args.ckpt_every and t and t % args.ckpt_every == 0:
            ckpt.save(t, state)
    ckpt.save(args.steps - 1, state)
    ckpt.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
