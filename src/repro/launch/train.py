"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --fedqcs --pods 2

On real hardware this binary runs unchanged per-host (jax.distributed
handles process groups); in this container it runs reduced configs on
simulated devices.  Wires together: config registry, synthetic data,
FedQCS train step, checkpointing with auto-resume, straggler/failure
handling via the participation vector, and periodic eval.

Cohort mode (`--fed-cohort`, DESIGN.md #Fed-engine) replaces the pod
collective with the `repro.fed` engine: the registry model is trained by a
simulated federation of `--clients` devices (Dirichlet `--alpha` dialect
skew over the synthetic language, `--sample-frac` uniform participation,
`--dropout` stragglers, `--snr-db` AWGN uplink):

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --fed-cohort --clients 64 --sample-frac 0.25 --snr-db 10 --steps 20
"""

import os

if "XLA_FLAGS" not in os.environ:  # simulated devices for the debug mesh
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.checkpoint.checkpointer import Checkpointer  # noqa: E402
from repro.configs.registry import ARCHS, get_config, smoke_config  # noqa: E402
from repro.core.compression import FedQCSConfig  # noqa: E402
from repro.data.synthetic import TokenDataset  # noqa: E402
from repro.launch.mesh import make_debug_mesh, make_production_mesh  # noqa: E402
from repro.optim.adam import OptConfig  # noqa: E402
from repro.runtime import steps  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fedqcs", action="store_true")
    ap.add_argument("--R", type=int, default=3)
    ap.add_argument("--Q", type=int, default=3)
    ap.add_argument("--s-ratio", type=float, default=0.05)
    ap.add_argument("--pods", type=int, default=2)
    # -- cohort mode (repro.fed engine) ------------------------------------
    ap.add_argument("--fed-cohort", action="store_true",
                    help="train via the fed cohort engine instead of the pod step")
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=0.0,
                    help="Dirichlet dialect concentration (0 = homogeneous)")
    ap.add_argument("--snr-db", type=float, default=None,
                    help="AWGN uplink SNR in dB (unset = ideal channel)")
    ap.add_argument("--sample-frac", type=float, default=1.0)
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-round straggler probability")
    ap.add_argument("--stream", type=int, default=0, metavar="BATCH",
                    help="streaming PS round mode: fold arrival batches of "
                         "BATCH clients (0 = one-shot barrier; "
                         "DESIGN.md #Streaming-PS)")
    ap.add_argument("--deadline", type=float, default=8.0,
                    help="streaming round deadline (latency units); late "
                         "clients carry full residuals")
    ap.add_argument("--scheduler", default=None,
                    choices=["full", "uniform", "async"],
                    help="default: uniform when --sample-frac < 1, else full")
    ap.add_argument("--server-opt", default="fedadam",
                    choices=["fedadam", "fedavg", "fedavgm"])
    ap.add_argument("--record", default=None, metavar="RUN_DIR",
                    help="cohort mode: record round/eval events to RUN_DIR "
                         "(render with `python -m repro.obs summarize`)")
    ap.add_argument("--client-batch", type=int, default=2)
    ap.add_argument("--chunk", type=int, default=16,
                    help="clients per scan chunk in the vmapped cohort pass")
    ap.add_argument("--interleave", type=int, default=0, metavar="CHUNKS",
                    help="backward-interleaved client encode: stream each "
                         "layout segment to the codec as its layer chunk "
                         "backprops, with the layer stack split into CHUNKS "
                         "segment-aligned stages (0 = off; "
                         "DESIGN.md #Interleave)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="interleave mode: microbatches per client pass")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 2x16x16 mesh (needs 512 devices)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--int8-opt-state", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.fed_cohort:
        return run_fed_cohort(args, cfg)
    mesh = (
        make_production_mesh(multi_pod=args.pods > 1)
        if args.production_mesh
        else make_debug_mesh(args.pods, 2, 2)
    )
    fed = (
        FedQCSConfig(block_size=255, reduction_ratio=args.R, bits=args.Q,
                     s_ratio=args.s_ratio, gamp_iters=15,
                     gamp_variance_mode="scalar")
        if args.fedqcs
        else None
    )
    opt = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                    decay_steps=max(args.steps, 100),
                    state_dtype="int8" if args.int8_opt_state else "float32")
    ds = TokenDataset(cfg.vocab_size, batch=args.batch, seq=args.seq, seed=0)

    state = steps.init_train_state(cfg, opt, fed, jax.random.PRNGKey(0), n_pods=args.pods)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} params={n_params:,} mesh={dict(mesh.shape)} "
          f"fedqcs={'on' if fed else 'off'}"
          + (f" ({fed.bits_per_entry:.2f} bits/entry)" if fed else ""))

    ckpt = Checkpointer(args.ckpt_dir or f"runs/ckpt_{cfg.name}", keep=2)
    start = 0
    if ckpt.latest_step() is not None:
        state, start = ckpt.restore(state)
        print(f"[train] resumed from step {start}")
    step_fn = steps.make_train_step(cfg, opt, fed, mesh, donate=False)

    t0 = time.time()
    for t in range(start, args.steps):
        state, metrics = step_fn(state, ds.get_batch(t))
        if t % args.log_every == 0 or t == args.steps - 1:
            print(f"step {t:5d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time()-t0):.0f}s)")
        if args.ckpt_every and t and t % args.ckpt_every == 0:
            ckpt.save(t, state)
    ckpt.save(args.steps - 1, state)
    ckpt.wait()
    print("[train] done")


def run_fed_cohort(args, cfg):
    """Registry-model training through the repro.fed cohort engine: clients
    hold dialect-skewed synthetic-language streams, the uplink is ideal or
    AWGN at --snr-db, and the PS applies --server-opt to the reconstructed
    aggregate.  Runs on a single (simulated) device — the cohort axis is
    vmap+scan, not a mesh axis."""
    from repro.fed.channel import ChannelConfig
    from repro.fed.engine import CohortConfig, CohortEngine, TokenClientData
    from repro.fed.scheduler import SchedulerConfig
    from repro.fed.server_opt import ServerOptConfig
    from repro.fed.stream import StreamConfig
    from repro.models import model

    fed = FedQCSConfig(block_size=255, reduction_ratio=args.R, bits=args.Q,
                       s_ratio=args.s_ratio, gamp_iters=15,
                       gamp_variance_mode="scalar")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    # --interleave: per-tensor layout split at the producer's chunk bounds +
    # the backward-interleaved segment producer feeding the streamed encode
    layout = None
    grad_segments_fn = None
    if args.interleave:
        from repro.fed.engine import make_interleaved_segments
        from repro.models.segment_tap import interleaved_layout

        layout = interleaved_layout(cfg, fed.block_size,
                                    layer_chunks=args.interleave)
        grad_segments_fn = make_interleaved_segments(
            cfg, layout, grad_accum=args.grad_accum,
            layer_chunks=args.interleave,
        )
    data = TokenClientData(cfg.vocab_size, batch=args.client_batch, seq=args.seq,
                           clients=args.clients, alpha=args.alpha)
    sched_kind = args.scheduler or ("uniform" if args.sample_frac < 1.0 else "full")
    recorder = None
    if args.record:
        from repro.obs import JsonlRecorder

        recorder = JsonlRecorder(
            args.record, config=vars(args), extra={"arch": cfg.name}
        )
    engine = CohortEngine(
        params,
        jax.grad(lambda p, b: model.train_loss(p, b, cfg)),
        data,
        fed_cfg=fed,
        cohort=CohortConfig(method="fedqcs-ae", chunk=args.chunk,
                            encode_stream=bool(args.interleave),
                            grad_accum=args.grad_accum),
        sched=SchedulerConfig(kind=sched_kind, sample_frac=args.sample_frac,
                              dropout_prob=args.dropout),
        chan=(ChannelConfig(kind="awgn", snr_db=args.snr_db)
              if args.snr_db is not None else ChannelConfig()),
        server=ServerOptConfig(kind=args.server_opt, lr=args.lr),
        stream=(StreamConfig(batch_clients=args.stream, deadline=args.deadline)
                if args.stream > 0 else None),
        obs=recorder,
        layout=layout,
        grad_segments_fn=grad_segments_fn,
    )
    if args.interleave:
        peak = grad_segments_fn.peak_live_grad_bytes(args.clients)
        print(f"[fed-cohort] interleave: {len(layout.segments)} segments, "
              f"stages {grad_segments_fn.stage_names}, "
              f"peak live grad+enc {peak / 1e6:.1f} MB "
              f"(whole tree {args.clients * layout.nbar * 4 / 1e6:.1f} MB)")
    probe = TokenDataset(cfg.vocab_size, batch=16, seq=args.seq, seed=123).get_batch(0)
    eval_loss = jax.jit(lambda p: model.train_loss(p, probe, cfg))
    print(f"[fed-cohort] arch={cfg.name} params={n_params:,} "
          f"clients={args.clients} alpha={args.alpha} "
          f"sample_frac={args.sample_frac} "
          f"channel={'awgn@%gdB' % args.snr_db if args.snr_db is not None else 'ideal'} "
          f"server={args.server_opt} ({fed.bits_per_entry:.2f} bits/entry)")
    t0 = time.time()
    for t in range(args.steps):
        stats = engine.run_round()
        if t % args.log_every == 0 or t == args.steps - 1:
            loss = float(eval_loss(engine.params))
            engine.obs.record("eval", {"round": t, "loss": loss})
            print(f"round {t:5d}  eval-loss {loss:.4f}  "
                  f"cohort {stats['cohort']:4.0f} "
                  f"(part {stats['participating']:4.0f})  "
                  f"nmse {stats.get('nmse', float('nan')):.3f}  "
                  f"({time.time() - t0:.0f}s)")
    if recorder is not None:
        recorder.close()
        print(f"[fed-cohort] run log: {recorder.run_dir}")
    print("[fed-cohort] done")


if __name__ == "__main__":
    main()
