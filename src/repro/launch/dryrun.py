import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and record memory/cost/collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

The two XLA_FLAGS lines above MUST stay the first statements: jax locks the
device count at first init, and the production meshes need 512 placeholder
host devices.  (Everything else -- smoke tests, benches -- runs on 1 device.)

Per-cell results land in runs/dryrun/<mesh>__<arch>__<shape>[__variant].json.
"""

import argparse
import json
import sys
import time
import traceback

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks"))

from repro.configs.registry import ARCHS, get_config
from repro.core.compression import FedQCSConfig
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_api
from repro.optim.adam import OptConfig
from repro.runtime import steps

DEFAULT_FED = FedQCSConfig(
    block_size=1024,
    reduction_ratio=4,
    bits=4,
    s_ratio=0.05,
    gamp_iters=8,
    gamp_variance_mode="scalar",
    sparsifier="bisect",  # partition-friendly top-S (see #Perf iteration 3c)
)


def _with_sharding(sds_tree, sharding_tree):
    """Attach shardings to a ShapeDtypeStruct tree."""

    def attach(sds, sh):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)

    return jax.tree_util.tree_map(attach, sds_tree, sharding_tree)


def _opt_cfg(cfg) -> OptConfig:
    big = cfg.param_count() > 50e9
    return OptConfig(state_dtype="int8" if big else "float32")


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
        "host_argument_size_in_bytes",
        "host_output_size_in_bytes",
        "host_temp_size_in_bytes",
    )
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def dryrun_cell(
    arch: str,
    shape: str,
    multi_pod: bool,
    fedqcs: bool = True,
    out_dir: str = "runs/dryrun",
    save_hlo: bool = False,
    impl: str = "auto",
):
    from hlo_analysis import collective_bytes, count_ops  # benchmarks/

    cfg = get_config(arch)
    cell = model_api.SHAPES[shape]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    variant = ""
    if cell.kind == "train":
        variant = "__fedqcs" if (fedqcs and multi_pod) else "__baseline"
        if variant == "__fedqcs" and impl != "auto":
            variant += f"_{impl}"
    tag = f"{mesh_name}__{arch}__{shape}{variant}"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, tag + ".json")
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "variant": variant.strip("_"),
        "kind": cell.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    ok, reason = model_api.supports_cell(cfg, shape)
    if not ok:
        rec["status"] = "skip"
        rec["reason"] = reason
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] SKIP {tag}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_pods = mesh.shape.get("pod", 1)
    t0 = time.time()
    try:
        if cell.kind == "train":
            fed = DEFAULT_FED if (fedqcs and multi_pod) else None
            opt = _opt_cfg(cfg)
            state = steps.init_train_state(
                cfg, opt, fed, jax.random.PRNGKey(0), n_pods=n_pods, abstract=True,
                mesh=mesh, impl=impl,
            )
            shardings = steps.train_state_shardings(state, mesh, fed is not None)
            state_in = _with_sharding(state, shardings)
            batch_sds = model_api.input_specs(cfg, shape)
            batch_in = _with_sharding(batch_sds, steps.batch_shardings(cfg, shape, mesh))
            step_fn = steps.make_train_step(cfg, opt, fed, mesh, donate=True, impl=impl)
            lowered = step_fn.lower(state_in, batch_in)
        elif cell.kind == "prefill":
            params = steps.abstract_params(cfg)
            pshard = steps.sane_param_shardings(params, mesh)
            params_in = _with_sharding(params, pshard)
            batch_sds = model_api.input_specs(cfg, shape)
            batch_in = _with_sharding(batch_sds, steps.batch_shardings(cfg, shape, mesh))
            step_fn = steps.make_prefill_step(cfg, mesh)
            lowered = step_fn.lower(params_in, batch_in)
        else:  # decode
            params = steps.abstract_params(cfg)
            pshard = steps.sane_param_shardings(params, mesh)
            params_in = _with_sharding(params, pshard)
            specs = model_api.input_specs(cfg, shape)
            shardings = steps.batch_shardings(cfg, shape, mesh)
            inputs = _with_sharding(specs, shardings)
            step_fn = steps.make_decode_step(cfg, mesh, donate=True)
            lowered = step_fn.lower(params_in, inputs["cache"], inputs["tokens"], inputs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = dict(compiled.cost_analysis() or {})
        hlo = compiled.as_text()
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_devices=mesh.size,
            cost={k: float(v) for k, v in cost.items() if isinstance(v, (int, float))},
            memory=_mem_dict(compiled),
            collective_bytes_per_device=collective_bytes(hlo),
            collective_ops=count_ops(hlo),
        )
        if save_hlo:
            with open(os.path.join(out_dir, tag + ".hlo"), "w") as f:
                f.write(hlo)
        print(
            f"[dryrun] OK {tag}: compile {t_compile:.0f}s "
            f"flops={cost.get('flops', 0):.3e} "
            f"coll={rec['collective_bytes_per_device'].get('total', 0):.3e}B"
        )
    except Exception as e:  # record failures -- they are bugs to fix
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] ERROR {tag}: {type(e).__name__}: {str(e)[:200]}")
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(model_api.SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--baseline", action="store_true", help="train without FedQCS")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--impl", default="auto")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = (
        [(a, s) for a in ARCHS for s in model_api.SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    n_fail = 0
    for mp in meshes:
        for arch, shape in cells:
            out = os.path.join(args.out)
            if args.skip_existing:
                cfg0 = get_config(arch)
                kind = model_api.SHAPES[shape].kind
                var = ("__fedqcs" if (not args.baseline and mp) else "__baseline") if kind == "train" else ""
                tag = f"{'2x16x16' if mp else '16x16'}__{arch}__{shape}{var}"
                pth = os.path.join(out, tag + ".json")
                if os.path.exists(pth):
                    import json as _json
                    st = _json.load(open(pth)).get("status")
                    if st in ("ok", "skip"):
                        continue
            rec = dryrun_cell(
                arch, shape, mp, fedqcs=not args.baseline, out_dir=out,
                save_hlo=args.save_hlo, impl=args.impl,
            )
            n_fail += rec.get("status") == "error"
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
