"""Monotonic-clock spans with one naming scheme for JSONL and profiler.

The federated engines wrap each round phase in a span::

    spans = SpanCollector()
    with span("client_pass", spans):
        ...
    spans.ms  # {"client_pass": 12.3, ...}

Span names are the phase vocabulary shared by the ``phase_ms`` field of
round events, the ``span`` event kind, and (when enabled) the
``jax.profiler.TraceAnnotation`` labels -- a profile and a run log line up
by construction.  Canonical engine phase names: ``client_pass``,
``encode``, ``uplink``, ``fold``, ``decode``, ``apply``; plus the
SUB-phases of the streamed client pass, ``backward`` (time inside the
gradient producer's next() -- for the interleaved producer, one stage's
VJP dispatch) and ``encode_overlap`` (the per-segment encode dispatch
riding on the backward sweep).  Sub-phases nest inside ``client_pass``,
so aggregations that sum phases must exclude :data:`SUB_PHASES` or the
nested time double-counts.

Overhead: with ``collector=None`` and annotations off, ``span`` is two
``time.monotonic()`` calls -- cheap enough to leave in place permanently.
jax.profiler annotations engage only when REPRO_TRACE_ANNOTATIONS=1 is set
in the environment (checked once at import), so the default path never
touches the profiler.

Timing caveat: spans measure host wall-clock.  JAX dispatch is async, so a
span around a jitted call measures dispatch unless the caller blocks;
engines block once per round when pulling metrics anyway, which lands the
full device time in the phase that materializes results.
"""

from __future__ import annotations

import functools
import os
import time
from contextlib import contextmanager
from typing import Dict, Optional

__all__ = ["SpanCollector", "span", "traced", "ANNOTATE", "SUB_PHASES"]

# Checked once at import: profiler annotations are opt-in by environment.
ANNOTATE = os.environ.get("REPRO_TRACE_ANNOTATIONS", "") == "1"

# Phases that time a slice of another phase (they nest inside client_pass):
# excluded when summing phase_ms into a round total.
SUB_PHASES = frozenset({"backward", "encode_overlap"})


class SpanCollector:
    """Accumulates span durations by name (ms, summed over re-entries)."""

    def __init__(self) -> None:
        self.ms: Dict[str, float] = {}

    def add(self, name: str, ms: float) -> None:
        self.ms[name] = self.ms.get(name, 0.0) + ms

    def drain(self) -> Dict[str, float]:
        """Returns the accumulated timings and resets the collector."""
        out, self.ms = self.ms, {}
        return out


@contextmanager
def span(name: str, collector: Optional[SpanCollector] = None):
    """Times a block; records into ``collector`` (None = annotation only)."""
    if ANNOTATE:
        from jax.profiler import TraceAnnotation

        with TraceAnnotation(name):
            t0 = time.monotonic()
            try:
                yield
            finally:
                if collector is not None:
                    collector.add(name, (time.monotonic() - t0) * 1e3)
        return
    t0 = time.monotonic()
    try:
        yield
    finally:
        if collector is not None:
            collector.add(name, (time.monotonic() - t0) * 1e3)


def traced(name: Optional[str] = None, collector: Optional[SpanCollector] = None):
    """Decorator form of :func:`span`; name defaults to the function name."""

    def wrap(fn):
        label = name or fn.__name__

        @functools.wraps(fn)
        def inner(*args, **kwargs):
            with span(label, collector):
                return fn(*args, **kwargs)

        return inner

    return wrap
