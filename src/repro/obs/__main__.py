"""``python -m repro.obs`` -- run-log toolchain entry point."""

import sys

from repro.obs.reader import main

try:
    sys.exit(main())
except BrokenPipeError:  # `... | head` closing the pipe is not an error
    sys.exit(0)
