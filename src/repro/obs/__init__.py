"""Unified telemetry layer (DESIGN.md #Observability).

Three small pieces, composable and individually optional:

  * recorder -- the MetricsRecorder protocol and its three sinks
    (NullRecorder / InMemoryRecorder / JsonlRecorder).  Engines take a
    recorder at construction; ``recorder.active`` is a *static* property so
    the jitted graphs they build never branch on it at trace time.
  * schema -- the versioned event envelope and the validators the CI smoke
    and the reader share.
  * trace -- monotonic-clock spans (contextmanager + decorator) with an
    optional jax.profiler.TraceAnnotation passthrough, so profiler traces
    and JSONL phase timings share one naming scheme.

The reader/CLI toolchain lives in reader.py and runs as
``python -m repro.obs summarize|tail|compare|validate <run_dir>``.

This package deliberately imports nothing from repro.fed / repro.core --
observability sits *below* the layers it instruments.
"""

from repro.obs.recorder import (
    NULL_RECORDER,
    InMemoryRecorder,
    JsonlRecorder,
    MetricsRecorder,
    NullRecorder,
)
from repro.obs.schema import SCHEMA_VERSION, validate_event, validate_meta
from repro.obs.trace import SpanCollector, span, traced

__all__ = [
    "MetricsRecorder",
    "NullRecorder",
    "InMemoryRecorder",
    "JsonlRecorder",
    "NULL_RECORDER",
    "SCHEMA_VERSION",
    "validate_event",
    "validate_meta",
    "SpanCollector",
    "span",
    "traced",
]
