"""MetricsRecorder protocol and its sinks (DESIGN.md #Observability).

Contract with the engines:

  * ``recorder.active`` is read ONCE, at engine construction, and treated as
    static -- the jitted graphs an engine builds differ between an active
    and a null recorder (extra auxiliary outputs), but never re-trace when
    events are recorded.  The null recorder therefore costs nothing on the
    hot path: no aux outputs are computed, ``record`` is a constant no-op.
  * ``record(kind, payload)`` is called on the HOST, at round boundaries,
    with plain-Python payloads (floats/ints/strs/lists) -- never inside a
    jitted function.  Callers are responsible for pulling device values
    before recording (one blocking transfer per round, amortized).
  * ``close()`` is idempotent; JsonlRecorder flushes per event so a crashed
    run still leaves a readable prefix.

Sinks:

  NullRecorder      active=False; every method a no-op.  Module singleton
                    NULL_RECORDER is the default everywhere.
  InMemoryRecorder  active=True; keeps the enveloped events in ``.events``
                    (tests, notebooks).
  JsonlRecorder     active=True; appends one JSON line per event to
                    ``<run_dir>/events.jsonl`` and writes ``meta.json``
                    (run id, schema version, config, git SHA, jax versions,
                    backend) at construction.
"""

from __future__ import annotations

import json
import os
import subprocess
import time
import uuid
from typing import Any, Dict, List, Mapping, Optional, Protocol, runtime_checkable

from repro.obs.schema import SCHEMA_VERSION

__all__ = [
    "MetricsRecorder",
    "NullRecorder",
    "InMemoryRecorder",
    "JsonlRecorder",
    "NULL_RECORDER",
]


@runtime_checkable
class MetricsRecorder(Protocol):
    """Anything with a static ``active`` flag and a host-side ``record``."""

    @property
    def active(self) -> bool: ...

    def record(self, kind: str, payload: Mapping[str, Any]) -> None: ...

    def close(self) -> None: ...


class NullRecorder:
    """The do-nothing sink; ``active`` is False so engines skip aux work."""

    active = False

    def record(self, kind: str, payload: Mapping[str, Any]) -> None:
        pass

    def close(self) -> None:
        pass


NULL_RECORDER = NullRecorder()


def _jsonable(v: Any) -> Any:
    """Coerces numpy/jax scalars and arrays into JSON-native values."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, Mapping):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    item = getattr(v, "item", None)
    if item is not None and getattr(v, "ndim", 1) == 0:
        return item()
    tolist = getattr(v, "tolist", None)
    if tolist is not None:
        return tolist()
    return str(v)


class _EnvelopingRecorder:
    """Shared envelope logic: v / kind / seq / t stamped on every event."""

    active = True

    def __init__(self) -> None:
        self._seq = 0
        self._t0 = time.monotonic()

    def _envelope(self, kind: str, payload: Mapping[str, Any]) -> Dict[str, Any]:
        ev = {
            "v": SCHEMA_VERSION,
            "kind": kind,
            "seq": self._seq,
            "t": round(time.monotonic() - self._t0, 6),
        }
        for k, v in payload.items():
            if k not in ev:  # payload may not shadow the envelope
                ev[k] = _jsonable(v)
        self._seq += 1
        return ev


class InMemoryRecorder(_EnvelopingRecorder):
    """Keeps enveloped events in a list -- tests and notebooks."""

    def __init__(self) -> None:
        super().__init__()
        self.events: List[Dict[str, Any]] = []

    def record(self, kind: str, payload: Mapping[str, Any]) -> None:
        self.events.append(self._envelope(kind, payload))

    def close(self) -> None:
        pass


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=5,
        )
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def _runtime_meta() -> Dict[str, Any]:
    meta: Dict[str, Any] = {}
    try:
        import jax

        meta["jax_version"] = jax.__version__
        try:
            meta["backend"] = jax.default_backend()
        except Exception:  # backend init can fail on exotic setups
            meta["backend"] = None
    except Exception:
        pass
    try:
        import jaxlib

        meta["jaxlib_version"] = jaxlib.__version__
    except Exception:
        pass
    return meta


class JsonlRecorder(_EnvelopingRecorder):
    """Appends events to ``<run_dir>/events.jsonl``; meta.json at open.

    ``run_dir`` is created (parents included).  ``config`` is any
    JSON-able mapping describing the run (typically dataclass asdict()s);
    ``extra`` merges additional top-level meta fields.
    """

    def __init__(
        self,
        run_dir: str,
        config: Optional[Mapping[str, Any]] = None,
        extra: Optional[Mapping[str, Any]] = None,
    ) -> None:
        super().__init__()
        self.run_dir = str(run_dir)
        os.makedirs(self.run_dir, exist_ok=True)
        self.run_id = os.path.basename(os.path.normpath(self.run_dir)) or uuid.uuid4().hex[:12]
        meta: Dict[str, Any] = {
            "run_id": self.run_id,
            "schema_version": SCHEMA_VERSION,
            "created_unix": time.time(),
            "git_sha": _git_sha(),
            **_runtime_meta(),
        }
        if config is not None:
            meta["config"] = _jsonable(config)
        if extra:
            meta.update({str(k): _jsonable(v) for k, v in extra.items()})
        with open(os.path.join(self.run_dir, "meta.json"), "w") as f:
            json.dump(meta, f, indent=2, sort_keys=True)
            f.write("\n")
        self._fh = open(os.path.join(self.run_dir, "events.jsonl"), "a")

    def record(self, kind: str, payload: Mapping[str, Any]) -> None:
        if self._fh is None:
            raise ValueError("record() after close()")
        self._fh.write(json.dumps(self._envelope(kind, payload)) + "\n")
        self._fh.flush()  # crashed runs keep a readable prefix

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "JsonlRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
