"""Versioned event schema for run logs (DESIGN.md #Observability).

Every line of ``events.jsonl`` is one JSON object -- the *envelope* plus the
event's payload merged flat:

    {"v": 1, "kind": "round", "seq": 0, "t": 12.034, ...payload...}

  v     int    SCHEMA_VERSION the writer spoke
  kind  str    event type (see KIND_REQUIRED for the known kinds)
  seq   int    0-based monotone sequence number within the run
  t     float  seconds since the recorder was opened (monotonic clock)

Known kinds and their required payload fields:

  round   per-round record from the federated engine -- requires
          round / cohort / participating; everything else (nmse, wire bytes,
          gamp health, buffer stats, phase_ms, ...) is optional so the
          schema survives engines that don't compute a given counter.
  span    one timed phase -- requires name / ms.
  eval    an evaluation snapshot (accuracy, loss) -- requires round.
  note    freeform annotation -- no required fields.

Readers must ignore unknown payload fields (writers may add counters
without a version bump); unknown *kinds* are skipped with a warning.  The
version bumps only when an envelope field or a required payload field
changes meaning.

``meta.json`` (one per run directory) requires run_id / schema_version /
created_unix; the writer also records config, git SHA, jax/jaxlib versions,
and the default backend when it can.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Mapping

__all__ = [
    "SCHEMA_VERSION",
    "ENVELOPE_FIELDS",
    "KIND_REQUIRED",
    "META_REQUIRED",
    "validate_event",
    "validate_meta",
    "validate_run",
]

SCHEMA_VERSION = 1

ENVELOPE_FIELDS = ("v", "kind", "seq", "t")

# kind -> payload fields that must be present (beyond the envelope)
KIND_REQUIRED: Dict[str, tuple] = {
    "round": ("round", "cohort", "participating"),
    "span": ("name", "ms"),
    "eval": ("round",),
    "note": (),
}

META_REQUIRED = ("run_id", "schema_version", "created_unix")


def validate_event(event: Mapping[str, Any]) -> List[str]:
    """Returns a list of problems (empty == valid).

    Unknown payload fields never fail validation; unknown kinds do, since a
    reader can't know their required fields."""
    problems: List[str] = []
    for f in ENVELOPE_FIELDS:
        if f not in event:
            problems.append(f"missing envelope field {f!r}")
    if problems:
        return problems
    if event["v"] != SCHEMA_VERSION:
        problems.append(f"schema version {event['v']!r} != {SCHEMA_VERSION}")
    kind = event["kind"]
    if kind not in KIND_REQUIRED:
        problems.append(f"unknown kind {kind!r}")
        return problems
    for f in KIND_REQUIRED[kind]:
        if f not in event:
            problems.append(f"kind {kind!r} missing required field {f!r}")
    if not isinstance(event["seq"], int) or event["seq"] < 0:
        problems.append(f"seq must be a non-negative int, got {event['seq']!r}")
    return problems


def validate_meta(meta: Mapping[str, Any]) -> List[str]:
    problems = [f"missing meta field {f!r}" for f in META_REQUIRED if f not in meta]
    if not problems and meta["schema_version"] != SCHEMA_VERSION:
        problems.append(
            f"meta schema_version {meta['schema_version']!r} != {SCHEMA_VERSION}"
        )
    return problems


def validate_run(meta: Mapping[str, Any], events: Iterable[Mapping[str, Any]]):
    """Validates a whole run: meta, every event, and seq monotonicity."""
    problems = [f"meta: {p}" for p in validate_meta(meta)]
    prev = -1
    for i, ev in enumerate(events):
        for p in validate_event(ev):
            problems.append(f"event {i}: {p}")
        seq = ev.get("seq")
        if isinstance(seq, int):
            if seq <= prev:
                problems.append(f"event {i}: seq {seq} not monotone (prev {prev})")
            prev = seq
    return problems
