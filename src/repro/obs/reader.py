"""Run-log reader + CLI: ``python -m repro.obs summarize|tail|compare|validate``.

Reads the ``runs/<run_id>/events.jsonl`` + ``meta.json`` pair a
JsonlRecorder writes and renders:

  summarize  meta header, per-round table (nmse / wire bytes / gamp health /
             buffer stats / wall-clock), decode-health + phase-time summary
  tail       the last N events, raw
  compare    aggregate deltas between two run dirs (same columns)
  validate   schema check (exit 1 on problems) -- what the CI smoke calls

Everything degrades gracefully: columns a run never recorded are shown as
"-", unknown event kinds are skipped.  Pure stdlib -- importing this module
must not pull in jax.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict, Iterator, List, Optional, Sequence

from repro.obs.schema import validate_run

__all__ = ["load_meta", "iter_events", "load_rounds", "summarize", "compare", "main"]


def load_meta(run_dir: str) -> Dict[str, Any]:
    with open(os.path.join(run_dir, "meta.json")) as f:
        return json.load(f)


def iter_events(run_dir: str) -> Iterator[Dict[str, Any]]:
    path = os.path.join(run_dir, "events.jsonl")
    if not os.path.exists(path):
        return
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield json.loads(line)


def load_rounds(run_dir: str) -> List[Dict[str, Any]]:
    return [ev for ev in iter_events(run_dir) if ev.get("kind") == "round"]


def _fmt(v: Any, spec: str = "") -> str:
    if v is None:
        return "-"
    try:
        return format(v, spec)
    except (TypeError, ValueError):
        return str(v)


def _bytes_h(v: Any) -> str:
    if v is None:
        return "-"
    v = float(v)
    for unit in ("B", "KB", "MB", "GB"):
        if v < 1024 or unit == "GB":
            return f"{v:.1f}{unit}" if unit != "B" else f"{int(v)}B"
        v /= 1024
    return "-"


# (header, event field, format spec or callable)
_ROUND_COLS = (
    ("rnd", "round", "d"),
    ("cohort", "cohort", "d"),
    ("part", "participating", "d"),
    ("nmse", "nmse", ".3e"),
    ("up", "wire_up_bytes", _bytes_h),
    ("down", "wire_down_bytes", _bytes_h),
    ("it_mean", "gamp_iters_mean", ".1f"),
    ("conv%", "gamp_converged_frac", ".0%"),
    ("sat%", "clip_saturation", ".1%"),
    ("buf", "buffer_peak_occupancy", "d"),
    ("ms", "round_ms", ".0f"),
)


def _table(rows: List[List[str]], headers: Sequence[str]) -> str:
    widths = [
        max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
        for i, h in enumerate(headers)
    ]
    def line(cells):
        return "  ".join(c.rjust(w) for c, w in zip(cells, widths))
    return "\n".join([line(headers)] + [line(r) for r in rows])


def _round_table(rounds: List[Dict[str, Any]]) -> str:
    # drop columns no round ever recorded, so barrier runs don't show buf=-
    cols = [c for c in _ROUND_COLS if any(r.get(c[1]) is not None for r in rounds)]
    rows = []
    for r in rounds:
        row = []
        for _, field, spec in cols:
            v = r.get(field)
            row.append(spec(v) if callable(spec) else _fmt(v, spec))
        rows.append(row)
    return _table(rows, [c[0] for c in cols])


def _mean(rounds: List[Dict[str, Any]], field: str) -> Optional[float]:
    vals = [float(r[field]) for r in rounds if r.get(field) is not None]
    return sum(vals) / len(vals) if vals else None


def _health_summary(rounds: List[Dict[str, Any]]) -> List[str]:
    out = []
    pairs = (
        ("gamp iters (mean)", "gamp_iters_mean", ".2f"),
        ("gamp converged frac", "gamp_converged_frac", ".1%"),
        ("quantizer clip saturation", "clip_saturation", ".2%"),
        ("unconverged survivors", "unconverged_survivors", ".1f"),
        ("buffer peak occupancy", "buffer_peak_occupancy", ".1f"),
        ("dedup drops / round", "batches_rejected_dup", ".2f"),
        ("backpressure drains / round", "batches_backpressure", ".2f"),
        ("post-combine nu (mean)", "nu_channel", ".3e"),
        ("CSI target mismatch", "csi_target_mismatch", ".3e"),
    )
    for label, field, spec in pairs:
        m = _mean(rounds, field)
        if m is not None:
            out.append(f"  {label:<28s} {format(m, spec)}")
    return out


def _phase_summary(rounds: List[Dict[str, Any]]) -> List[str]:
    from repro.obs.trace import SUB_PHASES

    acc: Dict[str, List[float]] = {}
    for r in rounds:
        for name, ms in (r.get("phase_ms") or {}).items():
            acc.setdefault(name, []).append(float(ms))
    if not acc:
        return []
    # sub-phases (backward/encode_overlap) nest inside client_pass: they get
    # a share of the round but must not inflate the denominator
    total = sum(sum(v) for k, v in acc.items() if k not in SUB_PHASES)
    out = []
    for name, vals in sorted(acc.items(), key=lambda kv: -sum(kv[1])):
        share = sum(vals) / total if total else 0.0
        label = f"{name} *" if name in SUB_PHASES else name
        out.append(
            f"  {label:<14s} {sum(vals) / len(vals):8.1f} ms/round  {share:5.1%}"
        )
    if any(k in SUB_PHASES for k in acc):
        out.append("  (* nested inside client_pass; excluded from totals)")
    return out


def summarize(run_dir: str) -> str:
    meta = load_meta(run_dir)
    rounds = load_rounds(run_dir)
    lines = [
        f"run {meta.get('run_id')}  "
        f"(schema v{meta.get('schema_version')}, "
        f"jax {meta.get('jax_version', '?')}, "
        f"backend {meta.get('backend', '?')}, "
        f"git {str(meta.get('git_sha'))[:10]})",
    ]
    if not rounds:
        return "\n".join(lines + ["no round events recorded"])
    lines += ["", _round_table(rounds)]
    health = _health_summary(rounds)
    if health:
        lines += ["", "decode health (mean over rounds):"] + health
    phases = _phase_summary(rounds)
    if phases:
        lines += ["", "phase wall-clock:"] + phases
    return "\n".join(lines)


_COMPARE_FIELDS = (
    ("nmse", "nmse", ".3e"),
    ("round_ms", "round_ms", ".1f"),
    ("wire_up_bytes", "wire_up_bytes", ".0f"),
    ("gamp_iters_mean", "gamp_iters_mean", ".2f"),
    ("gamp_converged_frac", "gamp_converged_frac", ".3f"),
    ("clip_saturation", "clip_saturation", ".4f"),
)


def compare(run_a: str, run_b: str) -> str:
    ra, rb = load_rounds(run_a), load_rounds(run_b)
    name_a = load_meta(run_a).get("run_id", run_a)
    name_b = load_meta(run_b).get("run_id", run_b)
    headers = ["metric", name_a, name_b, "delta"]
    rows = []
    for label, field, spec in _COMPARE_FIELDS:
        ma, mb = _mean(ra, field), _mean(rb, field)
        if ma is None and mb is None:
            continue
        delta = (mb - ma) if (ma is not None and mb is not None) else None
        rows.append([label, _fmt(ma, spec), _fmt(mb, spec), _fmt(delta, "+" + spec)])
    rows.append(["rounds", str(len(ra)), str(len(rb)), "-"])
    return _table(rows, headers)


def tail(run_dir: str, n: int = 10) -> str:
    events = list(iter_events(run_dir))[-n:]
    return "\n".join(json.dumps(ev) for ev in events)


def validate_dir(run_dir: str) -> List[str]:
    try:
        meta = load_meta(run_dir)
    except (OSError, json.JSONDecodeError) as e:
        return [f"meta.json unreadable: {e}"]
    try:
        events = list(iter_events(run_dir))
    except json.JSONDecodeError as e:
        return [f"events.jsonl unreadable: {e}"]
    problems = validate_run(meta, events)
    if not events:
        problems.append("no events recorded")
    return problems


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    p = argparse.ArgumentParser(
        prog="python -m repro.obs", description="run-log toolchain"
    )
    sub = p.add_subparsers(dest="cmd", required=True)
    for name in ("summarize", "tail", "validate"):
        sp = sub.add_parser(name)
        sp.add_argument("run_dir")
        if name == "tail":
            sp.add_argument("-n", type=int, default=10)
    cp = sub.add_parser("compare")
    cp.add_argument("run_a")
    cp.add_argument("run_b")
    args = p.parse_args(argv)

    if args.cmd == "summarize":
        print(summarize(args.run_dir))
    elif args.cmd == "tail":
        print(tail(args.run_dir, args.n))
    elif args.cmd == "compare":
        print(compare(args.run_a, args.run_b))
    elif args.cmd == "validate":
        problems = validate_dir(args.run_dir)
        if problems:
            for prob in problems:
                print(f"INVALID: {prob}", file=sys.stderr)
            return 1
        print(f"{args.run_dir}: valid")
    return 0
