"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors its kernel's *semantics* exactly (same math, same
iteration counts, same tie-breaking) using only jnp ops, so
``assert_allclose(kernel(...), ref(...))`` is meaningful across shape/dtype
sweeps.  These are also the implementations used when
``FedQCSConfig.use_kernels=False``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _scale_project_ref(blocks: jnp.ndarray, a_t: jnp.ndarray):
    """alpha = sqrt(M)/||block|| (0 for dead blocks) and y = alpha * b @ A^T
    -- the single scale/projection definition every encode-oracle branch
    shares (same ops/order as the kernels' in-VMEM version)."""
    m = a_t.shape[1]
    sq = jnp.sum(blocks * blocks, axis=1, keepdims=True)
    alive = sq > 1e-30
    inv_norm = jax.lax.rsqrt(jnp.where(alive, sq, 1.0))
    alpha = jnp.where(alive, jnp.sqrt(jnp.float32(m)) * inv_norm, 0.0)
    return (blocks * alpha) @ a_t, alpha[:, 0]


def bqcs_encode_ref(blocks: jnp.ndarray, a_t: jnp.ndarray, taus: jnp.ndarray):
    """(nb, N), (N, M), (2^Q-1,) -> codes (nb, M) int32, alpha (nb,)."""
    y, alpha = _scale_project_ref(blocks, a_t)
    codes = jnp.sum((y[:, :, None] > taus[None, None, :]).astype(jnp.int32), axis=-1)
    return codes, alpha


def bqcs_encode_fused_ref(
    blocks: jnp.ndarray,
    residual: jnp.ndarray,
    a_t: jnp.ndarray,
    taus: jnp.ndarray,
    s: int,
    bits: int,
    iters: int = 26,
    dither: jnp.ndarray | None = None,
    centroids: jnp.ndarray | None = None,
):
    """Single-pass fused encoder oracle: error-feedback add -> bisection
    top-S -> scale/project/encode -> lane-group uint32 packing.

    The encode stage follows the codebook family: threshold bucketize
    against ``taus`` (plus the optional per-lane subtractive ``dither``), or
    nearest-centroid against ``centroids`` (L, d) when given -- the latter
    via ``core.codebook.vq_nearest``, the single scoring definition the
    kernel mirrors.  Composes the stage oracles plus
    ``core.compression.pack_codes`` so the packed wire layout has exactly
    one jnp definition.  Returns
    (words uint32 (nb, W), alpha (nb,), new_residual (nb, N)).
    """
    from repro.core.compression import pack_codes

    carry = blocks + residual
    sparse, resid = block_topk_ref(carry, s, iters=iters)
    y, alpha = _scale_project_ref(sparse, a_t)
    if centroids is not None:
        from repro.core.codebook import vq_nearest

        codes = vq_nearest(y, centroids)
    else:
        if dither is not None:
            # the dithered encoder compares y + u against the thresholds,
            # identically to the kernel's y += dither before the bucketize
            y = y + dither[None, :]
        codes = jnp.sum(
            (y[:, :, None] > taus[None, None, :]).astype(jnp.int32), axis=-1
        )
    return pack_codes(codes.astype(jnp.uint8), bits), alpha, resid


def block_topk_ref(blocks: jnp.ndarray, s: int, iters: int = 26):
    """Bisection-threshold top-S (mirrors block_topk kernel, incl. ties)."""
    mag = jnp.abs(blocks)
    hi = jnp.max(mag, axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum((mag >= mid).astype(jnp.int32), axis=1, keepdims=True)
        too_many = count > s
        return jnp.where(too_many, mid, lo), jnp.where(too_many, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    keep = (mag >= hi) | (mag == jnp.max(mag, axis=1, keepdims=True))
    sparse = jnp.where(keep, blocks, 0.0)
    return sparse, blocks - sparse


def qgamp_step_ref(
    ghat, nu_g, shat, theta, codes, alpha, lo_tau, hi_tau, a,
    n_components=3, em=True,
):
    """One scalar-variance quantized-channel Q-EM-GAMP iteration (mirrors the
    qgamp_step kernel).  The truncated-Gaussian channel is core.gamp's
    `_quantized_channel` itself -- the ground truth the kernel must match --
    so the channel numerics exist in exactly two places: core and kernel.

    codes (nb, M) int; alpha (nb, 1) strictly positive; lo_tau/hi_tau (2^Q,)
    bin-edge tables (sentinels at the ends); theta packed (nb, 1+3L).
    """
    from repro.core.gamp import _quantized_channel

    L = n_components
    m = codes.shape[1]
    n = ghat.shape[1]
    al2 = alpha * alpha

    nu_p = jnp.maximum(al2 / m * jnp.sum(nu_g, axis=1, keepdims=True), _EPS)
    phat = alpha * (ghat @ a.T) - nu_p * shat

    xpost, nu_x = _quantized_channel(phat, nu_p, codes, lo_tau, hi_tau)

    shat_new = (xpost - phat) / nu_p
    nu_s = jnp.maximum((1.0 - nu_x / nu_p) / nu_p, _EPS)
    nu_r = 1.0 / jnp.maximum(al2 / m * jnp.sum(nu_s, axis=1, keepdims=True), _EPS)

    rhat = ghat + nu_r * (alpha * (shat_new @ a))
    gh, ng, th = _gm_input_and_em(rhat, nu_r, theta, n, L, em)
    return gh, ng, shat_new, th


def _gm_input_and_em(rhat, v, theta, n, L, em):
    """Shared input-channel + EM tail of the two GAMP-step oracles."""
    lam0 = theta[:, 0:1]
    lam = theta[:, 1 : 1 + L]
    mu = theta[:, 1 + L : 1 + 2 * L]
    phi = theta[:, 1 + 2 * L : 1 + 3 * L]
    inv_sqrt_2pi = 0.3989422804014327
    r3 = rhat[:, :, None]
    muc = mu[:, None, :]
    phic = phi[:, None, :]
    lamc = lam[:, None, :]
    beta0 = lam0 * (inv_sqrt_2pi * jax.lax.rsqrt(v)) * jnp.exp(-0.5 * rhat**2 / v)
    var_l = jnp.maximum(v[:, :, None] + phic, _EPS)
    diff = r3 - muc
    beta = lamc * (inv_sqrt_2pi * jax.lax.rsqrt(var_l)) * jnp.exp(
        -0.5 * diff * diff / var_l
    )
    denom = jnp.maximum(beta0 + jnp.sum(beta, axis=-1), _EPS)
    lam_post0 = beta0 / denom
    lam_post = beta / denom[:, :, None]
    mu_post = (r3 * phic + muc * v[:, :, None]) / var_l
    phi_post = v[:, :, None] * phic / var_l
    ghat_new = jnp.sum(lam_post * mu_post, axis=-1)
    second = jnp.sum(lam_post * (phi_post + mu_post * mu_post), axis=-1)
    nu_g_new = jnp.maximum(second - ghat_new**2, _EPS)

    if em:
        lam0_new = jnp.mean(lam_post0, axis=1, keepdims=True)
        lam_sum = jnp.sum(lam_post, axis=1)
        lam_new = lam_sum / n
        safe = jnp.maximum(lam_sum, _EPS)
        mu_new = jnp.sum(lam_post * mu_post, axis=1) / safe
        phi_new = (
            jnp.sum(lam_post * ((mu_new[:, None, :] - mu_post) ** 2 + phi_post), axis=1)
            / safe
        )
        lam0_new = jnp.clip(lam0_new, 1e-6, 1.0 - 1e-6)
        lam_new = jnp.maximum(lam_new, 1e-8)
        total = jnp.maximum(lam0_new + jnp.sum(lam_new, axis=1, keepdims=True), _EPS)
        theta_new = jnp.concatenate(
            [lam0_new / total, lam_new / total, mu_new, jnp.maximum(phi_new, _EPS)],
            axis=1,
        )
    else:
        theta_new = theta
    return ghat_new, nu_g_new, theta_new


def gamp_step_ref(ghat, nu_g, shat, theta, y, nu_d, a, n_components=3, em=True):
    """One scalar-variance AWGN EM-GAMP iteration (mirrors gamp_step kernel).

    theta packed as [lam0 | lam_1..L | mu_1..L | phi_1..L], (nb, 1+3L).
    """
    L = n_components
    m = y.shape[1]
    n = ghat.shape[1]
    nu_d = jnp.maximum(nu_d, _EPS)

    nu_p = jnp.maximum(jnp.sum(nu_g, axis=1, keepdims=True) / m, _EPS)
    phat = ghat @ a.T - nu_p * shat
    xpost = (phat * nu_d + y * nu_p) / (nu_p + nu_d)
    nu_x = nu_p * nu_d / (nu_p + nu_d)
    shat_new = (xpost - phat) / nu_p
    nu_s = jnp.maximum((1.0 - nu_x / nu_p) / nu_p, _EPS)
    nu_r = 1.0 / nu_s

    rhat = ghat + nu_r * (shat_new @ a)
    ghat_new, nu_g_new, theta_new = _gm_input_and_em(rhat, nu_r, theta, n, L, em)
    return ghat_new, nu_g_new, shat_new, theta_new
