"""Pallas TPU kernel: one fused EM-GAMP iteration (AWGN channel, AE path).

This is the PS-side hot loop of the paper's production strategy
(aggregate-and-estimate, Sec. IV-B): per GAMP iteration and per block we need

    phat  = ghat @ A^T - nu_p * shat          (MXU GEMM #1, contract N)
    AWGN posterior + Onsager terms            (VPU elementwise)
    rhat  = ghat + nu_r * (shat' @ A)         (MXU GEMM #2, contract M)
    Bernoulli Gaussian-mixture input channel  (VPU, L components)
    EM hyperparameter refresh                 (row reductions)

A naive XLA lowering round-trips every intermediate through HBM; the fused
kernel keeps the whole per-tile state (ghat, nu_g, shat, theta, y) in VMEM
across both GEMMs and all elementwise stages.  Scalar-variance GAMP (the
large-system iid-A approximation) is used, so no |A|^2 GEMMs are needed.

State is carried per block-row:  ghat (N), nu_g (N), shat (M), theta packed
as [lam0 | lam_1..L | mu_1..L | phi_1..L]  (1 + 3L floats).

Grid: one program per TB-row tile; A (M, N) stays resident across programs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import gm_prior

DEFAULT_TB = 32
_EPS = 1e-12


def _gamp_step_kernel(
    ghat_ref, nug_ref, shat_ref, theta_ref, y_ref, nud_ref, a_ref,
    ghat_out, nug_out, shat_out, theta_out, *, n_components: int, em: bool,
):
    L = n_components
    a = a_ref[...]  # (M, N)
    ghat = ghat_ref[...]  # (TB, N)
    nu_g = nug_ref[...]  # (TB, N)
    shat = shat_ref[...]  # (TB, M)
    th = theta_ref[...]  # (TB, 1 + 3L)
    y = y_ref[...]  # (TB, M)
    nu_d = jnp.maximum(nud_ref[...], _EPS)  # (TB, 1)
    m = y.shape[1]
    n = ghat.shape[1]

    theta_parts = gm_prior.unpack_theta(th, L)

    # ---- output side -----------------------------------------------------
    nu_p = jnp.maximum(jnp.sum(nu_g, axis=1, keepdims=True) / m, _EPS)  # (TB,1)
    phat = (
        jax.lax.dot_general(
            ghat, a, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        - nu_p * shat
    )  # (TB, M)
    xpost = (phat * nu_d + y * nu_p) / (nu_p + nu_d)
    nu_x = nu_p * nu_d / (nu_p + nu_d)  # (TB, 1)
    shat_new = (xpost - phat) / nu_p  # (TB, M)
    nu_s = jnp.maximum((1.0 - nu_x / nu_p) / nu_p, _EPS)  # (TB, 1)
    nu_r = 1.0 / nu_s  # scalar-variance identity: (1/m)*sum_M nu_s = nu_s

    # ---- input side ------------------------------------------------------
    rhat = ghat + nu_r * jax.lax.dot_general(
        shat_new, a, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TB, N)

    ghat_new, nu_g_new, posterior = gm_prior.gm_input_channel(
        rhat, nu_r, theta_parts
    )

    # ---- EM refresh (eq. 17) ----------------------------------------------
    theta_new = gm_prior.em_refresh(posterior, n) if em else th

    ghat_out[...] = ghat_new
    nug_out[...] = nu_g_new
    shat_out[...] = shat_new
    theta_out[...] = theta_new


@functools.partial(jax.jit, static_argnames=("n_components", "em", "tb", "interpret"))
def gamp_step_pallas(
    ghat: jnp.ndarray,  # (nb, N)
    nu_g: jnp.ndarray,  # (nb, N)
    shat: jnp.ndarray,  # (nb, M)
    theta: jnp.ndarray,  # (nb, 1 + 3L)
    y: jnp.ndarray,  # (nb, M)
    nu_d: jnp.ndarray,  # (nb, 1)
    a: jnp.ndarray,  # (M, N)
    n_components: int = 3,
    em: bool = True,
    tb: int = DEFAULT_TB,
    interpret: bool = False,
):
    nb, n = ghat.shape
    m = shat.shape[1]
    tl = theta.shape[1]
    assert nb % tb == 0, (nb, tb)
    kernel = functools.partial(_gamp_step_kernel, n_components=n_components, em=em)
    row = lambda i: (i, 0)
    outs = pl.pallas_call(
        kernel,
        grid=(nb // tb,),
        in_specs=[
            pl.BlockSpec((tb, n), row),
            pl.BlockSpec((tb, n), row),
            pl.BlockSpec((tb, m), row),
            pl.BlockSpec((tb, tl), row),
            pl.BlockSpec((tb, m), row),
            pl.BlockSpec((tb, 1), row),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, n), row),
            pl.BlockSpec((tb, n), row),
            pl.BlockSpec((tb, m), row),
            pl.BlockSpec((tb, tl), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, n), jnp.float32),
            jax.ShapeDtypeStruct((nb, n), jnp.float32),
            jax.ShapeDtypeStruct((nb, m), jnp.float32),
            jax.ShapeDtypeStruct((nb, tl), jnp.float32),
        ],
        interpret=interpret,
    )(ghat, nu_g, shat, theta, y, nu_d, a)
    return outs
