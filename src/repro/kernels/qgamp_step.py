"""Pallas TPU kernel: one fused Q-EM-GAMP iteration (quantized channel, EA path).

This is the PS-side hot loop of the paper's accuracy-optimal strategy
(estimate-and-aggregate, Procedure 2): each worker's code vector is inverted
*individually* over the quantized observation channel, so per GAMP iteration
and per block-row we need

    phat  = alpha * (ghat @ A^T) - nu_p * shat     (MXU GEMM #1, contract N)
    truncated-Gaussian quantized posterior          (VPU, eqs. 12-16)
    rhat  = ghat + nu_r * alpha * (shat' @ A)       (MXU GEMM #2, contract M)
    Bernoulli Gaussian-mixture input channel        (VPU, L components)
    EM hyperparameter refresh                       (row reductions, eq. 17)

The input side (GM posterior + EM) is shared with the AE kernel via
kernels/gm_prior.py; only the output channel differs: instead of the AWGN
Gaussian-product rule, the observation is the Lloyd-Max *code index* and the
posterior is a truncated-normal moment match between the decision thresholds
of the observed bin (with the same far-tail fallback as the pure-XLA
reference in core/gamp.py -- see that module's `_quantized_channel` for the
numerics rationale).

TPU adaptation notes:
  * packed-domain observation (``bits > 0``): the kernel consumes the (TB, W)
    uint32 *wire words* and unpacks the Q-bit indices in VMEM by reversing
    the fused encoder's lane-group shift-accumulate (static slices + shifts,
    DESIGN.md #Wire-format) -- the (nb, M) uint8 code tensor never exists in
    HBM on this path (DESIGN.md #Recon-engine);
  * the per-entry bin edges are fetched without a gather: the (2^Q,) lo/hi
    threshold tables stay resident in VMEM and the lookup is a one-hot
    broadcast-compare contraction over <= 256 lanes (same trick as the
    bucketize in bqcs_encode.py, run in reverse).
  * scalar-variance GAMP (the large-system iid-A approximation, the
    production default -- EXPERIMENTS.md #Perf): nu_p and nu_r are per-row
    scalars, so no |A|^2 GEMMs; unlike the AWGN channel the quantized
    posterior variance *is* per-entry, so nu_s is a (TB, M) tensor reduced
    to the scalar nu_r by a row-sum.
  * alpha (the per-block BQCS scale, transmitted) multiplies both GEMM
    outputs; dead rows (alpha == 0) are fed alpha = 1 by the wrapper and
    zeroed by the driver, exactly like the pure-XLA path.

State per block-row: ghat (N), nu_g (N), shat (M), theta packed as
[lam0 | lam_1..L | mu_1..L | phi_1..L] (1 + 3L floats) -- all kept in VMEM
across both GEMMs and every elementwise stage.

Grid: one program per TB-row tile; A (M, N) and the threshold tables stay
resident across programs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gamp import trunc_channel_moments  # the shared channel numerics
from repro.kernels import gm_prior

DEFAULT_TB = 32
_EPS = 1e-12


def _qgamp_step_kernel(
    ghat_ref, nug_ref, shat_ref, theta_ref, codes_ref, alpha_ref,
    lo_ref, hi_ref, a_ref,
    ghat_out, nug_out, shat_out, theta_out, *, n_components: int, em: bool,
    bits: int = 0,
):
    L = n_components
    a = a_ref[...]  # (M, N)
    ghat = ghat_ref[...]  # (TB, N)
    nu_g = nug_ref[...]  # (TB, N)
    shat = shat_ref[...]  # (TB, M)
    th = theta_ref[...]  # (TB, 1 + 3L)
    if bits:
        # Packed-domain observation: codes_ref holds the (TB, W) uint32 wire
        # words; the Q-bit indices are unpacked here, in VMEM, by reversing
        # the fused encoder's shift-accumulate over the 32 // Q lane groups
        # (DESIGN.md #Wire-format: group j = bits [j*Q, (j+1)*Q) of every
        # word = measurements [j*W, (j+1)*W)) -- static lane slices and
        # shifts only, and the uint8 code tensor never exists in HBM.
        words = codes_ref[...]  # (TB, W) uint32
        mask = jnp.uint32((1 << bits) - 1)
        codes = jnp.concatenate(
            [
                ((words >> jnp.uint32(j * bits)) & mask).astype(jnp.int32)
                for j in range(32 // bits)
            ],
            axis=1,
        )[:, : shat.shape[1]]  # (TB, Mp) -> (TB, M): drop word-padding lanes
    else:
        codes = codes_ref[...]  # (TB, M) int32 in [0, 2^Q)
    alpha = alpha_ref[...]  # (TB, 1) f32, dead rows pre-sanitized to 1.0
    lo_tau = lo_ref[...]  # (2^Q,) lower bin edges (sentinel at index 0)
    hi_tau = hi_ref[...]  # (2^Q,) upper bin edges (sentinel at index -1)
    m = codes.shape[1]
    n = ghat.shape[1]
    al2 = alpha * alpha  # (TB, 1)

    theta_parts = gm_prior.unpack_theta(th, L)

    # ---- output side -----------------------------------------------------
    nu_p = jnp.maximum(al2 / m * jnp.sum(nu_g, axis=1, keepdims=True), _EPS)
    phat = (
        alpha
        * jax.lax.dot_general(
            ghat, a, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        - nu_p * shat
    )  # (TB, M)

    # Bin-edge lookup via one-hot contraction (no gather on TPU).
    n_lev = lo_tau.shape[0]
    lvl = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_lev), 2)
    onehot = (codes[:, :, None] == lvl).astype(jnp.float32)  # (TB, M, 2^Q)
    lo = jnp.sum(onehot * lo_tau[None, None, :], axis=-1)  # (TB, M)
    hi = jnp.sum(onehot * hi_tau[None, None, :], axis=-1)

    # Truncated-Gaussian moment match (eqs. 12-16) + far-tail fallback --
    # the shared core.gamp numerics, inlined into the kernel body (plain jnp).
    xpost, nu_x = trunc_channel_moments(phat, nu_p, lo, hi)

    shat_new = (xpost - phat) / nu_p  # (TB, M)
    nu_s = jnp.maximum((1.0 - nu_x / nu_p) / nu_p, _EPS)  # (TB, M), per-entry
    nu_r = 1.0 / jnp.maximum(
        al2 / m * jnp.sum(nu_s, axis=1, keepdims=True), _EPS
    )  # (TB, 1)

    # ---- input side ------------------------------------------------------
    rhat = ghat + nu_r * (
        alpha
        * jax.lax.dot_general(
            shat_new, a, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    )  # (TB, N)

    ghat_new, nu_g_new, posterior = gm_prior.gm_input_channel(
        rhat, nu_r, theta_parts
    )
    theta_new = gm_prior.em_refresh(posterior, n) if em else th

    ghat_out[...] = ghat_new
    nug_out[...] = nu_g_new
    shat_out[...] = shat_new
    theta_out[...] = theta_new


@functools.partial(
    jax.jit, static_argnames=("n_components", "em", "tb", "interpret", "bits")
)
def qgamp_step_pallas(
    ghat: jnp.ndarray,  # (nb, N)
    nu_g: jnp.ndarray,  # (nb, N)
    shat: jnp.ndarray,  # (nb, M)
    theta: jnp.ndarray,  # (nb, 1 + 3L)
    codes: jnp.ndarray,  # (nb, M) int32 -- or (nb, W) uint32 words if bits
    alpha: jnp.ndarray,  # (nb, 1) f32, strictly positive (sanitized)
    lo_tau: jnp.ndarray,  # (2^Q,)
    hi_tau: jnp.ndarray,  # (2^Q,)
    a: jnp.ndarray,  # (M, N)
    n_components: int = 3,
    em: bool = True,
    tb: int = DEFAULT_TB,
    interpret: bool = False,
    bits: int = 0,  # 0 = unpacked int32 codes; Q = packed uint32 wire words
):
    nb, n = ghat.shape
    m = shat.shape[1]
    tl = theta.shape[1]
    n_lev = lo_tau.shape[0]
    assert nb % tb == 0, (nb, tb)
    obs_w = codes.shape[1]  # M unpacked, W = ceil(M / (32//Q)) packed
    kernel = functools.partial(
        _qgamp_step_kernel, n_components=n_components, em=em, bits=bits
    )
    row = lambda i: (i, 0)
    outs = pl.pallas_call(
        kernel,
        grid=(nb // tb,),
        in_specs=[
            pl.BlockSpec((tb, n), row),
            pl.BlockSpec((tb, n), row),
            pl.BlockSpec((tb, m), row),
            pl.BlockSpec((tb, tl), row),
            pl.BlockSpec((tb, obs_w), row),
            pl.BlockSpec((tb, 1), row),
            pl.BlockSpec((n_lev,), lambda i: (0,)),
            pl.BlockSpec((n_lev,), lambda i: (0,)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, n), row),
            pl.BlockSpec((tb, n), row),
            pl.BlockSpec((tb, m), row),
            pl.BlockSpec((tb, tl), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, n), jnp.float32),
            jax.ShapeDtypeStruct((nb, n), jnp.float32),
            jax.ShapeDtypeStruct((nb, m), jnp.float32),
            jax.ShapeDtypeStruct((nb, tl), jnp.float32),
        ],
        interpret=interpret,
    )(ghat, nu_g, shat, theta, codes, alpha, lo_tau, hi_tau, a)
    return outs
