"""Pallas TPU kernel: fused BQCS encode (scale -> project -> quantize).

Fuses the three per-block device-side ops of the paper's compressor
(eqs. 9-10) into one VMEM-resident pass:

    alpha = sqrt(M) / ||g_block||          (row reduction)
    y     = alpha * (g_block @ A^T)        (MXU GEMM)
    code  = #{tau_j < y}                   (Lloyd-Max bucketize, VPU compares)

TPU adaptation notes (vs. a CUDA port):
  * the GEMM contracts the full block length N per tile so the row norm and
    the projection share one VMEM residency of the block tile; N is chosen
    (config) so a (TB, N) f32 tile plus A^T (N, M) fit comfortably in VMEM
    (e.g. N=1024, M=256, TB=128 -> 0.5 MB + 1 MB + outputs).
  * bucketize is a broadcast-compare against the (2^Q - 1,) threshold vector
    and a sum over that axis -- no gather, no sort; 2^Q - 1 <= 255 lanes.
  * codes are emitted as int32 (TPU-friendly stores); the wrapper packs them.

Grid: one program per TB-row tile of the (nblocks, N) input.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TB = 128  # block-rows per program


def _encode_kernel(x_ref, at_ref, tau_ref, codes_ref, alpha_ref, *, m: int):
    x = x_ref[...]  # (TB, N) f32
    sq = jnp.sum(x * x, axis=1, keepdims=True)  # (TB, 1)
    alive = sq > 1e-30
    inv_norm = jax.lax.rsqrt(jnp.where(alive, sq, 1.0))
    alpha = jnp.where(alive, jnp.sqrt(jnp.float32(m)) * inv_norm, 0.0)  # (TB, 1)
    xs = x * alpha  # scaled block
    y = jax.lax.dot_general(
        xs,
        at_ref[...],  # (N, M)
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TB, M)
    taus = tau_ref[...]  # (n_taus,)
    codes = jnp.sum(
        (y[:, :, None] > taus[None, None, :]).astype(jnp.int32), axis=-1
    )  # (TB, M), values in [0, 2^Q)
    codes_ref[...] = codes
    alpha_ref[...] = alpha


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def bqcs_encode_pallas(
    blocks: jnp.ndarray,  # (nb, N) f32, nb % tb == 0
    a_t: jnp.ndarray,  # (N, M) f32 transposed sensing matrix
    taus: jnp.ndarray,  # (2^Q - 1,) f32 thresholds
    tb: int = DEFAULT_TB,
    interpret: bool = False,
):
    nb, n = blocks.shape
    m = a_t.shape[1]
    assert nb % tb == 0, (nb, tb)
    grid = (nb // tb,)
    kernel = functools.partial(_encode_kernel, m=m)
    codes, alpha = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),  # block tile
            pl.BlockSpec((n, m), lambda i: (0, 0)),  # A^T, resident
            pl.BlockSpec((taus.shape[0],), lambda i: (0,)),  # thresholds
        ],
        out_specs=[
            pl.BlockSpec((tb, m), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, m), jnp.int32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(blocks, a_t, taus)
    return codes, alpha[:, 0]
