"""Pallas TPU kernel: per-block magnitude top-S selection by bisection.

The paper's BlockSparse() keeps the top-S magnitudes per block.  Exact top-k
needs a sort (data-dependent gather) which maps poorly to the TPU vector
unit; instead we find a per-block magnitude *threshold* by fixed-iteration
bisection -- only compares and row reductions, fully in VMEM -- and mask.
With >= 24 iterations the threshold resolves to ~1e-7 of the block's dynamic
range, i.e. exact top-S whenever magnitudes are distinct at f32 resolution
(ties keep all tied entries; the count may then exceed S by the tie size,
which only *adds* information and keeps the error-feedback identity exact).

Outputs both the sparsified block and the residual (blocks - sparse), so the
error-feedback update (eq. 7) is one fused pass.

Grid: one program per TB-row tile of (nblocks, N).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TB = 128
BISECT_ITERS = 26


def _topk_kernel(x_ref, sparse_ref, resid_ref, *, s: int, iters: int):
    x = x_ref[...]  # (TB, N)
    mag = jnp.abs(x)
    hi = jnp.max(mag, axis=1, keepdims=True)  # (TB, 1)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum((mag >= mid).astype(jnp.int32), axis=1, keepdims=True)
        too_many = count > s
        lo = jnp.where(too_many, mid, lo)
        hi = jnp.where(too_many, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    thresh = hi  # keeps <= s entries (up to ties / bisection resolution)
    keep = (mag >= thresh) | (mag == jnp.max(mag, axis=1, keepdims=True))
    sparse = jnp.where(keep, x, 0.0)
    sparse_ref[...] = sparse
    resid_ref[...] = x - sparse


@functools.partial(jax.jit, static_argnames=("s", "tb", "iters", "interpret"))
def block_topk_pallas(
    blocks: jnp.ndarray,  # (nb, N) f32, nb % tb == 0
    s: int,
    tb: int = DEFAULT_TB,
    iters: int = BISECT_ITERS,
    interpret: bool = False,
):
    nb, n = blocks.shape
    assert nb % tb == 0, (nb, tb)
    kernel = functools.partial(_topk_kernel, s=s, iters=iters)
    sparse, resid = pl.pallas_call(
        kernel,
        grid=(nb // tb,),
        in_specs=[pl.BlockSpec((tb, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, n), jnp.float32),
            jax.ShapeDtypeStruct((nb, n), jnp.float32),
        ],
        interpret=interpret,
    )(blocks)
    return sparse, resid
