"""Pallas TPU kernel: single-pass fused BQCS encoder (paper Sec. III, eqs. 7-10).

One kernel, one VMEM residency per (TB, N) tile, doing the complete
worker-side compressor *including the wire packing*:

    carry  = blocks + residual                 (error feedback, eq. 8)
    sparse = TopS(carry)                       (bisection threshold, eq. 7)
    resid  = carry - sparse                    (new error-feedback state)
    alpha  = sqrt(M) / ||sparse||              (row reduction, eq. 9)
    y      = alpha * (sparse @ A^T)            (MXU GEMM)
    code   = #{tau_j < y}                      (Lloyd-Max bucketize, eq. 10)
    word   = OR_j  code[group j] << (j * Q)    (uint32 packing, the wire)

The unfused path runs this as two kernels (block_topk, bqcs_encode) plus an
XLA pack pass, which round-trips the (nb, N) carry, sparse, and residual
arrays AND the (nb, M) int32 codes through HBM between stages.  Fusing
removes three full-gradient HBM round trips and emits the Q-bit wire payload
directly, so nothing wider than the true wire format ever leaves the kernel.

Packing layout (the canonical wire format, see DESIGN.md #Wire-format): the
Mp = W * per_word measurement lanes (per_word = 32 // Q, W = ceil(M /
per_word), A^T zero-padded to Mp columns) are split into per_word contiguous
*lane groups* of width W; group j is shifted by j*Q bits and OR-accumulated
into the (TB, W) word tile.  Measurement m therefore lives in word ``m % W``
at bit offset ``(m // W) * Q`` -- contiguous static lane slices only, no
in-kernel transpose or gather.  ``core.compression.pack_codes`` implements
the identical layout for the XLA path.

Grid: one program per TB-row tile of (nblocks, N).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TB = 128  # block-rows per program
BISECT_ITERS = 26  # matches block_topk.py (threshold ~1e-7 of dynamic range)


def _fused_kernel(
    g_ref, r_ref, at_ref, tau_ref, words_ref, alpha_ref, resid_ref,
    *, s: int, iters: int, m: int, bits: int,
):
    carry = g_ref[...] + r_ref[...]  # (TB, N) error-feedback add

    # -- bisection top-S threshold (same math + trip count as block_topk) --
    mag = jnp.abs(carry)
    hi = jnp.max(mag, axis=1, keepdims=True)  # (TB, 1)
    lo = jnp.zeros_like(hi)

    def body(_, c):
        lo, hi = c
        mid = 0.5 * (lo + hi)
        count = jnp.sum((mag >= mid).astype(jnp.int32), axis=1, keepdims=True)
        too_many = count > s
        return jnp.where(too_many, mid, lo), jnp.where(too_many, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    keep = (mag >= hi) | (mag == jnp.max(mag, axis=1, keepdims=True))
    sparse = jnp.where(keep, carry, 0.0)
    resid_ref[...] = carry - sparse

    # -- norm/scale + MXU projection + threshold bucketize --
    sq = jnp.sum(sparse * sparse, axis=1, keepdims=True)  # (TB, 1)
    alive = sq > 1e-30
    inv_norm = jax.lax.rsqrt(jnp.where(alive, sq, 1.0))
    alpha = jnp.where(alive, jnp.sqrt(jnp.float32(m)) * inv_norm, 0.0)
    y = jax.lax.dot_general(
        sparse * alpha,
        at_ref[...],  # (N, Mp)
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TB, Mp)
    taus = tau_ref[...]  # (2^Q - 1,)
    codes = jnp.sum(
        (y[:, :, None] > taus[None, None, :]).astype(jnp.int32), axis=-1
    )  # (TB, Mp), values in [0, 2^Q)
    mp = codes.shape[1]
    if mp != m:
        # Zero the measurement lanes added by word-padding A^T so the padded
        # word bits match pack_codes' zero fill bit-exactly.
        lane = jax.lax.broadcasted_iota(jnp.int32, codes.shape, 1)
        codes = jnp.where(lane < m, codes, 0)

    # -- shift-accumulate pack over the 32 // Q lane groups --
    per_word = 32 // bits
    w = mp // per_word
    codes = codes.astype(jnp.uint32)
    words = codes[:, 0:w]
    for j in range(1, per_word):
        words = words | (codes[:, j * w : (j + 1) * w] << jnp.uint32(j * bits))
    words_ref[...] = words
    alpha_ref[...] = alpha


@functools.partial(jax.jit, static_argnames=("s", "m", "bits", "tb", "iters", "interpret"))
def bqcs_encode_fused_pallas(
    blocks: jnp.ndarray,  # (nb, N) f32, nb % tb == 0
    residual: jnp.ndarray,  # (nb, N) f32 error-feedback state
    a_t: jnp.ndarray,  # (N, Mp) f32, Mp = W * (32 // Q) zero-padded columns
    taus: jnp.ndarray,  # (2^Q - 1,) f32 Lloyd-Max thresholds
    s: int,
    m: int,  # true measurement count M <= Mp
    bits: int,  # Q
    tb: int = DEFAULT_TB,
    iters: int = BISECT_ITERS,
    interpret: bool = False,
):
    nb, n = blocks.shape
    mp = a_t.shape[1]
    per_word = 32 // bits
    assert nb % tb == 0, (nb, tb)
    assert mp % per_word == 0, (mp, per_word)
    w = mp // per_word
    kernel = functools.partial(_fused_kernel, s=s, iters=iters, m=m, bits=bits)
    words, alpha, resid = pl.pallas_call(
        kernel,
        grid=(nb // tb,),
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),  # gradient tile
            pl.BlockSpec((tb, n), lambda i: (i, 0)),  # residual tile
            pl.BlockSpec((n, mp), lambda i: (0, 0)),  # A^T, resident
            pl.BlockSpec((taus.shape[0],), lambda i: (0,)),  # thresholds
        ],
        out_specs=[
            pl.BlockSpec((tb, w), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, w), jnp.uint32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, n), jnp.float32),
        ],
        interpret=interpret,
    )(blocks, residual, a_t, taus)
    return words, alpha[:, 0], resid
