"""Pallas TPU kernel: single-pass fused BQCS encoder (paper Sec. III, eqs. 7-10).

One kernel, one VMEM residency per (TB, N) tile, doing the complete
worker-side compressor *including the wire packing*:

    carry  = blocks + residual                 (error feedback, eq. 8)
    sparse = TopS(carry)                       (bisection threshold, eq. 7)
    resid  = carry - sparse                    (new error-feedback state)
    alpha  = sqrt(M) / ||sparse||              (row reduction, eq. 9)
    y      = alpha * (sparse @ A^T)            (MXU GEMM)
    code   = codebook encode                   (broadcast-compare, eq. 10)
    word   = OR_j  code[group j] << (j * Q)    (uint32 packing, the wire)

The codebook table rides in as an operand, so ONE kernel serves every
registered family (core/codebook.py):

  * scalar (Lloyd-Max / dithered-uniform): ``tab`` is the (L-1,) threshold
    vector and ``code = #{tau_j < y (+ dither)}`` -- the broadcast-compare
    bucketize; the optional shared-seed dither is one extra (Mp,) operand
    added to y before the compare (absent for Lloyd-Max, so that path is
    bit-identical to the pre-codebook kernel).
  * vq (dim d > 1): ``tab`` is the (L, d) centroid table and the code is the
    nearest centroid, argmax_l <y_g, c_l> - ||c_l||^2/2, computed with the
    same broadcast-compare idiom: d static lane slices (the j-major group
    layout of core.codebook.vq_nearest) each contribute a rank-1 update to
    the (TB, G, L) score tensor, then a max/min-iota reduction picks the
    first argmax -- no gather, no transpose, no reshape.

The unfused path runs this as two kernels (block_topk, bqcs_encode) plus an
XLA pack pass, which round-trips the (nb, N) carry, sparse, and residual
arrays AND the (nb, M) int32 codes through HBM between stages.  Fusing
removes three full-gradient HBM round trips and emits the Q-bit wire payload
directly, so nothing wider than the true wire format ever leaves the kernel.

Packing layout (the canonical wire format, see DESIGN.md #Wire-format): the
Gp = W * per_word code lanes (per_word = 32 // Q, W = ceil(n_codes /
per_word); scalar: n_codes = M with A^T zero-padded to Gp columns, vq:
n_codes = M // d with the code vector zero-padded to Gp in-register) are
split into per_word contiguous *lane groups* of width W; group j is shifted
by j*Q bits and OR-accumulated into the (TB, W) word tile.  Code lane ``c``
therefore lives in word ``c % W`` at bit offset ``(c // W) * Q`` --
contiguous static lane slices only.  ``core.compression.pack_codes``
implements the identical layout for the XLA path.

Grid: one program per TB-row tile of (nblocks, N).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TB = 128  # block-rows per program
BISECT_ITERS = 26  # matches block_topk.py (threshold ~1e-7 of dynamic range)


def _fused_kernel(
    *refs, s: int, iters: int, m: int, bits: int, vq_d: int, has_dither: bool,
):
    if has_dither:
        g_ref, r_ref, at_ref, tab_ref, dith_ref = refs[:5]
        words_ref, alpha_ref, resid_ref = refs[5:]
    else:
        g_ref, r_ref, at_ref, tab_ref = refs[:4]
        words_ref, alpha_ref, resid_ref = refs[4:]
    carry = g_ref[...] + r_ref[...]  # (TB, N) error-feedback add

    # -- bisection top-S threshold (same math + trip count as block_topk) --
    mag = jnp.abs(carry)
    hi = jnp.max(mag, axis=1, keepdims=True)  # (TB, 1)
    lo = jnp.zeros_like(hi)

    def body(_, c):
        lo, hi = c
        mid = 0.5 * (lo + hi)
        count = jnp.sum((mag >= mid).astype(jnp.int32), axis=1, keepdims=True)
        too_many = count > s
        return jnp.where(too_many, mid, lo), jnp.where(too_many, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    keep = (mag >= hi) | (mag == jnp.max(mag, axis=1, keepdims=True))
    sparse = jnp.where(keep, carry, 0.0)
    resid_ref[...] = carry - sparse

    # -- norm/scale + MXU projection --
    sq = jnp.sum(sparse * sparse, axis=1, keepdims=True)  # (TB, 1)
    alive = sq > 1e-30
    inv_norm = jax.lax.rsqrt(jnp.where(alive, sq, 1.0))
    alpha = jnp.where(alive, jnp.sqrt(jnp.float32(m)) * inv_norm, 0.0)
    y = jax.lax.dot_general(
        sparse * alpha,
        at_ref[...],  # (N, Mp)
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TB, Mp)

    if vq_d > 1:
        # -- nearest-centroid encode over d-lane groups (j-major layout) --
        c = tab_ref[...]  # (L, d) centroid table
        n_lev = c.shape[0]
        g = m // vq_d  # true code-lane count (Mp == M for vq)
        cn = 0.5 * jnp.sum(c * c, axis=1)  # (L,)
        # Accumulation order matches codebook.vq_nearest exactly: j = 0
        # carries the -||c||^2/2 term, then j = 1..d-1 -- interpret-mode
        # runs are bit-identical to the XLA oracle.
        sc = y[:, 0:g][:, :, None] * c[None, None, :, 0] - cn[None, None, :]
        for j in range(1, vq_d):
            sc = sc + y[:, j * g : (j + 1) * g][:, :, None] * c[None, None, :, j]
        mx = jnp.max(sc, axis=-1, keepdims=True)  # (TB, G, 1)
        lvl = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 2)
        codes = jnp.min(jnp.where(sc == mx, lvl, n_lev), axis=-1)  # (TB, G)
        # Zero-pad the code lanes to the word grid (pure pack-side padding;
        # every measurement lane is real on the vq path).
        per_word = 32 // bits
        gp = -(-g // per_word) * per_word
        if gp != g:
            codes = jnp.concatenate(
                [codes, jnp.zeros((codes.shape[0], gp - g), jnp.int32)], axis=1
            )
    else:
        # -- threshold bucketize (broadcast-compare) --
        if has_dither:
            # shared-seed subtractive dither: the encoder quantizes y + u
            # (padded lanes carry u = 0 and are masked below anyway)
            y = y + dith_ref[...][None, :]
        taus = tab_ref[...]  # (L - 1,)
        codes = jnp.sum(
            (y[:, :, None] > taus[None, None, :]).astype(jnp.int32), axis=-1
        )  # (TB, Mp), values in [0, L)
        mp = codes.shape[1]
        if mp != m:
            # Zero the measurement lanes added by word-padding A^T so the
            # padded word bits match pack_codes' zero fill bit-exactly.
            lane = jax.lax.broadcasted_iota(jnp.int32, codes.shape, 1)
            codes = jnp.where(lane < m, codes, 0)

    # -- shift-accumulate pack over the 32 // Q lane groups --
    per_word = 32 // bits
    w = codes.shape[1] // per_word
    codes = codes.astype(jnp.uint32)
    words = codes[:, 0:w]
    for j in range(1, per_word):
        words = words | (codes[:, j * w : (j + 1) * w] << jnp.uint32(j * bits))
    words_ref[...] = words
    alpha_ref[...] = alpha


@functools.partial(
    jax.jit,
    static_argnames=("s", "m", "bits", "vq_d", "tb", "iters", "interpret"),
)
def bqcs_encode_fused_pallas(
    blocks: jnp.ndarray,  # (nb, N) f32, nb % tb == 0
    residual: jnp.ndarray,  # (nb, N) f32 error-feedback state
    a_t: jnp.ndarray,  # (N, Mp) f32; scalar: Mp = W * (32 // Q) zero-padded
    tab: jnp.ndarray,  # (L-1,) thresholds (scalar) or (L, d) centroids (vq)
    s: int,
    m: int,  # true measurement count M <= Mp
    bits: int,  # Q: index width on the wire
    vq_d: int = 1,  # codebook dim; > 1 selects nearest-centroid encode
    dither: jnp.ndarray | None = None,  # (Mp,) per-lane dither or None
    tb: int = DEFAULT_TB,
    iters: int = BISECT_ITERS,
    interpret: bool = False,
):
    nb, n = blocks.shape
    mp = a_t.shape[1]
    per_word = 32 // bits
    assert nb % tb == 0, (nb, tb)
    if vq_d > 1:
        assert mp == m and m % vq_d == 0, (mp, m, vq_d)
        w = -(-(m // vq_d) // per_word)
    else:
        assert mp % per_word == 0, (mp, per_word)
        w = mp // per_word
    has_dither = dither is not None
    kernel = functools.partial(
        _fused_kernel, s=s, iters=iters, m=m, bits=bits, vq_d=vq_d,
        has_dither=has_dither,
    )
    in_specs = [
        pl.BlockSpec((tb, n), lambda i: (i, 0)),  # gradient tile
        pl.BlockSpec((tb, n), lambda i: (i, 0)),  # residual tile
        pl.BlockSpec((n, mp), lambda i: (0, 0)),  # A^T, resident
        pl.BlockSpec(tab.shape, (lambda i: (0, 0)) if tab.ndim == 2 else (lambda i: (0,))),
    ]
    operands = [blocks, residual, a_t, tab]
    if has_dither:
        in_specs.append(pl.BlockSpec((mp,), lambda i: (0,)))
        operands.append(dither)
    words, alpha, resid = pl.pallas_call(
        kernel,
        grid=(nb // tb,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((tb, w), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, w), jnp.uint32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, n), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return words, alpha[:, 0], resid
