"""Pallas TPU kernels for FedQCS hot spots (validated in interpret mode).

Kernels: bqcs_encode (fused scale+project+quantize), block_topk (bisection
top-S sparsify), gamp_step (fused EM-GAMP iteration).  Public entry points
live in ops.py; pure-jnp oracles in ref.py.
"""
