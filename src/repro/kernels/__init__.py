"""Pallas TPU kernels for FedQCS hot spots (validated in interpret mode).

Kernels: bqcs_encode (fused scale+project+quantize), block_topk (bisection
top-S sparsify), gamp_step (fused AWGN EM-GAMP iteration, AE path),
qgamp_step (fused quantized-channel Q-EM-GAMP iteration, EA path).  The
Bernoulli-GM input channel + EM refresh shared by the two GAMP kernels live
in gm_prior.py.  Public entry points live in ops.py; pure-jnp oracles in
ref.py.
"""
