"""Pallas TPU kernels for FedQCS hot spots (validated in interpret mode).

Kernels: bqcs_encode_fused (the single-pass worker compressor: error
feedback + top-S + scale/project/quantize + uint32 wire packing -- the
production encode path), bqcs_encode (scale+project+quantize stage),
block_topk (bisection top-S sparsify stage), gamp_step (fused AWGN EM-GAMP
iteration, AE path), qgamp_step (fused quantized-channel Q-EM-GAMP
iteration, EA path).  The Bernoulli-GM input channel + EM refresh shared by
the two GAMP kernels live in gm_prior.py.  Public entry points live in
ops.py; pure-jnp oracles in ref.py.
"""
