"""Shared VPU stages of the fused GAMP kernels.

Both fused Pallas kernels (gamp_step: AWGN/AE path, qgamp_step: quantized/EA
path) run the *same* input side per iteration -- the Bernoulli
Gaussian-mixture posterior (eq. 11) and the EM hyperparameter refresh
(eq. 17) -- on the packed theta layout

    theta = [lam0 | lam_1..L | mu_1..L | phi_1..L]   (TB, 1 + 3L) f32.

The helpers here are plain jnp expressions, so they inline into either
kernel body (and into interpret mode) without any Pallas-specific types.
They must stay numerically identical to core/gamp.py's `_input_channel` /
`_em_update` (the pure-XLA reference) -- the kernel allclose tests pin this.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12
_INV_SQRT_2PI = 0.3989422804014327


def unpack_theta(th: jnp.ndarray, L: int):
    """(TB, 1+3L) -> (lam0 (TB,1), lam (TB,L), mu (TB,L), phi (TB,L))."""
    return (
        th[:, 0:1],
        th[:, 1 : 1 + L],
        th[:, 1 + L : 1 + 2 * L],
        th[:, 1 + 2 * L : 1 + 3 * L],
    )


def gm_input_channel(rhat, v, theta_parts):
    """Posterior mean/var of g given rhat = g + N(0, v), g ~ BG(theta).

    rhat: (TB, N); v: (TB, 1) scalar-variance nu_r (broadcasts over N).
    Returns (ghat_new, nu_g_new, posterior) where posterior is the tuple
    (lam_post0, lam_post, mu_post, phi_post) reused by `em_refresh`.
    """
    lam0, lam, mu, phi = theta_parts
    r3 = rhat[:, :, None]  # (TB, N, 1)
    muc = mu[:, None, :]  # (TB, 1, L)
    phic = phi[:, None, :]
    lamc = lam[:, None, :]
    beta0 = lam0 * (_INV_SQRT_2PI * jax.lax.rsqrt(v)) * jnp.exp(
        -0.5 * rhat * rhat / v
    )  # (TB, N)
    var_l = jnp.maximum(v[:, :, None] + phic, _EPS)  # (TB, 1->N, L)
    diff = r3 - muc
    beta = lamc * (_INV_SQRT_2PI * jax.lax.rsqrt(var_l)) * jnp.exp(
        -0.5 * diff * diff / var_l
    )  # (TB, N, L)
    denom = jnp.maximum(beta0 + jnp.sum(beta, axis=-1), _EPS)  # (TB, N)
    lam_post0 = beta0 / denom
    lam_post = beta / denom[:, :, None]
    mu_post = (r3 * phic + muc * v[:, :, None]) / var_l
    phi_post = v[:, :, None] * phic / var_l
    ghat_new = jnp.sum(lam_post * mu_post, axis=-1)  # (TB, N)
    second = jnp.sum(lam_post * (phi_post + mu_post * mu_post), axis=-1)
    nu_g_new = jnp.maximum(second - ghat_new * ghat_new, _EPS)
    return ghat_new, nu_g_new, (lam_post0, lam_post, mu_post, phi_post)


def em_refresh(posterior, n: int):
    """EM hyperparameter refresh (eq. 17) -> new packed theta (TB, 1+3L).

    The component variance is the posterior scatter around the same-step
    refreshed mean mu_new (matching core.gamp._em_update exactly).
    """
    lam_post0, lam_post, mu_post, phi_post = posterior
    lam0_new = jnp.mean(lam_post0, axis=1, keepdims=True)  # (TB, 1)
    lam_sum = jnp.sum(lam_post, axis=1)  # (TB, L)
    lam_new = lam_sum / n
    safe = jnp.maximum(lam_sum, _EPS)
    mu_new = jnp.sum(lam_post * mu_post, axis=1) / safe
    phi_new = (
        jnp.sum(lam_post * ((mu_new[:, None, :] - mu_post) ** 2 + phi_post), axis=1)
        / safe
    )
    lam0_new = jnp.clip(lam0_new, 1e-6, 1.0 - 1e-6)
    lam_new = jnp.maximum(lam_new, 1e-8)
    total = jnp.maximum(lam0_new + jnp.sum(lam_new, axis=1, keepdims=True), _EPS)
    return jnp.concatenate(
        [lam0_new / total, lam_new / total, mu_new, jnp.maximum(phi_new, _EPS)],
        axis=1,
    )


def pack_init_theta(nb: int, L: int, init_var, lam0: float):
    """Packed-theta variant of core.gamp.make_init_theta (same init)."""
    sigma = jnp.sqrt(jnp.maximum(init_var, _EPS))
    gmax = 3.0 * sigma[:, None]
    ls = jnp.arange(1, L + 1, dtype=jnp.float32)[None, :]
    mu0 = -gmax + (2.0 * ls - 1.0) / (2.0 * L) * (2.0 * gmax)
    phi0 = jnp.broadcast_to((2.0 * gmax / L) ** 2 / 12.0, mu0.shape)
    return jnp.concatenate(
        [
            jnp.full((nb, 1), lam0, jnp.float32),
            jnp.full((nb, L), (1.0 - lam0) / L, jnp.float32),
            mu0,
            phi0,
        ],
        axis=1,
    )
