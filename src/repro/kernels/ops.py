"""Jit'd public wrappers around the Pallas kernels.

Handles: row padding to tile multiples, interpret-mode selection (the kernels
execute in interpret mode on CPU -- the TPU lowering is the target), dtype
plumbing, and a full GAMP driver (`gamp_ae_run`) that scans the fused
`gamp_step` kernel.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.codebook import as_codebook
from repro.core.gamp import block_prior_energy, norm_guard, tau_tables
from repro.kernels import bqcs_encode as _enc
from repro.kernels import bqcs_encode_fused as _fenc
from repro.kernels import block_topk as _topk
from repro.kernels import gamp_step as _gstep
from repro.kernels import gm_prior as _gm
from repro.kernels import qgamp_step as _qstep


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jnp.ndarray, tb: int) -> Tuple[jnp.ndarray, int]:
    nb = x.shape[0]
    pad = (-nb) % tb
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, nb


def _pad_rows_ones(arrays, tb: int):
    """Pads every array to a row-multiple of tb with ONES -- the benign fill
    for GAMP state (zeros would divide-by-zero inside the kernels).  Returns
    (padded arrays, original nb)."""
    nb = arrays[0].shape[0]
    pad = (-nb) % tb
    if pad:
        arrays = [
            jnp.concatenate([x, jnp.ones((pad,) + x.shape[1:], x.dtype)], axis=0)
            for x in arrays
        ]
    return arrays, nb


def bqcs_encode(
    blocks: jnp.ndarray, a: jnp.ndarray, quantizer, tb: int | None = None
):
    """Fused scale+project+quantize.  blocks (nb, N), a (M, N).
    ``quantizer``: a scalar Codebook or legacy LloydMaxQuantizer.

    Returns (codes uint8 (nb, M), alpha (nb,)).
    """
    tb = tb or min(_enc.DEFAULT_TB, max(8, blocks.shape[0]))
    padded, nb = _pad_rows(blocks.astype(jnp.float32), tb)
    codes, alpha = _enc.bqcs_encode_pallas(
        padded, a.T, as_codebook(quantizer).jnp_thresholds(), tb=tb,
        interpret=_interpret(),
    )
    return codes[:nb].astype(jnp.uint8), alpha[:nb]


def bqcs_encode_fused(
    blocks: jnp.ndarray,
    residual: jnp.ndarray,
    a: jnp.ndarray,
    quantizer,  # Codebook of any family (or legacy LloydMaxQuantizer)
    s: int,
    tb: int | None = None,
):
    """Single-pass fused encoder: error-feedback add -> bisection top-S ->
    scale/project/encode -> uint32 wire packing, one VMEM residency.  The
    codebook table (thresholds for the scalar families, centroids for vq,
    plus the optional shared-seed dither vector) rides in as an operand, so
    one kernel serves every registered family.

    blocks/residual (nb, N), a (M, N).  Pads rows once to the tile multiple;
    the scalar families additionally pad A^T's columns once to the word
    multiple (32 // Q) -- zero fill is benign for both (dead rows get
    alpha=0; padded measurement lanes are masked to code 0 in-kernel).  The
    vq family pads at the code-lane level instead (every measurement lane is
    real; M % d == 0 enforced at codebook design).

    Returns (words uint32 (nb, W), alpha (nb,), new_residual (nb, N)) with
    W = ceil(n_codes / (32 // Q)) -- the canonical packed wire layout of
    ``core.compression.pack_codes``.
    """
    from repro.core.compression import packed_width

    cb = as_codebook(quantizer)
    bits = cb.bits
    per_word = 32 // bits
    m, n = a.shape
    a_t = a.T
    dither = None
    if cb.dim > 1:
        tab = cb.jnp_centroids()  # (L, d): nearest-centroid encode
    else:
        tab = cb.jnp_thresholds()  # (L - 1,): threshold bucketize
        w = packed_width(m, bits)  # the single wire-width definition
        pad_m = w * per_word - m
        if pad_m:
            a_t = jnp.concatenate([a_t, jnp.zeros((n, pad_m), a_t.dtype)], axis=1)
        dither = cb.jnp_dither()
        if dither is not None and pad_m:
            dither = jnp.concatenate([dither, jnp.zeros((pad_m,), dither.dtype)])
    tb = tb or min(_fenc.DEFAULT_TB, max(8, blocks.shape[0]))
    padded_b, nb = _pad_rows(blocks.astype(jnp.float32), tb)
    padded_r, _ = _pad_rows(residual.astype(jnp.float32), tb)
    words, alpha, resid = _fenc.bqcs_encode_fused_pallas(
        padded_b, padded_r, a_t, tab,
        s=s, m=m, bits=bits, vq_d=cb.dim, dither=dither,
        tb=tb, interpret=_interpret(),
    )
    return words[:nb], alpha[:nb], resid[:nb]


def block_sparsify(blocks: jnp.ndarray, s: int, tb: int | None = None):
    """Bisection top-S sparsify.  Returns (sparse, residual)."""
    tb = tb or min(_topk.DEFAULT_TB, max(8, blocks.shape[0]))
    padded, nb = _pad_rows(blocks.astype(jnp.float32), tb)
    sparse, resid = _topk.block_topk_pallas(padded, s, tb=tb, interpret=_interpret())
    return sparse[:nb], resid[:nb]


def gamp_step(
    ghat, nu_g, shat, theta, y, nu_d, a, n_components: int = 3, em: bool = True,
    tb: int | None = None,
):
    """One fused AE GAMP iteration (see gamp_step.py for contract)."""
    tb = tb or min(_gstep.DEFAULT_TB, max(8, ghat.shape[0]))
    (ghat, nu_g, shat, theta, y, nu_d), nb = _pad_rows_ones(
        (ghat, nu_g, shat, theta, y, nu_d), tb
    )
    outs = _gstep.gamp_step_pallas(
        ghat, nu_g, shat, theta, y, nu_d, a,
        n_components=n_components, em=em, tb=tb, interpret=_interpret(),
    )
    return tuple(o[:nb] for o in outs)


def qgamp_step(
    ghat, nu_g, shat, theta, codes, alpha, lo_tau, hi_tau, a,
    n_components: int = 3, em: bool = True, tb: int | None = None,
):
    """One fused EA Q-GAMP iteration (see qgamp_step.py for contract).

    codes (nb, M) int32; alpha (nb, 1) strictly positive (dead rows must be
    sanitized to 1.0 by the caller -- the driver below does this).
    """
    tb = tb or min(_qstep.DEFAULT_TB, max(8, ghat.shape[0]))
    (ghat, nu_g, shat, theta, codes, alpha), nb = _pad_rows_ones(
        (ghat, nu_g, shat, theta, codes, alpha), tb
    )
    outs = _qstep.qgamp_step_pallas(
        ghat, nu_g, shat, theta, codes, alpha, lo_tau, hi_tau, a,
        n_components=n_components, em=em, tb=tb, interpret=_interpret(),
    )
    return tuple(o[:nb] for o in outs)


def _qgamp_ea_scan(obs, alpha, a, taus, bits, m, n_components, iters, em, lam0):
    """Shared EA scan body: ``obs`` is (nb, M) int32 codes when ``bits == 0``
    or (nb, W) uint32 packed wire words when ``bits == Q`` (unpacked in-VMEM
    by the kernel -- the uint8 view never hits HBM)."""
    nb = obs.shape[0]
    n = a.shape[1]
    lo_tau, hi_tau = tau_tables(taus)  # shared protocol constant (core.gamp)
    alpha = jnp.asarray(alpha, jnp.float32)
    alive = alpha > 0.0
    safe_alpha = jnp.where(alive, alpha, 1.0)
    init_var = block_prior_energy(alpha, m, n)
    # Pad ONCE to a tile multiple (benign ones-rows), scan the raw kernel,
    # trim once at the end -- no per-iteration pad/trim copies in the scan.
    tb = min(_qstep.DEFAULT_TB, max(8, nb))
    (obs_p, alpha2d, init_var_p), _ = _pad_rows_ones(
        (obs, safe_alpha[:, None], init_var), tb
    )
    nbp = obs_p.shape[0]
    theta0 = _gm.pack_init_theta(nbp, n_components, init_var_p, lam0)
    ghat0 = jnp.zeros((nbp, n), jnp.float32)
    nu_g0 = jnp.broadcast_to(
        jnp.maximum(init_var_p, 1e-12)[:, None], (nbp, n)
    ).astype(jnp.float32)
    shat0 = jnp.zeros((nbp, m), jnp.float32)

    def body(carry, _):
        gh, ng, sh, th = carry
        gh, ng, sh, th = _qstep.qgamp_step_pallas(
            gh, ng, sh, th, obs_p, alpha2d, lo_tau, hi_tau, a,
            n_components=n_components, em=em, tb=tb, interpret=_interpret(),
            bits=bits,
        )
        return (gh, ng, sh, th), None

    (ghat, _, _, _), _ = jax.lax.scan(
        body, (ghat0, nu_g0, shat0, theta0), None, length=iters
    )
    ghat = jnp.where(alive[:, None], ghat[:nb], 0.0)
    # The PS knows the true block norm (see core.gamp.qem_gamp).
    true_norm = jnp.where(alive, jnp.sqrt(jnp.float32(m)) / safe_alpha, 0.0)
    return norm_guard(ghat, true_norm)


@functools.partial(jax.jit, static_argnames=("n_components", "iters", "em"))
def qgamp_ea_run(
    codes: jnp.ndarray,  # (nb, M) uint8/int Lloyd-Max code indices
    alpha: jnp.ndarray,  # (nb,) transmitted BQCS scales (0 = dead block)
    a: jnp.ndarray,  # (M, N)
    taus: jnp.ndarray,  # (2^Q - 1,) interior Lloyd-Max thresholds
    n_components: int = 3,
    iters: int = 25,
    em: bool = True,
    lam0: float = 0.9,
) -> jnp.ndarray:
    """Full EA reconstruction using the fused kernel: scan of qgamp_step.

    Equivalent to core.gamp.qem_gamp(variance_mode='scalar', tol=0) -- the
    kernel path runs a fixed trip count with no early-freeze (static work for
    the scheduler; see DESIGN.md), including the same far-tail channel
    fallback and final norm guard.
    """
    m = codes.shape[1]
    return _qgamp_ea_scan(
        codes.astype(jnp.int32), alpha, a, taus, 0, m, n_components, iters, em, lam0
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "m", "n_components", "iters", "em")
)
def qgamp_ea_run_packed(
    words: jnp.ndarray,  # (nb, W) uint32 packed wire words (pack_codes layout)
    alpha: jnp.ndarray,  # (nb,) transmitted BQCS scales (0 = dead block)
    a: jnp.ndarray,  # (M, N)
    taus: jnp.ndarray,  # (2^Q - 1,) interior Lloyd-Max thresholds
    bits: int,  # Q
    m: int,  # true measurement count M <= W * (32 // Q)
    n_components: int = 3,
    iters: int = 25,
    em: bool = True,
    lam0: float = 0.9,
) -> jnp.ndarray:
    """Packed-domain EA reconstruction: the scan consumes the uint32 wire
    words directly and the kernel unpacks per lane group in VMEM, so the
    (nb, M) uint8 code tensor never exists in HBM.  Bit-identical to
    ``qgamp_ea_run(unpack_codes(words), ...)`` (pinned by tests)."""
    assert words.dtype == jnp.uint32, words.dtype
    return _qgamp_ea_scan(
        words, alpha, a, taus, bits, m, n_components, iters, em, lam0
    )


@functools.partial(jax.jit, static_argnames=("n_components", "iters", "em"))
def gamp_ae_run(
    y: jnp.ndarray,  # (nb, M) Bussgang-aggregated observations
    nu_d: jnp.ndarray,  # (nb,) effective AWGN variance (eq. 24)
    a: jnp.ndarray,  # (M, N)
    init_var: jnp.ndarray,  # (nb,) per-entry signal energy
    n_components: int = 3,
    iters: int = 25,
    em: bool = True,
    lam0: float = 0.9,
) -> jnp.ndarray:
    """Full AE reconstruction using the fused kernel: scan of gamp_step.

    Equivalent to core.gamp.em_gamp(variance_mode='scalar', tol=0) -- the
    kernel path runs a fixed trip count with no early-freeze (static work for
    the scheduler; see DESIGN.md).
    """
    nb, m = y.shape
    n = a.shape[1]
    init_var = jnp.asarray(init_var, jnp.float32)
    # Pad ONCE to a tile multiple (benign ones-rows), scan the raw kernel,
    # trim once at the end -- same pattern as qgamp_ea_run below.
    tb = min(_gstep.DEFAULT_TB, max(8, nb))
    (y_p, nud2, init_var_p), _ = _pad_rows_ones(
        (y, jnp.asarray(nu_d, jnp.float32)[:, None], init_var), tb
    )
    nbp = y_p.shape[0]
    theta0 = _gm.pack_init_theta(nbp, n_components, init_var_p, lam0)
    ghat0 = jnp.zeros((nbp, n), jnp.float32)
    nu_g0 = jnp.broadcast_to(
        jnp.maximum(init_var_p, 1e-12)[:, None], (nbp, n)
    ).astype(jnp.float32)
    shat0 = jnp.zeros((nbp, m), jnp.float32)

    def body(carry, _):
        gh, ng, sh, th = carry
        gh, ng, sh, th = _gstep.gamp_step_pallas(
            gh, ng, sh, th, y_p, nud2, a,
            n_components=n_components, em=em, tb=tb, interpret=_interpret(),
        )
        return (gh, ng, sh, th), None

    (ghat, _, _, _), _ = jax.lax.scan(
        body, (ghat0, nu_g0, shat0, theta0), None, length=iters
    )
    # Expected ||g_sum||^2 = init_var * N (see core.gamp.em_gamp).
    return norm_guard(ghat[:nb], jnp.sqrt(jnp.maximum(init_var * n, 0.0)))
