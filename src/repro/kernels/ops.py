"""Jit'd public wrappers around the Pallas kernels.

Handles: row padding to tile multiples, interpret-mode selection (the kernels
execute in interpret mode on CPU -- the TPU lowering is the target), dtype
plumbing, and a full GAMP driver (`gamp_ae_run`) that scans the fused
`gamp_step` kernel.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import LloydMaxQuantizer
from repro.kernels import bqcs_encode as _enc
from repro.kernels import block_topk as _topk
from repro.kernels import gamp_step as _gstep


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jnp.ndarray, tb: int) -> Tuple[jnp.ndarray, int]:
    nb = x.shape[0]
    pad = (-nb) % tb
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, nb


def bqcs_encode(
    blocks: jnp.ndarray, a: jnp.ndarray, quantizer: LloydMaxQuantizer, tb: int | None = None
):
    """Fused scale+project+quantize.  blocks (nb, N), a (M, N).

    Returns (codes uint8 (nb, M), alpha (nb,)).
    """
    tb = tb or min(_enc.DEFAULT_TB, max(8, blocks.shape[0]))
    padded, nb = _pad_rows(blocks.astype(jnp.float32), tb)
    codes, alpha = _enc.bqcs_encode_pallas(
        padded, a.T, quantizer.jnp_thresholds(), tb=tb, interpret=_interpret()
    )
    return codes[:nb].astype(jnp.uint8), alpha[:nb]


def block_sparsify(blocks: jnp.ndarray, s: int, tb: int | None = None):
    """Bisection top-S sparsify.  Returns (sparse, residual)."""
    tb = tb or min(_topk.DEFAULT_TB, max(8, blocks.shape[0]))
    padded, nb = _pad_rows(blocks.astype(jnp.float32), tb)
    sparse, resid = _topk.block_topk_pallas(padded, s, tb=tb, interpret=_interpret())
    return sparse[:nb], resid[:nb]


def gamp_step(
    ghat, nu_g, shat, theta, y, nu_d, a, n_components: int = 3, em: bool = True,
    tb: int | None = None,
):
    """One fused AE GAMP iteration (see gamp_step.py for contract)."""
    tb = tb or min(_gstep.DEFAULT_TB, max(8, ghat.shape[0]))
    nb = ghat.shape[0]
    pad = (-nb) % tb
    if pad:
        padf = lambda x: jnp.concatenate(
            [x, jnp.ones((pad,) + x.shape[1:], x.dtype)], axis=0
        )
        ghat, nu_g, shat, theta, y, nu_d = map(padf, (ghat, nu_g, shat, theta, y, nu_d))
    outs = _gstep.gamp_step_pallas(
        ghat, nu_g, shat, theta, y, nu_d, a,
        n_components=n_components, em=em, tb=tb, interpret=_interpret(),
    )
    return tuple(o[:nb] for o in outs)


@functools.partial(jax.jit, static_argnames=("n_components", "iters", "em"))
def gamp_ae_run(
    y: jnp.ndarray,  # (nb, M) Bussgang-aggregated observations
    nu_d: jnp.ndarray,  # (nb,) effective AWGN variance (eq. 24)
    a: jnp.ndarray,  # (M, N)
    init_var: jnp.ndarray,  # (nb,) per-entry signal energy
    n_components: int = 3,
    iters: int = 25,
    em: bool = True,
    lam0: float = 0.9,
) -> jnp.ndarray:
    """Full AE reconstruction using the fused kernel: scan of gamp_step.

    Equivalent to core.gamp.em_gamp(variance_mode='scalar', tol=0) -- the
    kernel path runs a fixed trip count with no early-freeze (static work for
    the scheduler; see DESIGN.md).
    """
    nb, m = y.shape
    n = a.shape[1]
    L = n_components
    sigma = jnp.sqrt(jnp.maximum(init_var, 1e-12))
    gmax = 3.0 * sigma[:, None]
    ls = jnp.arange(1, L + 1, dtype=jnp.float32)[None, :]
    mu0 = -gmax + (2.0 * ls - 1.0) / (2.0 * L) * (2.0 * gmax)
    phi0 = jnp.broadcast_to((2.0 * gmax / L) ** 2 / 12.0, mu0.shape)
    theta0 = jnp.concatenate(
        [
            jnp.full((nb, 1), lam0, jnp.float32),
            jnp.full((nb, L), (1.0 - lam0) / L, jnp.float32),
            mu0,
            phi0,
        ],
        axis=1,
    )
    ghat0 = jnp.zeros((nb, n), jnp.float32)
    nu_g0 = jnp.broadcast_to(jnp.maximum(init_var, 1e-12)[:, None], (nb, n)).astype(
        jnp.float32
    )
    shat0 = jnp.zeros((nb, m), jnp.float32)
    nud2 = jnp.asarray(nu_d, jnp.float32)[:, None]

    def body(carry, _):
        gh, ng, sh, th = carry
        gh, ng, sh, th = gamp_step(
            gh, ng, sh, th, y, nud2, a, n_components=n_components, em=em
        )
        return (gh, ng, sh, th), None

    (ghat, _, _, _), _ = jax.lax.scan(
        body, (ghat0, nu_g0, shat0, theta0), None, length=iters
    )
    return ghat
