"""Adam/AdamW + SGD from scratch, with optional blockwise-int8 moment states.

The int8 state quantization (bitsandbytes-style linear blockwise, block=256)
is a beyond-paper distributed-optimization feature thematically aligned with
FedQCS: it keeps the optimizer-state HBM footprint of the largest assigned
architectures (deepseek-v3-671b) within a v5e pod's memory budget
(2 x 1 byte/param instead of 2 x 4 -- see EXPERIMENTS.md #Dry-run).

All functions are pure pytree -> pytree (jit/shard_map friendly); state
leaves inherit the parameter sharding (quantized leaves keep the original
leaf shape so PartitionSpecs transfer unchanged).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

_QBLOCK = 256


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adam"  # adam | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"  # float32 | int8
    momentum: float = 0.9  # sgd


def schedule(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac.  (step+1)/warmup so the
    very first step takes a non-zero update."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum((step + 1.0) / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


# ---------------------------------------------------------------------------
# blockwise int8 moment quantization
# ---------------------------------------------------------------------------


class QLeaf(NamedTuple):
    q: jnp.ndarray  # int8, original leaf shape
    scale: jnp.ndarray  # f32, (ceil(size/256),)


def _quantize_leaf(x: jnp.ndarray, sqrt_domain: bool = False) -> QLeaf:
    """Blockwise int8.  ``sqrt_domain=True`` (used for Adam's second moment)
    stores sqrt(x)/sqrt(blockmax) instead of x/blockmax: v spans many decades
    within a block, and a LINEAR mapping underflows small v to exactly 0,
    which makes 1/(sqrt(v)+eps) explode.  The sqrt mapping gives ~250x more
    headroom at the small end; dequantization floors at a half-LSB so v never
    collapses to zero."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % _QBLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, _QBLOCK)
    if sqrt_domain:
        fp = jnp.sqrt(jnp.maximum(fp, 0.0))
    scale = jnp.max(jnp.abs(fp), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(fp / safe[:, None]), -127, 127).astype(jnp.int8)
    return QLeaf(q.reshape(-1)[: flat.shape[0]].reshape(x.shape), scale)


def _dequantize_leaf(ql: QLeaf, sqrt_domain: bool = False) -> jnp.ndarray:
    flat = ql.q.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % _QBLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, _QBLOCK)
    if sqrt_domain:
        fp = jnp.maximum(jnp.abs(fp), 0.5)  # half-LSB floor: v never hits 0
        out = jnp.square(fp * ql.scale[:, None])
        zero_blocks = (ql.scale == 0.0)[:, None]
        out = jnp.where(zero_blocks, 0.0, out)
    else:
        out = fp * ql.scale[:, None]
    return out.reshape(-1)[: flat.size].reshape(ql.q.shape)


def _maybe_q(x, cfg: OptConfig, sqrt_domain: bool = False):
    return _quantize_leaf(x, sqrt_domain) if cfg.state_dtype == "int8" else x


def _maybe_dq(x, cfg: OptConfig, sqrt_domain: bool = False):
    return _dequantize_leaf(x, sqrt_domain) if isinstance(x, QLeaf) else x


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def init_state(cfg: OptConfig, params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    if cfg.kind == "adam":
        return {
            "m": jax.tree_util.tree_map(lambda p: _maybe_q(zeros(p), cfg), params),
            "v": jax.tree_util.tree_map(lambda p: _maybe_q(zeros(p), cfg), params),
        }
    return {"m": jax.tree_util.tree_map(lambda p: _maybe_q(zeros(p), cfg), params)}


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
    )


def update(cfg: OptConfig, grads, state, params, step) -> Tuple[Any, dict]:
    lr = schedule(cfg, step)
    if cfg.grad_clip > 0:
        gn = _global_norm(grads)
        clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * clip, grads)
    t = jnp.asarray(step, jnp.float32) + 1.0

    if cfg.kind == "sgd":

        def upd(p, g, m):
            mf = _maybe_dq(m, cfg)
            mf = cfg.momentum * mf + g.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * mf
            if cfg.weight_decay:
                new_p = new_p - lr * cfg.weight_decay * p.astype(jnp.float32)
            return new_p.astype(p.dtype), _maybe_q(mf, cfg)

        out = jax.tree_util.tree_map(
            upd, params, grads, state["m"],
            is_leaf=lambda x: isinstance(x, QLeaf),
        )
        new_params = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m}

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = _maybe_dq(m, cfg)
        vf = _maybe_dq(v, cfg, sqrt_domain=True)
        mf = cfg.b1 * mf + (1 - cfg.b1) * gf
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(gf)
        mhat = mf / (1 - cfg.b1**t)
        vhat = vf / (1 - cfg.b2**t)
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * step_dir
        if cfg.weight_decay:
            new_p = new_p - lr * cfg.weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), _maybe_q(mf, cfg), _maybe_q(vf, cfg, sqrt_domain=True)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    is_q = lambda x: isinstance(x, QLeaf)
    flat_m = jax.tree_util.tree_leaves(state["m"], is_leaf=is_q)
    flat_v = jax.tree_util.tree_leaves(state["v"], is_leaf=is_q)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    return new_params, {"m": new_m, "v": new_v}
