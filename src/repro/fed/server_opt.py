"""Server-side optimizers over the reconstructed aggregate (DESIGN.md
#Fed-engine).

The PS treats the reconstructed, rho-weighted aggregate as a pseudo-gradient
and applies one server update per round (Reddi et al., "Adaptive Federated
Optimization"):

  * ``fedavg``  — plain SGD: ``params -= lr * ghat`` (lr=1 recovers classical
    parameter averaging of client deltas).
  * ``fedavgm`` — server momentum: ``m = momentum*m + ghat; params -= lr*m``.
  * ``fedadam`` — server Adam; delegates to ``optim/adam.py`` with clipping,
    warmup, and decay disabled, which is exactly the update the paper's
    Sec. VI experiment ran (and what ``paper/mlp.py`` used before the cohort
    engine absorbed it), so the rewire is update-for-update identical.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adam

__all__ = ["ServerOptConfig", "init_server_state", "server_update"]


@dataclasses.dataclass(frozen=True)
class ServerOptConfig:
    kind: str = "fedadam"  # fedavg | fedavgm | fedadam
    lr: float = 0.003
    momentum: float = 0.9  # fedavgm
    b1: float = 0.9  # fedadam
    b2: float = 0.999
    eps: float = 1e-8

    def _adam_cfg(self) -> adam.OptConfig:
        return adam.OptConfig(
            lr=self.lr, b1=self.b1, b2=self.b2, eps=self.eps, grad_clip=0.0,
            warmup_steps=0, decay_steps=10**9, min_lr_frac=1.0,
        )


def init_server_state(cfg: ServerOptConfig, params: Any) -> Dict[str, Any]:
    if cfg.kind == "fedadam":
        return adam.init_state(cfg._adam_cfg(), params)
    if cfg.kind == "fedavgm":
        return {"m": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)}
    if cfg.kind == "fedavg":
        return {}
    raise ValueError(f"unknown server optimizer {cfg.kind!r}")


def server_update(
    cfg: ServerOptConfig, ghat: Any, state: Dict[str, Any], params: Any, step
) -> Tuple[Any, Dict[str, Any]]:
    """One server round: (params, state) <- update(params, ghat)."""
    if cfg.kind == "fedadam":
        return adam.update(cfg._adam_cfg(), ghat, state, params, step)
    if cfg.kind == "fedavgm":
        new_m = jax.tree_util.tree_map(
            lambda m, g: cfg.momentum * m + g.astype(jnp.float32), state["m"], ghat
        )
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - cfg.lr * m).astype(p.dtype),
            params, new_m,
        )
        return new_params, {"m": new_m}
    if cfg.kind == "fedavg":
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - cfg.lr * g.astype(jnp.float32)).astype(p.dtype),
            params, ghat,
        )
        return new_params, state
    raise ValueError(f"unknown server optimizer {cfg.kind!r}")
