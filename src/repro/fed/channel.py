"""Wireless uplink models (DESIGN.md #Fed-engine).

The paper's Sec. IV reconstruction already consumes a per-block AWGN variance
(``em_gamp(..., noise_var)``); the repo's drivers fed it only the Bussgang
quantization distortion of eq. 24.  This module supplies the missing wireless
term: each client's M normalized measurements (the BQCS ``alpha`` scaling
makes them ~ N(0,1), i.e. unit transmit power) cross an uplink that adds
noise, and the *effective* post-equalization variance is threaded into the
same ``noise_var`` hook — exactly the FedVQCS scenario axis
(arXiv:2204.07692).

Models (``ChannelConfig.kind``):

  * ``ideal``    — error-free digital uplink: zero added variance.  The only
    model under which code-domain methods (EA, QIHT, dither, signsgd) are
    well-defined, since those need the exact codes at the PS.
  * ``awgn``     — unit channel gain, noise variance ``sigma^2 =
    10**(-snr_db/10)`` per measurement (SNR is defined against the unit
    transmit power the alpha-scaling guarantees).
  * ``rayleigh`` — block-fading: one power gain ``g_k = |h_k|^2 ~ Exp(1)``
    per client per round, constant across that client's blocks.  Clients
    transmit at the fixed unit power and the PS zero-forces the known
    channel (divides by ``h_k``), so the equalized noise variance is
    ``sigma^2 / g_k`` — deep fades cost noise, not transmit power.  A gain
    below ``outage_gain`` makes the equalized SNR unusable and the client
    goes into outage (its cohort slot gets ``rho_k = 0``, same straggler
    contract as the scheduler).

The realization is sampled *before* the cohort passes run, so the outage
mask can fold into the effective rhos and the per-client residual carry rule
(engine.py) — and so the vmapped and Python-loop paths consume bit-identical
channel draws.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["ChannelConfig", "ChannelRealization", "realize_uplink", "snr_noise_var"]


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    kind: str = "ideal"  # ideal | awgn | rayleigh
    snr_db: float = 20.0  # receive SNR per measurement (unit transmit power)
    outage_gain: float = 0.05  # truncated-inversion floor on |h|^2


class ChannelRealization(NamedTuple):
    """One round's uplink draw for a C-client cohort.

    noise_var: (C, nblocks) effective post-equalization AWGN variance on each
      client's unit-power measurement rows (0 for ideal / outage slots).
    mask: (C,) 1.0 for clients whose uplink closed, 0.0 for outage.
    """

    noise_var: jnp.ndarray
    mask: jnp.ndarray


def snr_noise_var(snr_db: float) -> float:
    """sigma^2 = 10**(-SNR_dB/10): noise power at unit receive signal power."""
    return float(10.0 ** (-snr_db / 10.0))


def realize_uplink(
    cfg: ChannelConfig, key: jax.Array, clients: int, nblocks: int
) -> ChannelRealization:
    """Samples one round's channel state for a ``clients``-slot cohort."""
    ones = jnp.ones((clients,), jnp.float32)
    if cfg.kind == "ideal":
        return ChannelRealization(jnp.zeros((clients, nblocks), jnp.float32), ones)
    sigma2 = snr_noise_var(cfg.snr_db)
    if cfg.kind == "awgn":
        return ChannelRealization(
            jnp.full((clients, nblocks), sigma2, jnp.float32), ones
        )
    if cfg.kind == "rayleigh":
        gain = jax.random.exponential(key, (clients,), jnp.float32)  # |h|^2
        alive = gain >= cfg.outage_gain
        safe = jnp.where(alive, gain, 1.0)
        nu = jnp.where(alive, sigma2 / safe, 0.0)
        return ChannelRealization(
            jnp.broadcast_to(nu[:, None], (clients, nblocks)).astype(jnp.float32),
            alive.astype(jnp.float32),
        )
    raise ValueError(f"unknown channel kind {cfg.kind!r}")
