"""Wireless uplink models behind the `ChannelFamily` registry
(DESIGN.md #Channels).

The paper's Sec. IV reconstruction already consumes a per-block AWGN variance
(``em_gamp(..., noise_var)``); this module supplies the wireless term.  Like
the quantizer codebooks (core/codebook.py), uplink physics is a *pluggable
family*: each model registers a :class:`ChannelFamily` whose hooks the engine
calls, so a new channel lands as one registration -- never another
``if kind ==`` branch in the engine.

Family hooks (all jit-safe; ``cfg`` is the frozen :class:`ChannelConfig`):

  * ``realize(cfg, key, clients, nblocks) -> ChannelRealization`` -- one
    round's channel draw for a ``clients``-slot cohort, sampled *before* the
    cohort passes run (so outage folds into the effective rhos / residual
    carry, and the vmapped and loop paths consume bit-identical draws).
  * ``transmit(cfg, realization, x, key) -> y`` -- pushes the cohort's
    transmitted measurement rows ``x`` through the channel.  Per-client
    families return per-client receptions of ``x``'s shape; multiple-access
    families return the SUPERIMPOSED signal ``Y = H X + N`` whose size does
    not grow with the cohort.
  * ``effective_noise(realization) -> (C, nblocks)`` -- the per-client
    post-equalization variance threaded into ``em_gamp``'s ``noise_var``
    (per-client families; MAC families estimate noise in ``combine``).
  * ``combine(cfg, realization, y, w, active, ..., with_aux=False) ->
    (y_eff, nu_eff)`` -- multiple-access only: joint-estimation decode of
    the superimposed reception (see below); ``with_aux=True`` appends a
    scalar combiner-health dict (repro.obs decode counters).

Traits drive the engine's method gating (no string dispatch):

  * ``exact_codes`` -- error-free digital uplink: the only regime where
    code-domain methods (EA, QIHT, dither, signsgd) are well-defined.
  * ``multiple_access`` -- the PS receives ONE superimposed signal and must
    joint-estimate the aggregate (the ``combine`` hook).

Registered families:

  * ``ideal``    -- error-free digital uplink: zero added variance.
  * ``awgn``     -- unit channel gain, noise variance ``sigma^2 =
    10**(-snr_db/10)`` per measurement (SNR against the unit transmit power
    the BQCS alpha-scaling guarantees).
  * ``rayleigh`` -- block-fading: one power gain ``g_k = |h_k|^2 ~ Exp(1)``
    per client per round; the PS zero-forces the known channel so the
    equalized noise variance is ``sigma^2 / g_k``; a gain below
    ``outage_gain`` puts the client in outage (``rho_k = 0``, the scheduler's
    straggler contract).
  * ``mimo_mac`` -- the over-the-air MIMO multiple-access uplink of the
    paper's sequels (arXiv:2206.05723, arXiv:2003.08059): a per-round real
    fading matrix ``H`` (n_rx antennas x C clients), every participating
    client transmits its Bussgang-weighted dequantized measurement rows
    *simultaneously*, and the PS receives ``Y = H X + sigma N`` -- one
    ``(n_rx, nblocks, M)`` signal independent of cohort size.  Imperfect CSI
    is a scenario axis: the PS combines with ``H_hat = H + sqrt(csi_error)
    Delta``.  Decode is LMMSE (or zero-forcing) spatial combining into an
    estimate of the rho-weighted aggregate plus its effective post-combining
    noise variance, which threads straight into the existing Bussgang/EM-GAMP
    machinery (eq. 24 + the ``nu_eff`` term).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ChannelConfig",
    "ChannelRealization",
    "ChannelFamily",
    "CHANNEL_FAMILIES",
    "register_channel_family",
    "get_channel_family",
    "realize_uplink",
    "snr_noise_var",
    "mimo_tx_gain",
    "mimo_combine",
]


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    kind: str = "ideal"  # any registered family: ideal | awgn | rayleigh | mimo_mac
    snr_db: float = 20.0  # receive SNR per measurement (unit transmit power)
    outage_gain: float = 0.05  # truncated-inversion floor on |h|^2 (rayleigh)
    # -- mimo_mac scenario axes --------------------------------------------
    n_rx: int = 8  # PS receive antennas (rows of H)
    csi_error: float = 0.0  # per-entry variance of the PS's CSI estimate error
    combiner: str = "lmmse"  # spatial combiner: lmmse | zf


class ChannelRealization(NamedTuple):
    """One round's uplink draw for a C-client cohort.

    noise_var: (C, nblocks) effective post-equalization AWGN variance on each
      client's unit-power measurement rows (0 for ideal / outage / MAC slots).
    mask: (C,) 1.0 for clients whose uplink closed, 0.0 for outage.
    h / h_hat / sigma2: multiple-access families only -- the true (n_rx, C)
      fading matrix, the PS's CSI estimate of it, and the scalar receiver
      noise variance.  ``None`` for per-client families (jit-safe: None
      leaves drop out of the pytree).
    """

    noise_var: jnp.ndarray
    mask: jnp.ndarray
    h: Optional[jnp.ndarray] = None
    h_hat: Optional[jnp.ndarray] = None
    sigma2: Optional[jnp.ndarray] = None


@dataclasses.dataclass(frozen=True)
class ChannelFamily:
    """The protocol every uplink model implements (module docstring)."""

    name: str
    exact_codes: bool  # error-free digital wire: code-domain methods OK
    multiple_access: bool  # superimposed reception: joint-estimation decode
    realize: Callable[..., ChannelRealization]
    transmit: Callable[..., jnp.ndarray]
    effective_noise: Callable[[ChannelRealization], jnp.ndarray]
    combine: Optional[Callable[..., Tuple[jnp.ndarray, jnp.ndarray]]] = None


CHANNEL_FAMILIES: Dict[str, ChannelFamily] = {}


def register_channel_family(name: str, family: ChannelFamily) -> None:
    """Registers ``family`` under ``ChannelConfig.kind == name``.  This is
    the plugin point: new uplink physics (correlated fading, OFDM subcarrier
    maps, jamming) lands as one registration, and the engine, the streaming
    PS, and the drivers all pick it up through the traits + hooks."""
    CHANNEL_FAMILIES[name] = family


def get_channel_family(kind: str) -> ChannelFamily:
    """Resolves a registered family; the ONLY kind dispatch in the repo."""
    try:
        return CHANNEL_FAMILIES[kind]
    except KeyError:
        raise ValueError(
            f"unknown channel kind {kind!r} "
            f"(registered: {sorted(CHANNEL_FAMILIES)})"
        ) from None


def snr_noise_var(snr_db: float) -> float:
    """sigma^2 = 10**(-SNR_dB/10): noise power at unit receive signal power."""
    return float(10.0 ** (-snr_db / 10.0))


def realize_uplink(
    cfg: ChannelConfig, key: jax.Array, clients: int, nblocks: int
) -> ChannelRealization:
    """Samples one round's channel state for a ``clients``-slot cohort
    through the registry (bit-identical draws to the pre-registry models,
    pinned by tests/test_channel.py)."""
    return get_channel_family(cfg.kind).realize(cfg, key, clients, nblocks)


# ---------------------------------------------------------------------------
# per-client families: ideal / awgn / rayleigh
# ---------------------------------------------------------------------------


def _ideal_realize(cfg, key, clients, nblocks):
    return ChannelRealization(
        jnp.zeros((clients, nblocks), jnp.float32), jnp.ones((clients,), jnp.float32)
    )


def _awgn_realize(cfg, key, clients, nblocks):
    sigma2 = snr_noise_var(cfg.snr_db)
    return ChannelRealization(
        jnp.full((clients, nblocks), sigma2, jnp.float32),
        jnp.ones((clients,), jnp.float32),
    )


def _rayleigh_realize(cfg, key, clients, nblocks):
    sigma2 = snr_noise_var(cfg.snr_db)
    gain = jax.random.exponential(key, (clients,), jnp.float32)  # |h|^2
    alive = gain >= cfg.outage_gain
    safe = jnp.where(alive, gain, 1.0)
    nu = jnp.where(alive, sigma2 / safe, 0.0)
    return ChannelRealization(
        jnp.broadcast_to(nu[:, None], (clients, nblocks)).astype(jnp.float32),
        alive.astype(jnp.float32),
    )


def _ideal_transmit(cfg, real, x, key):
    return x


def _pointwise_transmit(cfg, real, x, key):
    """Per-client reception: each client's (nb, M) rows arrive with their
    equalized noise sampled at the realization's per-(client, block)
    variance.  x: (C, nb, M)."""
    noise = jax.random.normal(key, x.shape, x.dtype)
    return x + noise * jnp.sqrt(real.noise_var)[..., None]


def _pointwise_noise(real):
    return real.noise_var


register_channel_family("ideal", ChannelFamily(
    name="ideal", exact_codes=True, multiple_access=False,
    realize=_ideal_realize, transmit=_ideal_transmit,
    effective_noise=_pointwise_noise,
))
register_channel_family("awgn", ChannelFamily(
    name="awgn", exact_codes=False, multiple_access=False,
    realize=_awgn_realize, transmit=_pointwise_transmit,
    effective_noise=_pointwise_noise,
))
register_channel_family("rayleigh", ChannelFamily(
    name="rayleigh", exact_codes=False, multiple_access=False,
    realize=_rayleigh_realize, transmit=_pointwise_transmit,
    effective_noise=_pointwise_noise,
))


# ---------------------------------------------------------------------------
# mimo_mac: over-the-air MIMO multiple-access uplink
# ---------------------------------------------------------------------------


def _mimo_realize(cfg, key, clients, nblocks):
    if cfg.n_rx < 1:
        raise ValueError(f"mimo_mac needs n_rx >= 1 receive antennas, got {cfg.n_rx}")
    if cfg.combiner not in ("lmmse", "zf"):
        raise ValueError(
            f"unknown mimo_mac combiner {cfg.combiner!r} (choose 'lmmse' or 'zf')"
        )
    k_h, k_e = jax.random.split(key)
    h = jax.random.normal(k_h, (cfg.n_rx, clients), jnp.float32)
    if cfg.csi_error > 0:
        h_hat = h + np.sqrt(cfg.csi_error) * jax.random.normal(
            k_e, h.shape, jnp.float32
        )
    else:
        h_hat = h
    return ChannelRealization(
        jnp.zeros((clients, nblocks), jnp.float32),
        jnp.ones((clients,), jnp.float32),
        h=h,
        h_hat=h_hat,
        sigma2=jnp.float32(snr_noise_var(cfg.snr_db)),
    )


def _mimo_transmit(cfg, real, x, key):
    """The multiple-access superposition: every client transmits its rows
    SIMULTANEOUSLY and the channel adds them -- ``Y = H X + sigma N``.

    x: (C, nb, M) pre-scaled transmit rows (non-participants carry zero rows,
    so masking H columns is implicit) -> (n_rx, nb, M) received signal, whose
    size is independent of the cohort size C.
    """
    y = jnp.einsum("rk,kbm->rbm", real.h, x)
    noise = jax.random.normal(key, y.shape, y.dtype)
    return y + jnp.sqrt(real.sigma2) * noise


def _mimo_noise(real):
    # The MAC has no per-client equalized variance; the decode-side noise
    # estimate comes out of `combine` (post-combining, per block).
    return real.noise_var


def mimo_tx_gain(w: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """Open-loop power control: ONE broadcast scalar ``eta`` normalizing the
    cohort's average transmit power to the unit power the SNR is defined
    against.

    Clients pre-scale by their Bussgang weight ``w_k ~ rho_k / (gamma
    alpha_k)``; without power control the transmitted power carries a
    ``rho^2`` penalty (a 1/K^2 SNR loss at uniform weights) that the
    per-client families never pay, because they weight AFTER the channel.
    ``eta = 1 / rms(active w)`` restores unit average power; it is a single
    scalar negotiated once per round (the standard OTA-FL power-control
    feedback loop), NOT per-client side information.  Returns 0 when the
    whole cohort is silent (nothing transmits).
    """
    w2 = jnp.square(w) * active[:, None]  # (C, nb)
    n = jnp.maximum(jnp.sum(active) * w.shape[1], 1.0)
    mean_w2 = jnp.sum(w2) / n
    return jnp.where(
        mean_w2 > 0, jax.lax.rsqrt(jnp.maximum(mean_w2, 1e-30)), 0.0
    ).astype(jnp.float32)


def mimo_combine(
    cfg: ChannelConfig,
    real: ChannelRealization,
    y: jnp.ndarray,  # (n_rx, nb, M) superimposed reception
    w: jnp.ndarray,  # (C, nb) Bussgang weights the clients pre-scaled with
    active: jnp.ndarray,  # (C,) 1.0 = transmitted this round, 0.0 = silent
    psi: float = 1.0,  # codebook per-entry second moment (transmit power)
    tx_gain: Optional[jnp.ndarray] = None,  # mimo_tx_gain eta (None = 1)
    with_aux: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """Joint-estimation decode: spatial combining of ``Y = H X + sigma N``
    into an estimate of the rho-weighted aggregate measurement vector plus
    its effective post-combining noise variance.

    Clients transmit ``x_k = eta * w_k * deq_k`` (Bussgang pre-scaling: rho
    is broadcast by the PS, alpha is client-local, so NO per-client side
    information crosses the uplink; ``eta`` is :func:`mimo_tx_gain`'s
    broadcast power-control scalar, which the PS divides back out here),
    making the target combining response ``f^T h_k = 1`` on every active
    column.  One combining vector ``f`` serves all blocks:

      * ``lmmse``: ``f = (H P H^T + (sigma^2 + csi_error tr P) I)^-1 H p``
        with per-client power ``p_k = psi * mean_b w_kb^2`` (0 for silent
        clients, which drops them from the combiner automatically);
      * ``zf``: ``f^T h_k = 1`` exactly on active columns (needs
        n_rx >= #active); silent columns are pinned out of the solve with
        static shapes.

    The combiner only sees ``h_hat`` (imperfect CSI); the returned noise
    estimate charges the residual target mismatch, the CSI error, and the
    combined receiver noise:

        nu_b = psi sum_k w_kb^2 (f^T h_hat_k - t_k)^2
             + psi csi_error ||f||^2 sum_k w_kb^2
             + sigma^2 ||f||^2.

    Returns ``(y_eff (nb, M), nu_eff (nb,))`` -- a linear AWGN observation of
    the aggregated gradient, exactly what ``em_gamp``'s ``noise_var`` hook
    consumes next to the eq. 24 quantization term.

    ``with_aux`` appends a jit-safe scalar dict of combiner health --
    ``csi_target_mismatch`` (mean squared combining-response error
    ``(f^T h_hat_k - 1)^2`` over active columns: how far imperfect CSI pulls
    the combiner off its unit-gain target) and ``combiner_norm2``
    (``||f||^2``, the receiver-noise amplification) -- for repro.obs.  Every
    multiple-access family's ``combine`` hook accepts this kwarg (part of
    the protocol), so the engine stays free of kind dispatch.
    """
    h_hat = real.h_hat
    if tx_gain is not None:
        # the combiner sees the powers actually on the air
        w = w * tx_gain
    w2 = jnp.square(w) * active[:, None]  # (C, nb)
    if cfg.combiner == "zf":
        # Pin silent columns to the identity so the (C, C) solve keeps static
        # shapes: their Gram row becomes e_k with a zero target -> c_k = 0.
        ha = h_hat * active[None, :]
        gram = ha.T @ ha + jnp.diag(1.0 - active)
        c = jnp.linalg.solve(gram, active)
        f = ha @ c  # (n_rx,)
    else:  # lmmse
        p = psi * jnp.mean(w2, axis=1)  # (C,) per-client transmit power
        cov = (h_hat * p[None, :]) @ h_hat.T
        reg = real.sigma2 + float(cfg.csi_error) * jnp.sum(p)
        eye = jnp.eye(cfg.n_rx, dtype=jnp.float32)
        f = jnp.linalg.solve(cov + reg * eye, h_hat @ p)
    y_eff = jnp.einsum("r,rbm->bm", f, y)
    e = jnp.einsum("r,rk->k", f, h_hat) - active  # target mismatch per column
    f2 = jnp.sum(jnp.square(f))
    nu = psi * jnp.einsum("k,kb->b", jnp.square(e) * active, w2)
    nu = nu + psi * float(cfg.csi_error) * f2 * jnp.sum(w2, axis=0)
    nu = nu + real.sigma2 * f2
    if tx_gain is not None:
        # back to the un-amplified aggregate's domain (eta = 0 means the
        # whole cohort was silent: f = 0 already, return the zero signal)
        inv = jnp.where(tx_gain > 0, 1.0 / jnp.maximum(tx_gain, 1e-30), 0.0)
        y_eff = y_eff * inv
        nu = nu * jnp.square(inv)
    if with_aux:
        n_active = jnp.maximum(jnp.sum(active), 1.0)
        aux = {
            "csi_target_mismatch": jnp.sum(jnp.square(e) * active) / n_active,
            "combiner_norm2": f2,
        }
        return y_eff, nu, aux
    return y_eff, nu


register_channel_family("mimo_mac", ChannelFamily(
    name="mimo_mac", exact_codes=False, multiple_access=True,
    realize=_mimo_realize, transmit=_mimo_transmit,
    effective_noise=_mimo_noise, combine=mimo_combine,
))
