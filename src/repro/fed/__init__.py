"""repro.fed — the federated cohort simulation engine.

The paper's setting is wireless federated learning: K devices with non-IID
local data, partial participation, and a noisy uplink feeding the PS-side
reconstruction.  This package turns the repo's codec + reconstruction stack
into a *system* that simulates that setting at thousands-of-clients scale
over any registry model:

  * :mod:`repro.fed.partition`  — IID / label-shard / Dirichlet(alpha) /
    paper partitioners over labeled datasets;
  * :mod:`repro.fed.scheduler`  — full / uniform-sampling / staleness-
    weighted async participation plus straggler-dropout, driving the
    ``rho_k`` weighting end to end;
  * :mod:`repro.fed.channel`    — the pluggable ``ChannelFamily`` registry:
    ideal / AWGN / Rayleigh block-fading uplinks whose effective noise
    variance threads into EM-GAMP's ``noise_var``, plus the ``mimo_mac``
    over-the-air multiple-access uplink (Y = HX + N, joint-estimation
    decode; DESIGN.md #Channels, #Fed-engine);
  * :mod:`repro.fed.server_opt` — FedAvg / FedAvgM / FedAdam server-side
    optimizers over the reconstructed aggregate;
  * :mod:`repro.fed.engine`     — the vmap(+scan-chunked) cohort round loop
    with a Python-loop oracle for bit-exactness and benchmarking;
  * :mod:`repro.fed.stream`     — the streaming round mode: arrival-ordered
    sub-cohort batches through a bounded ingest buffer into a carry-save
    tree of partial Bussgang/EA sufficient statistics, with a deadline
    cutoff that degrades into the non-participation contract
    (DESIGN.md #Streaming-PS).
"""

from repro.fed.channel import (
    CHANNEL_FAMILIES,
    ChannelConfig,
    ChannelFamily,
    ChannelRealization,
    get_channel_family,
    register_channel_family,
    realize_uplink,
)
from repro.fed.engine import ArrayClientData, CohortConfig, CohortEngine, TokenClientData
from repro.fed.partition import PartitionConfig, partition_indices
from repro.fed.scheduler import SchedulerConfig, SchedulerState, select_cohort
from repro.fed.server_opt import ServerOptConfig
from repro.fed.stream import BoundedIngestBuffer, StreamConfig, StreamingPS, stream_decode

__all__ = [
    "ArrayClientData",
    "BoundedIngestBuffer",
    "CHANNEL_FAMILIES",
    "ChannelConfig",
    "ChannelFamily",
    "ChannelRealization",
    "CohortConfig",
    "CohortEngine",
    "PartitionConfig",
    "SchedulerConfig",
    "SchedulerState",
    "ServerOptConfig",
    "StreamConfig",
    "StreamingPS",
    "TokenClientData",
    "get_channel_family",
    "partition_indices",
    "realize_uplink",
    "register_channel_family",
    "select_cohort",
    "stream_decode",
]
