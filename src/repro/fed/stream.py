"""Streaming rolling-cohort PS aggregation (DESIGN.md #Streaming-PS).

Every pre-existing round shape is a barrier: one cohort, one ``gather_codes``,
one monolithic decode over all K payloads.  This module is the Ape-X-style
producer/consumer split of that round: clients "arrive" over simulated time
(a deterministic latency/straggler model layered on the PR-3 scheduler's
cohort), their payloads land in a :class:`BoundedIngestBuffer` in sub-cohort
batches, and the :class:`StreamingPS` consumer drains the buffer into a
carry-save :class:`~repro.core.aggregator.AggregatorTree` of partial
Bussgang/EA sufficient statistics.  Consequences:

  * PS decode state is O(tree depth) partial stats + one in-flight batch --
    constant in the REGISTERED client count and logarithmic in the arrival
    batch count, never O(K) payloads (the barrier's ``(C, nb, M)`` stack).
  * Decode overlaps ingest: EA batches run their per-client GAMP inversions
    through the recon engine's chunk streaming *as they arrive*; AE folds are
    cheap dequant-and-accumulate with the single EM-GAMP at finalize.
  * The round deadline degrades gracefully: whatever arrived by the cutoff is
    decoded; non-arrivals keep their cohort slot with weight 0, so their
    error-feedback residual absorbs the FULL carry (``engine._encode_fn``'s
    rho = 0 branch) and the scheduler un-stamps them -- bit-identical to the
    PR-3 non-participation contract.
  * Late-but-before-deadline arrivals are down-weighted with the scheduler's
    own ``staleness_discount`` (staleness = soft-deadline overrun), so
    "stale by rounds" and "stale by seconds" share one knee.

Weight normalization: the consumer cannot know the final participant set
until the deadline, so stats fold with RAW weights and finalization rescales
by 1/W (see ``aggregator.normalized_stats``).  The streamed result therefore
matches the one-shot barrier decode up to f32 reassociation of the client
sums -- the tolerance contract pinned in ``tests/test_stream.py``.

Determinism: arrivals are a pure function of ``(StreamConfig.seed, round)``;
batch admission dedups on payload identity (a redelivered batch is rejected,
not double-counted); and the tree's fold order depends only on the admission
order, so a fixed arrival sequence reproduces bit-identical sums.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregator, bussgang
from repro.core.compression import BQCSCodec
from repro.core.gamp import GampConfig, gamp_health
from repro.core.recon_engine import decode_from_stats, ea_solve_flat
from repro.fed.channel import (
    ChannelConfig,
    ChannelRealization,
    get_channel_family,
    mimo_tx_gain,
)
from repro.fed.scheduler import staleness_discount

__all__ = [
    "StreamConfig",
    "simulate_arrivals",
    "late_discount",
    "batch_arrivals",
    "BoundedIngestBuffer",
    "StreamingPS",
    "stream_decode",
]


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Knobs of the streaming round.  Times are in units of the median
    client latency (the log-normal's scale), so ``deadline=8`` means "wait
    8x the typical client" regardless of absolute wall-clock."""

    batch_clients: int = 64  # sub-cohort payload batch size (ingest unit)
    buffer_batches: int = 8  # BoundedIngestBuffer capacity (backpressure past this)
    fanout: int = 8  # aggregator-tree carry fanout
    deadline: float = 8.0  # round cutoff: later arrivals are non-participants
    soft_deadline: float = 4.0  # overrun past this is "staleness" for late_decay
    late_decay: float = 0.0  # staleness_discount exponent for late arrivals
    latency_sigma: float = 0.35  # log-normal latency spread
    straggler_prob: float = 0.0  # P(client latency is multiplied by straggler_mult)
    straggler_mult: float = 8.0
    seed: int = 0


def simulate_arrivals(
    cfg: StreamConfig, round_idx: int, n: int, alive: np.ndarray
) -> np.ndarray:
    """Deterministic per-client arrival times (n,) for one round.

    Latency is log-normal (median 1) with a heavy straggler tail; clients not
    ``alive`` (scheduler-dropped or channel outage) never arrive (inf).
    Pure function of (cfg.seed, round_idx) -- the 0xA881 tag keeps this
    stream disjoint from the scheduler's and the data sampler's.
    """
    rng = np.random.default_rng((cfg.seed, 0xA881, round_idx))
    lat = rng.lognormal(mean=0.0, sigma=cfg.latency_sigma, size=n)
    if cfg.straggler_prob > 0:
        lat = np.where(rng.random(n) < cfg.straggler_prob, lat * cfg.straggler_mult, lat)
    return np.where(np.asarray(alive, bool), lat, np.inf)


def late_discount(cfg: StreamConfig, times: np.ndarray) -> np.ndarray:
    """Aggregation-weight discount for late-but-in-deadline arrivals:
    ``staleness_discount`` over the soft-deadline overrun.  Identity when
    ``late_decay == 0`` or the client beat the soft deadline."""
    if cfg.late_decay <= 0:
        return np.ones_like(np.asarray(times, np.float64))
    overrun = np.where(np.isfinite(times), np.maximum(times - cfg.soft_deadline, 0.0), 0.0)
    return staleness_discount(overrun, cfg.late_decay)


def batch_arrivals(
    times: np.ndarray, deadline: float, batch_clients: int
) -> List[np.ndarray]:
    """Groups the in-deadline arrivals into arrival-ordered sub-cohort payload
    batches of ``batch_clients`` positions (the last batch may be short).
    Ties break by cohort position (stable sort) -- deterministic."""
    arrived = np.flatnonzero(times <= deadline)
    order = arrived[np.argsort(times[arrived], kind="stable")]
    return [order[i : i + batch_clients] for i in range(0, len(order), batch_clients)]


class BoundedIngestBuffer:
    """Bounded FIFO between arrival and the folding consumer.

    ``push`` admits a batch under a content key and REJECTS redelivery: a key
    seen before (this round) is counted in ``rejected_dup`` and never occupies
    a slot, so a duplicated batch cannot be double-counted downstream.
    ``push`` raises when full -- the driver must drain first (backpressure),
    which is what bounds ingest memory.  Tracks ``peak_occupancy``.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"buffer capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._q: deque = deque()
        self._seen: set = set()
        self.admitted = 0
        self.rejected_dup = 0
        self.peak_occupancy = 0

    def __len__(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.capacity

    def push(self, key: bytes, item) -> bool:
        """Admit ``item`` under ``key``; False (rejected) for a duplicate."""
        if key in self._seen:
            self.rejected_dup += 1
            return False
        if self.full:
            raise RuntimeError(
                f"ingest buffer full ({self.capacity} batches): drain before pushing"
            )
        self._seen.add(key)
        self._q.append(item)
        self.admitted += 1
        self.peak_occupancy = max(self.peak_occupancy, len(self._q))
        return True

    def pop(self):
        return self._q.popleft()


class StreamingPS:
    """The consumer: folds gathered payload batches into the aggregator tree
    and finalizes the round decode from the folded root.

    Holds the jitted fold/finalize functions, so one instance should be
    reused across rounds (the engine owns one); ``begin_round`` resets the
    tree.  Batches must be padded to a fixed ``batch_clients`` width by the
    caller (zero-weight pad slots contribute exactly nothing) so every fold
    hits the same compiled shape.

    ``collect_health`` is STATIC (decided at construction, like the engine's
    recorder activity): when set, the jitted EA folds also return per-batch
    GAMP health sums (iters / converged over live problems) accumulated
    lazily on device -- no extra host sync per batch -- and the AE finalize
    decodes with ``with_info``; :meth:`health` summarizes after finalize.
    """

    def __init__(
        self,
        codec: BQCSCodec,
        mode: str = "ae",
        gamp: Optional[GampConfig] = None,
        stream: StreamConfig = StreamConfig(),
        use_pallas: bool = False,
        recon_chunk: int = 0,
        chan: Optional[ChannelConfig] = None,
        collect_health: bool = False,
    ):
        if mode not in ("ae", "ea"):
            raise ValueError(f"unknown streaming mode {mode!r} (choose 'ae' or 'ea')")
        from repro.core.reconstruction import gamp_config_from

        self.codec = codec
        self.mode = mode
        self.gamp = gamp or gamp_config_from(codec)
        self.stream = stream
        self.tree: Optional[aggregator.AggregatorTree] = None
        fam = get_channel_family(chan.kind) if chan is not None else None
        if fam is not None and not fam.multiple_access:
            raise ValueError(
                "StreamingPS takes chan= only for multiple-access families "
                "(per-client noisy uplinks thread nu_chan/noise_keys instead); "
                f"got {chan.kind!r}"
            )
        if fam is not None and mode != "ae":
            raise ValueError(
                "a multiple-access uplink superimposes the cohort before the "
                "PS can decode, so only joint-estimation 'ae' streaming is "
                f"defined; got mode {mode!r}"
            )

        def fold_ae_ideal(words, alphas, w):
            return aggregator.ae_batch_stats(codec, words, alphas, w)

        def fold_ae_noisy(words, alphas, w, nu_chan, keys):
            # Per-CLIENT noise keys (fold_in of the round noise key by client
            # id), so the draw is invariant to how arrivals batch up.
            m = codec.cfg.m
            nb = alphas.shape[1]
            noise = jax.vmap(lambda k: jax.random.normal(k, (nb, m)))(keys)
            noise = noise * jnp.sqrt(nu_chan)[..., None]
            return aggregator.ae_batch_stats(codec, words, alphas, w, nu_chan, noise)

        def fold_ae_mimo(words, alphas, w, h, h_hat, sigma2, key):
            # One superimposed sub-cohort reception over the round's H,
            # restricted to this batch's columns: the batch pre-scales by its
            # Bussgang weights, transmits simultaneously, and the PS combines
            # the single (n_rx, nb, M) signal into the tier's partial stats.
            m = codec.cfg.m
            deq = codec.codebook.decode_packed(words, m)  # (B, nb, M)
            wq = bussgang.bussgang_weight(w[:, None], alphas, codec.codebook)
            active = (w > 0).astype(jnp.float32)
            eta = mimo_tx_gain(wq, active)  # this batch's power control
            x = (eta * wq)[..., None] * deq
            real = ChannelRealization(
                jnp.zeros(alphas.shape, jnp.float32), active,
                h=h, h_hat=h_hat, sigma2=sigma2,
            )
            y_rx = fam.transmit(chan, real, x, key)
            y_eff, nu = fam.combine(chan, real, y_rx, wq, active,
                                    psi=codec.codebook.psi, tx_gain=eta)
            return aggregator.mimo_batch_stats(codec, y_eff, nu, alphas, w)

        def fold_ea(words, alphas, w):
            # Decode-overlapped-with-ingest: this batch's per-client GAMP
            # problems stream through the recon engine's chunked solver NOW,
            # while later arrivals are still in flight.
            b, nb = alphas.shape
            ghat = ea_solve_flat(
                codec,
                words.reshape((b * nb,) + words.shape[2:]),
                alphas.reshape(b * nb),
                self.gamp,
                packed=True,
                use_pallas=use_pallas,
                chunk=recon_chunk,
                with_info=collect_health,
            )
            if collect_health:
                ghat, ginfo = ghat
                live = (alphas.reshape(b * nb) > 0).astype(jnp.float32)
                aux = {
                    "iters_sum": jnp.sum(ginfo.iters.astype(jnp.float32) * live),
                    "conv_sum": jnp.sum(ginfo.converged.astype(jnp.float32) * live),
                    "iters_max": jnp.max(ginfo.iters.astype(jnp.float32) * live),
                    "live": jnp.sum(live),
                }
                return aggregator.ea_batch_stats(ghat.reshape(b, nb, -1), w), aux
            return aggregator.ea_batch_stats(ghat.reshape(b, nb, -1), w)

        self.collect_health = collect_health
        self._health_acc: Optional[Dict[str, jnp.ndarray]] = None
        self._final_info = None
        self._fold_ae_ideal = jax.jit(fold_ae_ideal)
        self._fold_ae_noisy = jax.jit(fold_ae_noisy)
        self._fold_ae_mimo = jax.jit(fold_ae_mimo) if fam is not None else None
        self.chan = chan
        self._fold_ea = jax.jit(fold_ea)
        self._final = jax.jit(
            lambda stats: decode_from_stats(
                codec, stats, self.gamp,
                use_pallas=use_pallas, with_info=collect_health,
            )
        )

    def begin_round(self, nb: int) -> None:
        width = self.codec.cfg.m if self.mode == "ae" else self.codec.cfg.block_size
        self.tree = aggregator.AggregatorTree(
            aggregator.zero_stats(self.mode, nb, width), fanout=self.stream.fanout
        )
        self._health_acc = None
        self._final_info = None

    def fold_batch(
        self, words, alphas, weights, nu_chan=None, noise_keys=None, mimo=None
    ) -> None:
        """Fold one gathered (padded) sub-cohort batch into the tree.
        ``mimo`` is ``(h, h_hat, sigma2, key)`` -- this batch's columns of the
        round's fading matrix plus the batch's receiver noise key -- for
        multiple-access streaming (requires construction with ``chan=``)."""
        if self.mode == "ea":
            stats = self._fold_ea(words, alphas, weights)
            if self.collect_health:
                stats, aux = stats
                # lazy device-side accumulation: no host sync until health()
                if self._health_acc is None:
                    self._health_acc = dict(aux)
                else:
                    acc = self._health_acc
                    for k in ("iters_sum", "conv_sum", "live"):
                        acc[k] = acc[k] + aux[k]
                    acc["iters_max"] = jnp.maximum(acc["iters_max"], aux["iters_max"])
        elif mimo is not None:
            if self._fold_ae_mimo is None:
                raise ValueError(
                    "multiple-access fold needs a StreamingPS built with chan="
                )
            stats = self._fold_ae_mimo(words, alphas, weights, *mimo)
        elif nu_chan is None:
            stats = self._fold_ae_ideal(words, alphas, weights)
        else:
            stats = self._fold_ae_noisy(words, alphas, weights, nu_chan, noise_keys)
        self.tree.push(stats)

    def finalize(self) -> Tuple[jnp.ndarray, aggregator.PartialStats]:
        """Folds the pending tiers and decodes -> ((nb, N) blocks, root stats).
        An empty round (nothing arrived) short-circuits to the exact zero
        update, the same graceful degradation as the barrier blackout path."""
        root = self.tree.root()
        if float(root.count) == 0:
            nb = root.y.shape[0]
            return jnp.zeros((nb, self.codec.cfg.block_size), jnp.float32), root
        out = self._final(root)
        if self.collect_health:
            out, self._final_info = out  # info is None on the EA path
        return out, root

    def health(self) -> Dict[str, float]:
        """Round decode-health scalars (one host sync; call after finalize).
        EA: GAMP iters/convergence summed over the round's fold batches.
        AE: the finalize decode's GAMP info (the round's single solve)."""
        if not self.collect_health:
            return {}
        if self._final_info is not None:  # ae finalize decode
            return {k: float(v) for k, v in gamp_health(self._final_info).items()}
        if self._health_acc is None:  # ea round with no folds
            return {}
        acc = self._health_acc
        live = max(float(acc["live"]), 1.0)
        return {
            "gamp_iters_mean": float(acc["iters_sum"]) / live,
            "gamp_iters_max": float(acc["iters_max"]),
            "gamp_converged_frac": float(acc["conv_sum"]) / live,
        }


def stream_decode(
    codec: BQCSCodec,
    words: jnp.ndarray,  # (C, nb, W) packed wire words of the whole cohort
    alphas: jnp.ndarray,  # (C, nb)
    weights: np.ndarray,  # (C,) RAW weights (0 = non-participant)
    batches: List[np.ndarray],  # arrival-ordered position batches
    *,
    mode: str = "ae",
    stream: Optional[StreamConfig] = None,
    gamp: Optional[GampConfig] = None,
    nu_chan: Optional[jnp.ndarray] = None,  # (C, nb) channel variance (noisy AE)
    noise_keys: Optional[jnp.ndarray] = None,  # (C,) per-client PRNG keys
    chan: Optional[ChannelConfig] = None,  # multiple-access uplink config
    chan_real: Optional[ChannelRealization] = None,  # its round realization
    chan_key: Optional[jax.Array] = None,  # round receiver-noise key (MAC)
    use_pallas: bool = False,
    recon_chunk: int = 0,
    ps: Optional[StreamingPS] = None,
) -> Tuple[jnp.ndarray, Dict[str, float]]:
    """One streamed round, driven end to end: producers push each arrival
    batch into the bounded buffer (draining one batch first when full --
    backpressure), the consumer folds drained batches into the tree, and the
    round finalizes from the folded root.

    Single-host deterministic simulation of the producer/consumer split; the
    testable unit for fault injection (``batches`` may be reordered,
    duplicated, or partially dropped by the caller).  Returns
    ((nb, N) aggregated blocks, info dict).
    """
    if chan_real is not None and (chan_real.h is None or chan_key is None):
        raise ValueError(
            "multiple-access streaming needs a realization with a fading "
            "matrix and a round receiver-noise key (chan_real=, chan_key=)"
        )
    if ps is None:
        ps = StreamingPS(
            codec, mode, gamp, stream or StreamConfig(),
            use_pallas=use_pallas, recon_chunk=recon_chunk, chan=chan,
        )
    cfg = ps.stream
    w_np = np.asarray(weights, np.float32)
    nb = alphas.shape[1]
    ps.begin_round(nb)
    buf = BoundedIngestBuffer(cfg.buffer_batches)
    consumed = [0]  # admission counter: the MAC batch noise key index
    backpressure = [0]  # forced drains: pushes that found the buffer full

    def consume_one():
        pos, valid = buf.pop()
        w_b = jnp.asarray(w_np[pos] * valid)
        mimo = None
        if chan_real is not None:
            # This batch's columns of the round's H; one fresh receiver
            # noise draw per admitted batch (deterministic in fold order).
            jpos = jnp.asarray(pos)
            mimo = (
                chan_real.h[:, jpos],
                chan_real.h_hat[:, jpos],
                chan_real.sigma2,
                jax.random.fold_in(chan_key, consumed[0]),
            )
        consumed[0] += 1
        ps.fold_batch(
            words[pos],
            alphas[pos],
            w_b,
            None if nu_chan is None else nu_chan[pos],
            None if noise_keys is None else noise_keys[pos],
            mimo=mimo,
        )

    for pos in batches:
        pos = np.asarray(pos, np.int64)
        key = pos.tobytes()  # content identity: a redelivered batch dedups
        pad = cfg.batch_clients - len(pos)
        if pad < 0:
            raise ValueError(
                f"batch of {len(pos)} clients exceeds batch_clients={cfg.batch_clients}"
            )
        valid = np.concatenate([np.ones(len(pos), np.float32), np.zeros(pad, np.float32)])
        padded = np.concatenate([pos, np.full(pad, pos[0] if len(pos) else 0, np.int64)])
        if buf.full:
            backpressure[0] += 1
            consume_one()  # backpressure: bounded ingest memory
        buf.push(key, (padded, valid))
    while len(buf):
        consume_one()

    ghat, root = ps.finalize()
    info = {
        "batches_admitted": buf.admitted,
        "batches_rejected_dup": buf.rejected_dup,
        "batches_backpressure": backpressure[0],
        "buffer_peak_occupancy": buf.peak_occupancy,
        "tree_tiers": len(ps.tree.tiers),
        "peak_live_stats_bytes": ps.tree.peak_live_bytes,
        "participating": float(root.count),
        "weight_sum": float(root.wsum),
    }
    info.update(ps.health())
    return ghat, info
