"""``python -m repro.fed`` — the tiny end-to-end cohort smoke (CI runs this
in the minimal-deps leg: 8 clients, 2 rounds, Dirichlet + AWGN engine path)."""

from repro.fed.engine import _smoke_main

if __name__ == "__main__":
    _smoke_main()
