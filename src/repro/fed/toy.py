"""The shared toy federation: a Gaussian-prototype classification problem +
softmax linear classifier, small enough that per-client compute is
negligible.  One definition serves the engine smoke (``python -m
repro.fed``), the cohort-scaling benchmark (``benchmarks/run.py --only
fed``), and ``tests/test_fed.py`` — so all three exercise the identical
workload.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["toy_classification", "toy_loss", "toy_params"]


def toy_classification(n_samples: int = 512, dim: int = 32, classes: int = 4,
                       noise: float = 0.5, seed: int = 0):
    """Returns (x, y): class-prototype Gaussians with pixel noise."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(0, 1, (classes, dim)).astype(np.float32)
    y = rng.integers(0, classes, n_samples).astype(np.int32)
    x = (protos[y] + rng.normal(0, noise, (n_samples, dim))).astype(np.float32)
    return x, y


def toy_loss(params, batch):
    """Softmax cross-entropy of the linear classifier on an {"x","y"} batch."""
    logp = jax.nn.log_softmax(batch["x"] @ params["w"] + params["b"])
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], axis=1))


def toy_params(dim: int = 32, classes: int = 4, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(0, 0.1, (dim, classes)), jnp.float32),
        "b": jnp.zeros((classes,), jnp.float32),
    }
