"""The cohort round engine (DESIGN.md #Fed-engine).

Runs full FL rounds over *any* model exposed as ``grad_fn(params, batch)`` at
thousands-of-clients scale.  One round is two passes:

  * **client pass** — every cohort member's gradient + BQCS encode, batched
    through ``jax.vmap`` in one device pass (optionally ``lax.scan``-chunked
    so the per-client gradient trees never all materialize at once).  A
    bit-identical Python-loop oracle (``impl="loop"``) dispatches the
    per-client codec path one client at a time — the pre-engine
    ``paper/mlp.py`` dispatch pattern — and is the benchmark baseline.  Both
    impls share the batched gradient pass (the gradient is the model's work,
    and per-client GEMM lowerings are not ulp-deterministic across batch
    shapes on every backend), so loop-vs-vmap equality is exact by
    construction for any model.
  * **PS pass** — reconstruction once per round from the stacked payloads:
    the method dispatch (fedqcs-ae / fedqcs-ea / qcs-qiht / qcs-dither /
    signsgd / none) reuses ``core/reconstruction.py`` + ``core/baselines.py``
    unchanged; the wireless channel's effective noise variance threads into
    ``em_gamp``'s ``noise_var`` next to the Bussgang quantization distortion
    (eq. 24 + channel term).

The quantizer codebook is a scenario axis like the partition or channel:
``FedQCSConfig.codebook`` ("lloyd_max" / "dithered_uniform" / "vq") selects
the wire family for every fedqcs/qiht method, and the PS dispatch picks the
matching channel automatically (exact truncated-posterior cells for scalar
families, the Bussgang-linearized fallback for vq -- DESIGN.md #Codebooks);
``examples/federated_mnist.py --compare`` sweeps EA/AE across the families.

Participation contract (shared with ``runtime/collectives.py``): a cohort
slot with ``rho_k = 0`` — scheduler dropout or channel outage — contributes
exactly zero to the aggregate, and its error-feedback residual carries the
*full* gradient forward (``blocks + residual``), so a straggler's work is
deferred, not lost.  Clients outside the cohort are untouched.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, bussgang
from repro.core.compression import (
    BQCSCodec,
    FedQCSConfig,
    blocks_to_tree,
    packed_width,
)
from repro.core.layout import GradientLayout
from repro.core.gamp import em_gamp, gamp_health
from repro.core.reconstruction import (
    aggregate_and_estimate,
    estimate_and_aggregate_packed,
    gamp_config_from,
)
from repro.obs import NULL_RECORDER
from repro.obs.trace import SUB_PHASES, SpanCollector, span
from repro.fed.channel import (
    CHANNEL_FAMILIES,
    ChannelConfig,
    get_channel_family,
    mimo_tx_gain,
    realize_uplink,
)
from repro.fed.scheduler import SchedulerConfig, SchedulerState, select_cohort
from repro.fed.server_opt import ServerOptConfig, init_server_state, server_update
from repro.fed.stream import (
    StreamConfig,
    StreamingPS,
    batch_arrivals,
    late_discount,
    simulate_arrivals,
    stream_decode,
)

__all__ = [
    "CohortConfig",
    "CohortEngine",
    "ArrayClientData",
    "TokenClientData",
    "make_interleaved_segments",
]

EF_METHODS = ("fedqcs-ae", "fedqcs-ea", "qcs-qiht")
METHODS = EF_METHODS + ("qcs-dither", "signsgd", "none")


@dataclasses.dataclass(frozen=True)
class CohortConfig:
    """Engine-level knobs (protocol knobs live in FedQCSConfig)."""

    method: str = "fedqcs-ae"
    chunk: int = 0  # clients per scan chunk in the vmapped pass; 0 = all at once
    groups: int = 1  # AE grouping (G), ideal channel only
    impl: str = "vmap"  # vmap | loop  (loop = per-client oracle/baseline)
    dither_n: int = 2048  # qcs-dither re-blocking size (power of 2)
    record_nmse: bool = True
    seed: int = 0
    # Block layout of the gradient wire (core/layout.py): "monolithic" (the
    # paper's whole-model flatten, bit-identical to the pre-layout engine) or
    # "per_tensor" (independently padded leaf segments -- the streaming
    # geometry).  An explicit GradientLayout passed to CohortEngine(layout=)
    # wins over this string.
    layout: str = "monolithic"
    # Segment-streamed client encode (per_tensor layouts only): the encode
    # pass consumes the gradient one layout segment at a time, so peak live
    # encoder memory is bounded by the largest segment's blocks instead of
    # the whole model (DESIGN.md #Layout).
    encode_stream: bool = False
    # Microbatch count for the default gradient hook under encode_stream
    # (client batch split into grad_accum equal microbatches, gradients
    # averaged) -- bounds per-client activation memory next to the encoder's
    # segment bound.
    grad_accum: int = 1


# ---------------------------------------------------------------------------
# Client data sources
# ---------------------------------------------------------------------------


class ArrayClientData:
    """Labeled-array federation: clients are index sets (from
    ``fed.partition``) into one (x, y) array pair.  Batches are drawn
    host-side, deterministic in (seed, round, client id) so a client's draw
    does not depend on who else is in the cohort."""

    def __init__(self, x, y, parts: List[np.ndarray], batch_size: int = 1, seed: int = 0):
        self.x, self.y = np.asarray(x), np.asarray(y)
        self.parts = [np.asarray(p, np.int64) for p in parts]
        self.counts = np.array([len(p) for p in self.parts], np.int64)
        if (self.counts == 0).any():
            raise ValueError("every client needs at least one sample")
        self.batch_size = batch_size
        self.seed = seed
        # Padded (K, maxlen) index matrix: one vectorized gather per round.
        maxlen = int(self.counts.max())
        self._idx = np.zeros((len(parts), maxlen), np.int64)
        for k, p in enumerate(self.parts):
            self._idx[k, : len(p)] = p
            self._idx[k, len(p) :] = p[0]  # padding never drawn (pos < len)

    def cohort_batch(self, round_idx: int, ids: np.ndarray) -> Dict[str, jnp.ndarray]:
        # One vectorized draw over ALL K clients, rows indexed by global
        # client id: client k's minibatch is a pure function of
        # (seed, round, k), independent of who else is in the cohort (the
        # 0xDA7A tag keeps this stream disjoint from the scheduler's).
        rng = np.random.default_rng((self.seed, 0xDA7A, round_idx))
        u = rng.random((len(self.counts), self.batch_size))[ids]  # (C, b)
        pos = (u * self.counts[ids][:, None]).astype(np.int64)
        sel = self._idx[ids[:, None], pos]  # (C, b)
        return {"x": jnp.asarray(self.x[sel]), "y": jnp.asarray(self.y[sel])}


class TokenClientData:
    """Synthetic-language federation for the registry models: each client
    holds its own stream of ``data/synthetic.py``-style affine-rule sequences.
    Heterogeneity: clients mix ``n_dialects`` rule variants (the additive
    constant shifts per dialect) with per-client mixture weights drawn from
    Dir(alpha) — alpha -> 0 gives one-dialect clients, alpha -> inf IID."""

    def __init__(
        self,
        vocab_size: int,
        batch: int,
        seq: int,
        clients: int,
        alpha: float = 0.0,  # 0 = homogeneous (no dialect skew)
        n_dialects: int = 10,
        noise: float = 0.2,
        seed: int = 0,
    ):
        self.vocab_size, self.batch, self.seq = vocab_size, batch, seq
        self.noise, self.seed = noise, seed
        self.counts = np.ones(clients, np.int64)
        rng = np.random.default_rng((seed, 0xD1A1))
        if alpha > 0:
            self._p = rng.dirichlet(np.full(n_dialects, alpha), size=clients)
        else:
            self._p = np.full((clients, n_dialects), 1.0 / n_dialects)
        self._make = jax.jit(jax.vmap(self._make_one))

    def _make_one(self, key, p):
        from repro.data.synthetic import affine_rule_batch

        k1, k2, k3, k4 = jax.random.split(key, 4)
        dialect = jax.random.categorical(k4, jnp.log(p + 1e-9), shape=(self.batch, 1))
        # dialect shifts the affine rule's additive constant
        return affine_rule_batch(
            k1, k2, k3, self.batch, self.seq, self.vocab_size, self.noise,
            c=17 + 5 * dialect,
        )

    def cohort_batch(self, round_idx: int, ids: np.ndarray) -> Dict[str, jnp.ndarray]:
        base = jax.random.fold_in(jax.random.PRNGKey(self.seed), round_idx)
        keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.asarray(ids))
        return self._make(keys, jnp.asarray(self._p[ids], jnp.float32))


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class CohortEngine:
    """Stateful driver: owns params, per-client residuals, server-opt and
    scheduler state; each :meth:`run_round` is one federated round.

    ``obs`` is a :class:`repro.obs.MetricsRecorder` (default: the null
    recorder).  Its ``active`` flag is read ONCE here and treated as static:
    an active recorder makes the jitted PS pass return the decode-health
    auxiliaries (GAMP iters/convergence, clip saturation, combiner health)
    and wraps each round phase in a blocking span; the null recorder builds
    the exact pre-telemetry graphs, so it costs nothing (pinned by the
    ``obs`` bench).  Recording itself happens on the host, once per round.
    """

    def __init__(
        self,
        params: Any,
        grad_fn: Callable[[Any, Any], Any],
        data: Any,  # ArrayClientData / TokenClientData duck type
        fed_cfg: Optional[FedQCSConfig] = None,
        cohort: CohortConfig = CohortConfig(),
        sched: SchedulerConfig = SchedulerConfig(),
        chan: ChannelConfig = ChannelConfig(),
        server: ServerOptConfig = ServerOptConfig(),
        stream: Optional[StreamConfig] = None,
        obs: Any = None,
        layout: Optional[GradientLayout] = None,
        grad_segments_fn: Optional[Callable[[Any, Any, GradientLayout], Any]] = None,
    ):
        if cohort.method not in METHODS:
            raise ValueError(f"unknown method {cohort.method!r} (choose from {METHODS})")
        if cohort.layout not in ("monolithic", "per_tensor"):
            raise ValueError(
                f"unknown layout {cohort.layout!r} (choose 'monolithic' or "
                "'per_tensor', or pass an explicit GradientLayout)"
            )
        if cohort.encode_stream and cohort.method not in EF_METHODS:
            raise ValueError(
                "encode_stream drives the BQCS encoder one layout segment at a "
                f"time, which only the error-feedback codec methods {EF_METHODS} "
                f"use; got {cohort.method!r}"
            )
        if cohort.encode_stream and cohort.impl == "loop":
            raise ValueError(
                "encode_stream is a vmapped-encode path; the per-client loop "
                "oracle encodes whole block grids (impl='vmap')"
            )
        if cohort.grad_accum < 1:
            raise ValueError(f"grad_accum must be >= 1, got {cohort.grad_accum}")
        if cohort.grad_accum > 1 and not cohort.encode_stream:
            raise ValueError(
                "grad_accum microbatching is the encode_stream gradient hook's "
                "knob (DESIGN.md #Layout); set encode_stream=True"
            )
        if grad_segments_fn is not None and not cohort.encode_stream:
            raise ValueError(
                "grad_segments_fn feeds the segment-streamed encode "
                "(DESIGN.md #Interleave); set encode_stream=True"
            )
        if stream is not None and cohort.method not in ("fedqcs-ae", "fedqcs-ea"):
            raise ValueError(
                f"streaming rounds fold Bussgang/EA sufficient statistics, which "
                f"only the fedqcs methods produce; got {cohort.method!r}"
            )
        if stream is not None and cohort.groups != 1:
            raise ValueError("streaming fedqcs-ae has no group structure (groups must be 1)")
        # Channel gating is by family TRAITS, not kind strings: the registry
        # (fed/channel.py) is the only place a kind resolves to behavior.
        fam = get_channel_family(chan.kind)
        if not fam.exact_codes and cohort.method != "fedqcs-ae":
            raise ValueError(
                f"method {cohort.method!r} needs the exact codes at the PS, which "
                "only an ideal (error-free digital) uplink provides; noisy "
                "channels are supported by 'fedqcs-ae' (Bussgang + channel "
                "variance into em_gamp noise_var, DESIGN.md #Fed-engine)"
            )
        if cohort.groups != 1 and (cohort.method != "fedqcs-ae" or not fam.exact_codes):
            raise ValueError("groups != 1 is only defined for fedqcs-ae over an ideal uplink")
        self._chan_family = fam
        self.cohort, self.sched, self.chan, self.server = cohort, sched, chan, server
        self.stream = stream
        self.obs = obs if obs is not None else NULL_RECORDER
        self._collect = bool(self.obs.active)  # static: fixes the jitted graphs
        self._spans = SpanCollector() if self._collect else None
        self.fed_cfg = fed_cfg or FedQCSConfig()
        self.grad_fn = grad_fn
        self.data = data
        self.params = params

        # The block layout is hoisted ONCE here and shared by every pass (the
        # constructor used to flatten params for the spec and the vmapped
        # client pass re-derived and discarded it each call).  The layout IS
        # the spec: blocks_to_tree takes it directly.
        n = self.fed_cfg.block_size
        if layout is not None:
            if layout.n != n:
                raise ValueError(
                    f"explicit layout has block size {layout.n}, "
                    f"FedQCSConfig.block_size is {n}"
                )
            self.layout = layout
        elif cohort.layout == "per_tensor":
            self.layout = GradientLayout.per_tensor(params, n)
        else:
            self.layout = GradientLayout.monolithic(params, n)
        if self.layout.kind == "per_tensor" and cohort.method == "qcs-dither":
            raise ValueError(
                "qcs-dither re-blocks the monolithic flat vector; a per-tensor "
                "layout interleaves per-segment padding into that vector, so "
                "its geometry does not apply (use the monolithic layout)"
            )
        if not cohort.encode_stream and any(
            seg.s is not None for seg in self.layout.segments
        ):
            raise ValueError(
                "per-segment sparsity budgets only take effect on the "
                "segment-streamed encode; set encode_stream=True"
            )
        self.spec = self.layout
        self.nbar = self.layout.nbar
        self.nb, self.n = self.layout.rows, n
        self._grad_segments_fn = grad_segments_fn
        self.clients = len(data.counts)
        self.codec = BQCSCodec(self.fed_cfg) if cohort.method in EF_METHODS else None
        self.gamp = gamp_config_from(self.codec) if self.codec else None
        self._dither = (
            baselines.DitherCodec(
                n=cohort.dither_n,
                m=cohort.dither_n // self.fed_cfg.reduction_ratio,
                bits=self.fed_cfg.bits,
            )
            if cohort.method == "qcs-dither"
            else None
        )
        self.residuals = jnp.zeros((self.clients, self.nb, self.n), jnp.float32)
        self.server_state = init_server_state(server, params)
        self.sched_state = SchedulerState.init(self.clients)
        self.round = 0
        self.key = jax.random.PRNGKey(cohort.seed)
        self._grads_jit = jax.jit(self._grad_blocks_fn)
        self._encode_jit = jax.jit(self._encode_fn)  # loop-oracle unit
        # the cohort residual rows arrive as a fresh gather (residuals[jids])
        # consumed only by the encode, so the new residual writes in place
        self._encode_vmap_jit = jax.jit(
            jax.vmap(self._encode_fn), donate_argnums=(1,)
        )
        if cohort.encode_stream:
            # Per-segment units of the streamed client pass: the batched
            # gradient tree (hook default), one segment's (C, rows, N) block
            # view, the vmapped per-segment encode (top-S budget static so a
            # layout's per-segment s values each get their own graph), and
            # the running true-sum fold for nmse bookkeeping.
            self._grads_tree_jit = jax.jit(self._grads_tree_fn)
            self._seg_blocks_jit = jax.jit(
                self.layout.segment_blocks_batched, static_argnums=(1,)
            )
            # segment residual rows are a fresh slice (residuals[:, rows]):
            # donated so each segment's new residual reuses that buffer
            self._encode_seg_jit = jax.jit(
                jax.vmap(self._encode_segment_fn, in_axes=(0, 0, 0, None)),
                static_argnums=(3,),
                donate_argnums=(1,),
            )
            self._seg_true_sum_jit = jax.jit(
                lambda rhos, blocks: jnp.einsum("k,kbn->bn", rhos, blocks)
            )
        self._ps_jit = jax.jit(self._ps_fn)
        self._uplink_jit = jax.jit(
            lambda key, c, nb: realize_uplink(self.chan, key, c, nb),
            static_argnums=(1, 2),
        )
        # per-round prep (effective rhos + per-client keys) in one dispatch
        self._prep_jit = jax.jit(self._prep_fn)
        if stream is not None:
            # One StreamingPS reused across rounds (owns the jitted folds).
            self._stream_ps = StreamingPS(
                self.codec,
                mode="ae" if cohort.method == "fedqcs-ae" else "ea",
                gamp=self.gamp,
                stream=stream,
                use_pallas=self.fed_cfg.use_kernels,
                recon_chunk=self.fed_cfg.recon_chunk,
                chan=self.chan if fam.multiple_access else None,
                collect_health=self._collect,
            )
            self._noise_keys_jit = jax.jit(
                lambda jids, k: jax.vmap(lambda i: jax.random.fold_in(k, i))(jids)
            )
            self._nmse_jit = jax.jit(
                lambda ghat, blocks, rhos: (
                    jnp.sum(jnp.square(ghat - jnp.einsum("k,kbn->bn", rhos, blocks)))
                    / (jnp.sum(jnp.square(jnp.einsum("k,kbn->bn", rhos, blocks))) + 1e-30)
                )
            )
            # encode_stream folds the reference sum during the client pass
            # (payloads carry (nb, N) true_sum, not (C, nb, N) blocks)
            self._nmse_true_jit = jax.jit(
                lambda ghat, ts: jnp.sum(jnp.square(ghat - ts))
                / (jnp.sum(jnp.square(ts)) + 1e-30)
            )
        # blocks -> tree -> server update in one jitted apply (the per-round
        # fixed cost would otherwise be tens of eager dispatches and dominate
        # small cohorts).
        self._apply_jit = jax.jit(
            lambda ghat_blocks, params, sstate, step: server_update(
                self.server,
                blocks_to_tree(ghat_blocks, self.spec, self.nbar),
                sstate,
                params,
                step,
            )
        )
        if self._collect:
            # one fused reduction per round: update + param global l2 norms
            self._norms_jit = jax.jit(
                lambda blocks, params: (
                    jnp.sqrt(jnp.sum(jnp.square(blocks))),
                    jnp.sqrt(
                        sum(
                            jnp.sum(jnp.square(x))
                            for x in jax.tree_util.tree_leaves(params)
                        )
                    ),
                )
            )
            self._sat_jit = (
                jax.jit(self.codec.clip_saturation) if self.codec is not None else None
            )

    def _prep_fn(self, rho0, mask, jids, kr):
        r = rho0 * mask
        total = jnp.sum(r)
        rhos_eff = jnp.where(total > 0, r / jnp.maximum(total, 1e-12), 0.0)
        keys = jax.vmap(lambda i: jax.random.fold_in(kr, i))(jids)
        return rhos_eff, keys

    # -- client side --------------------------------------------------------

    def _grad_blocks_fn(self, params, batch):
        """(C, ...) cohort batch -> (C, nb, N) gradient blocks, one vmapped
        device pass, ``lax.scan``-chunked when ``cohort.chunk`` bounds how
        many per-client gradient trees materialize at once.  Both impls share
        this pass — the gradient is the *model's* work; the engine's claim
        (and the loop oracle) is about the per-client codec path."""
        vm = jax.vmap(
            lambda b: self.layout.to_blocks(self.grad_fn(params, b))
        )
        leaves = jax.tree_util.tree_leaves(batch)
        c = leaves[0].shape[0]
        chunk = self.cohort.chunk
        if chunk <= 0 or chunk >= c:
            return vm(batch)
        nch = -(-c // chunk)
        pad = nch * chunk - c

        def chunked(x):  # padded slots replay client 0; outputs sliced off
            xp = jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)]) if pad else x
            return xp.reshape((nch, chunk) + x.shape[1:])

        _, blocks = jax.lax.scan(
            lambda _, b: (None, vm(b)), None, jax.tree_util.tree_map(chunked, batch)
        )
        return blocks.reshape((nch * chunk, self.nb, self.n))[:c]

    def _grads_tree_fn(self, params, batch):
        """(C, ...) cohort batch -> batched gradient TREE (leaves keep their
        model shapes under a leading client axis) -- the streamed pass slices
        layout segments out of this instead of one monolithic block grid.
        ``cohort.grad_accum`` > 1 splits each client's samples into that many
        microbatches and averages the gradients through a ``lax.scan``, so
        per-client activation memory is bounded alongside the encoder's
        segment bound."""
        vg = jax.vmap(lambda b: self.grad_fn(params, b))
        acc = self.cohort.grad_accum
        if acc <= 1:
            return vg(batch)
        leaves = jax.tree_util.tree_leaves(batch)
        c, bsz = leaves[0].shape[0], leaves[0].shape[1]
        if bsz % acc:
            raise ValueError(
                f"grad_accum={acc} must divide the per-client batch size {bsz}"
            )
        mb = bsz // acc

        def split(x):  # (C, b, ...) -> (acc, C, b/acc, ...)
            return x.reshape((c, acc, mb) + x.shape[2:]).swapaxes(0, 1)

        mbatches = jax.tree_util.tree_map(split, batch)
        first = jax.tree_util.tree_map(lambda x: x[0], mbatches)
        rest = jax.tree_util.tree_map(lambda x: x[1:], mbatches)
        gsum, _ = jax.lax.scan(
            lambda carry, b: (
                jax.tree_util.tree_map(jnp.add, carry, vg(b)),
                None,
            ),
            vg(first),
            rest,
        )
        return jax.tree_util.tree_map(lambda g: g / acc, gsum)

    def _grad_segments(self, params, batch):
        """Segment source for the streamed client pass: yields
        ``(segment index, (C, rows, N) blocks)`` in any order.  The default
        runs one batched gradient pass (grad_accum-microbatched) and slices
        each layout segment out of the gradient tree; a custom
        ``grad_segments_fn(params, batch, layout)`` can instead yield
        segments as the backward pass produces them -- encode of layer L
        overlapping backprop of layer L-1 -- which is the interleave hook the
        LLM-scale pipeline plugs into."""
        if self._grad_segments_fn is not None:
            yield from self._grad_segments_fn(params, batch, self.layout)
            return
        grads = self._grads_tree_jit(params, batch)
        for seg in self.layout.segments:
            yield seg.index, self._seg_blocks_jit(grads, seg.index)

    def _encode_segment_fn(self, blocks, residual, rho, s):
        """One client's codec path for ONE layout segment: (rows, N) blocks
        + matching residual rows -> wire payload rows.  Every codec stage is
        per-block, so the segment outputs concatenate bit-identically to the
        whole-grid encode; ``s`` is the segment's static top-S budget."""
        if self.cohort.method == "fedqcs-ea" or (
            self.cohort.method == "fedqcs-ae" and self.stream is not None
        ):
            words, alpha, enc_res = self.codec.compress_blocks_packed(
                blocks, residual, s=s
            )
            payload = {"words": words, "alpha": alpha}
        else:  # fedqcs-ae / qcs-qiht barrier rounds consume the index view
            codes, alpha, enc_res = self.codec.compress_blocks(blocks, residual, s=s)
            payload = {"codes": codes, "alpha": alpha}
        new_res = jnp.where(rho > 0, enc_res, blocks + residual)
        return payload, new_res

    def _client_pass_streamed(self, params, batch, residuals, rhos, rhos_nmse):
        """Segment-streamed client pass (``cohort.encode_stream``): consumes
        the gradient one layout segment at a time, so the encoder's live
        block state is one segment's ``(C, rows, N)`` -- bounded by the
        largest segment -- never the whole ``(C, nb, N)`` grid.  Wire output
        is bit-identical to the one-pass encode (pinned by test).  nmse
        bookkeeping folds into a running ``(nb, N)`` true_sum instead of
        carrying every client's full blocks to the PS."""
        nseg = len(self.layout.segments)
        pay: List[Any] = [None] * nseg
        res: List[Any] = [None] * nseg
        tsum: List[Any] = [None] * nseg
        seg_s = self.layout.segment_s(self.fed_cfg.s)
        # Spans here are host wall-clock around ASYNC dispatch: "backward" is
        # the time the producer spends inside next() (for an interleaved
        # grad_segments_fn, one stage's VJP dispatch), "encode_overlap" the
        # encode dispatch riding on top of it -- the overlap the interleave
        # buys shows up as encode_overlap << a blocking encode would be.
        it = self._grad_segments(params, batch)
        while True:
            with span("backward", self._spans):
                nxt = next(it, None)
            if nxt is None:
                break
            idx, seg_blocks = nxt
            if not 0 <= idx < nseg:
                raise ValueError(
                    f"grad_segments_fn yielded segment index {idx}, layout "
                    f"has {nseg} segments"
                )
            if pay[idx] is not None:
                raise ValueError(
                    f"grad_segments_fn yielded segment {idx} "
                    f"({self.layout.segments[idx].name!r}) twice -- a second "
                    "payload would silently drop the first from the wire"
                )
            seg = self.layout.segments[idx]
            with span("encode_overlap", self._spans):
                pay[idx], res[idx] = self._encode_seg_jit(
                    seg_blocks, residuals[:, seg.row_slice], rhos, seg_s[idx]
                )
                if self.cohort.record_nmse:
                    tsum[idx] = self._seg_true_sum_jit(rhos_nmse, seg_blocks)
        missing = [i for i, p in enumerate(pay) if p is None]
        if missing:
            raise ValueError(f"grad_segments_fn never yielded segments {missing}")
        payloads = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=1), *pay
        )
        new_res = jnp.concatenate(res, axis=1)
        if self.cohort.record_nmse:
            payloads = dict(payloads, true_sum=jnp.concatenate(tsum, axis=0))
        return payloads, new_res

    def _encode_fn(self, blocks, residual, rho, key):
        """One client's codec path: (nb, N) blocks -> method payload.

        ``rho`` is the client's effective weight (0 = dropped/outage: the
        error-feedback residual then absorbs the full carry so nothing is
        lost).  ``key`` seeds per-client randomness (dither)."""
        payload: Dict[str, jnp.ndarray] = {}
        method = self.cohort.method
        if method == "fedqcs-ea" or (method == "fedqcs-ae" and self.stream is not None):
            # EA -- and every streaming round -- consumes the wire words
            # directly (packed reconstruction engine / streaming ingest,
            # DESIGN.md #Recon-engine, #Streaming-PS): the payload carries
            # what crosses the wire and the uint8 index view never
            # materializes.
            words, alpha, enc_res = self.codec.compress_blocks_packed(blocks, residual)
            payload["words"], payload["alpha"] = words, alpha
            new_res = jnp.where(rho > 0, enc_res, blocks + residual)
        elif method in EF_METHODS:
            codes, alpha, enc_res = self.codec.compress_blocks(blocks, residual)
            payload["codes"], payload["alpha"] = codes, alpha
            new_res = jnp.where(rho > 0, enc_res, blocks + residual)
        elif method == "qcs-dither":
            dn = self.cohort.dither_n
            nb2 = -(-self.nbar // dn)
            flat = blocks.reshape(-1)[: self.nbar]
            carry = jnp.pad(flat, (0, nb2 * dn - self.nbar)).reshape(nb2, dn)
            q, delta, dith = self._dither.compress(carry, key)
            recon = self._dither.reconstruct(q, delta, dith).reshape(-1)[: self.nbar]
            payload["recon"] = jnp.pad(
                recon, (0, self.nb * self.n - self.nbar)
            ).reshape(self.nb, self.n)
            new_res = residual
        elif method == "signsgd":
            payload["signs"] = baselines.signsgd_compress(blocks)
            new_res = residual
        else:  # none
            new_res = residual
        return payload, new_res

    def _client_pass(self, params, batch, residuals, rhos, keys, rhos_nmse=None):
        """Gradients (always batched) + encode (vmapped, or the per-client
        Python-loop oracle).  The two impls are bit-identical: they share the
        gradient pass, and the per-client encode touches only its own row.
        ``rhos_nmse`` is the normalized weighting the nmse reference uses
        when it differs from ``rhos`` (streaming rounds pass raw weights)."""
        if self.cohort.encode_stream:
            return self._client_pass_streamed(
                params, batch, residuals, rhos,
                rhos_nmse if rhos_nmse is not None else rhos,
            )
        blocks = self._grads_jit(params, batch)
        if self.cohort.impl == "loop":
            outs = [
                self._encode_jit(blocks[i], residuals[i], rhos[i], keys[i])
                for i in range(int(rhos.shape[0]))
            ]
            payloads = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *[o[0] for o in outs]
            )
            new_res = jnp.stack([o[1] for o in outs])
        else:
            payloads, new_res = self._encode_vmap_jit(blocks, residuals, rhos, keys)
        method = self.cohort.method
        if self.cohort.record_nmse or method in ("none", "signsgd"):
            payloads = dict(payloads, blocks=blocks)
        return payloads, new_res

    # -- PS side ------------------------------------------------------------

    def _ps_fn(self, payloads, rhos_eff, chan, key):
        """Reconstruction once per round from the stacked cohort payloads.
        ``chan`` is the round's full ChannelRealization; for fedqcs-ae over a
        per-client noisy uplink its effective variance threads into em_gamp's
        noise_var next to the Bussgang term and the received measurements get
        a matching noise draw (faithful simulation, not just a variance
        hint); over a multiple-access uplink the PS sees only the
        superimposed ``fam.transmit`` output and joint-estimates the
        aggregate through ``fam.combine``."""
        method = self.cohort.method
        stats: Dict[str, jnp.ndarray] = {}
        if self._collect and self.codec is not None:
            # quantizer clip-saturation rate off the wire payload (scalar
            # families; vq reports 0 -- see BQCSCodec.clip_saturation)
            if "words" in payloads:
                stats["clip_saturation"] = self.codec.clip_saturation(payloads["words"])
            elif "codes" in payloads:
                stats["clip_saturation"] = self.codec.clip_saturation(
                    payloads["codes"], packed=False
                )
        true_sum = None
        if "true_sum" in payloads:  # encode_stream folded it per segment
            true_sum = payloads["true_sum"]
        elif "blocks" in payloads:
            true_sum = jnp.einsum("k,kbn->bn", rhos_eff, payloads["blocks"])
        if method == "none":
            ghat = true_sum
        elif method == "signsgd":
            # unweighted majority vote (the baseline's defining semantics);
            # rho_k = 0 clients abstain (their signs are zeroed out)
            alive = (rhos_eff > 0).astype(jnp.int8)[:, None, None]
            scale = jnp.mean(jnp.abs(true_sum))
            ghat = baselines.signsgd_aggregate(payloads["signs"] * alive, lr_scale=scale)
        elif method == "qcs-dither":
            ghat = jnp.einsum("k,kbn->bn", rhos_eff, payloads["recon"])
        elif method == "qcs-qiht":
            codes, alphas = payloads["codes"], payloads["alpha"]
            c, nb, m = codes.shape
            parts = baselines.qiht_reconstruct(
                codes.reshape(c * nb, m), alphas.reshape(-1),
                self.codec.a, self.codec.codebook, self.fed_cfg.s,
            )
            ghat = jnp.einsum("k,kbn->bn", rhos_eff, parts.reshape(c, nb, -1))
        elif method == "fedqcs-ea":
            # Packed-domain chunked EA decode (words straight from the client
            # pass; chunking per FedQCSConfig.recon_chunk).
            ghat = estimate_and_aggregate_packed(
                self.codec, payloads["words"], payloads["alpha"], rhos_eff,
                self.gamp, with_info=self._collect,
            )
            if self._collect:
                ghat, ginfo = ghat
                stats.update(gamp_health(ginfo, live=payloads["alpha"] > 0))
        else:  # fedqcs-ae
            codes, alphas = payloads["codes"], payloads["alpha"]
            q = self.codec.codebook
            fam = self._chan_family
            nu_q = bussgang.effective_noise_var(alphas, rhos_eff, q)
            stats["nu_quant"] = jnp.mean(nu_q)
            if fam.exact_codes:
                stats["nu_channel"] = jnp.zeros(())
                ghat = aggregate_and_estimate(
                    self.codec, codes, alphas, rhos_eff,
                    groups=self.cohort.groups, gamp=self.gamp,
                    with_info=self._collect,
                )
                if self._collect:
                    ghat, ginfo = ghat
                    stats.update(gamp_health(ginfo))
            else:
                m = self.fed_cfg.m
                deq = self.codec.dequantize(codes)  # (C, nb, M)
                w = bussgang.bussgang_weight(rhos_eff[:, None], alphas, q)  # (C, nb)
                if fam.multiple_access:
                    # Over-the-air joint estimation: every client pre-scales
                    # by its Bussgang weight (rho is PS-broadcast, alpha is
                    # client-local -- no per-client side channel) times the
                    # round's broadcast power-control scalar (mimo_tx_gain:
                    # unit average power on the air) and transmits
                    # SIMULTANEOUSLY; non-participants carry w = 0 rows.
                    # The PS spatially combines the one superimposed
                    # reception into the aggregate observation + its
                    # effective noise.
                    active = (rhos_eff > 0).astype(jnp.float32)
                    eta = mimo_tx_gain(w, active)
                    x = (eta * w)[..., None] * deq  # (C, nb, M) transmit rows
                    y_rx = fam.transmit(self.chan, chan, x, key)
                    if self._collect:
                        # combiner-health aux (CSI mismatch, ||f||^2) is part
                        # of the combine hook protocol -- no kind dispatch
                        y, nu_ch, ch_aux = fam.combine(
                            self.chan, chan, y_rx, w, active,
                            psi=q.psi, tx_gain=eta, with_aux=True,
                        )
                        stats.update(ch_aux)
                    else:
                        y, nu_ch = fam.combine(self.chan, chan, y_rx, w, active,
                                               psi=q.psi, tx_gain=eta)
                else:
                    # Per-client reception: equalized rows + their effective
                    # variance, Bussgang-combined at the PS (eq. 23/24 +
                    # channel term).
                    nu_chan = fam.effective_noise(chan)
                    y_rx = fam.transmit(self.chan, chan, deq, key)
                    y = jnp.sum(w[..., None] * y_rx, axis=0)
                    nu_ch = jnp.sum(jnp.square(w) * nu_chan, axis=0)  # (nb,)
                stats["nu_channel"] = jnp.mean(nu_ch)
                energy = bussgang.signal_energy(alphas, rhos_eff, m, self.n)
                ghat = em_gamp(
                    y, nu_q + nu_ch, self.codec.a, self.gamp,
                    init_var=energy, use_pallas=self.fed_cfg.use_kernels,
                    with_info=self._collect,
                )
                if self._collect:
                    ghat, ginfo = ghat
                    stats.update(gamp_health(ginfo))
        if self.cohort.record_nmse and true_sum is not None and method != "none":
            num = jnp.sum(jnp.square(ghat - true_sum))
            den = jnp.sum(jnp.square(true_sum)) + 1e-30
            stats["nmse"] = num / den
        return ghat, stats

    # -- round loop ---------------------------------------------------------

    def _staleness(self, prev_sched, ids, t) -> np.ndarray:
        """Cohort staleness at selection time: rounds since each member's
        last successful participation (0 for never-participated), mirroring
        the async scheduler's discount input."""
        last = prev_sched.last_round[ids]
        return np.where(last < 0, 0, t - 1 - last)

    def _wire_up_bytes(self, participating: float):
        """Uplink wire cost this round: what the participants' payloads cost
        on the canonical wire (packed words + one f32 alpha per block for the
        fedqcs/qiht families, 1 bit/entry for signsgd; None where the method
        has no defined wire format)."""
        method = self.cohort.method
        if self.codec is not None and method in EF_METHODS:
            q = self.codec.codebook
            w = packed_width(q.n_codes(self.fed_cfg.m), q.bits)
            return participating * self.nb * (w * 32 + 32) / 8.0
        if method == "signsgd":
            return participating * self.nb * self.n / 8.0
        return None

    def _record_round(self, t, out, staleness, ghat_blocks) -> None:
        """Assembles and records the round event (host side, once per round).
        The event is a superset of the returned stats dict: envelope fields
        come from the recorder, wire/norm/staleness/phase timings here."""
        event: Dict[str, Any] = dict(out)
        event["round"] = t
        event["staleness_mean"] = float(np.mean(staleness)) if len(staleness) else 0.0
        wire = self._wire_up_bytes(out["participating"])
        if wire is not None:
            event["wire_up_bytes"] = wire
            if self.codec is not None and len(self.layout.segments) > 1:
                # per-tensor wire accounting: each layout segment's share of
                # the uplink (rows scale the same packed-words-per-row cost;
                # pad rows are wire overhead the monolithic layout wouldn't
                # pay, so they're itemized per segment)
                q = self.codec.codebook
                w = packed_width(q.n_codes(self.fed_cfg.m), q.bits)
                event["wire_segments"] = [
                    {
                        "name": seg.name,
                        "rows": seg.rows,
                        "pad": seg.pad,
                        "bytes": out["participating"] * seg.rows * (w * 32 + 32) / 8.0,
                    }
                    for seg in self.layout.segments
                ]
        # model broadcast: every cohort member pulls the nbar f32 params
        event["wire_down_bytes"] = float(out["cohort"]) * self.nbar * 4.0
        un, pn = self._norms_jit(ghat_blocks, self.params)
        event["update_norm"], event["param_norm"] = float(un), float(pn)
        phase = self._spans.drain()
        event["phase_ms"] = phase
        # backward/encode_overlap nest inside client_pass: don't double-count
        event["round_ms"] = sum(
            v for k, v in phase.items() if k not in SUB_PHASES
        )
        self.obs.record("round", event)

    def run_round(self) -> Dict[str, float]:
        """One federated round; advances params/residuals/server state and
        returns the round's stats (python floats)."""
        if self.stream is not None:
            return self._run_round_streaming()
        t = self.round
        prev_sched = self.sched_state
        ids, rho0, new_sched = select_cohort(
            self.sched, prev_sched, t, self.data.counts
        )
        stale = self._staleness(prev_sched, ids, t) if self._collect else ()
        kr = jax.random.fold_in(self.key, t)
        k_chan, k_noise = jax.random.split(kr)
        with span("uplink", self._spans):
            chan = self._uplink_jit(k_chan, len(ids), self.nb)
            if self._collect:
                jax.block_until_ready(chan)
        # Channel outage is a failed participation: un-stamp those clients so
        # the async staleness discount sees their true last *successful*
        # round (their residual carries the full gradient meanwhile).
        dead = ids[np.asarray(chan.mask) == 0]
        if len(dead):
            new_sched.last_round[dead] = prev_sched.last_round[dead]
        self.sched_state = new_sched
        jids = jnp.asarray(ids)
        rhos_eff, keys = self._prep_jit(jnp.asarray(rho0), chan.mask, jids, kr)

        with span("client_pass", self._spans):
            batch = self.data.cohort_batch(t, ids)
            res_c = self.residuals[jids]
            payloads, new_res = self._client_pass(
                self.params, batch, res_c, rhos_eff, keys
            )
            if self._collect:
                jax.block_until_ready(payloads)
        with span("decode", self._spans):
            ghat_blocks, stats = self._ps_jit(payloads, rhos_eff, chan, k_noise)
            if self._collect:
                jax.block_until_ready(ghat_blocks)

        with span("apply", self._spans):
            self.residuals = self.residuals.at[jids].set(new_res)
            self.params, self.server_state = self._apply_jit(
                ghat_blocks, self.params, self.server_state, t
            )
            if self._collect:
                jax.block_until_ready(self.params)
        self.round = t + 1
        out = {k: float(v) for k, v in stats.items()}
        out["cohort"] = len(ids)
        out["participating"] = float(jnp.sum(rhos_eff > 0))
        if self._collect:
            self._record_round(t, out, stale, ghat_blocks)
        return out

    def _run_round_streaming(self) -> Dict[str, float]:
        """Streaming round mode (DESIGN.md #Streaming-PS): same client pass,
        but the PS folds arrival-ordered sub-cohort payload batches through
        the bounded ingest buffer into partial sufficient statistics instead
        of one barrier decode.  Missed-deadline clients are non-participants:
        weight 0 (full residual carry) and un-stamped, exactly like channel
        outage."""
        t = self.round
        prev_sched = self.sched_state
        ids, rho0, new_sched = select_cohort(
            self.sched, prev_sched, t, self.data.counts
        )
        stale = self._staleness(prev_sched, ids, t) if self._collect else ()
        kr = jax.random.fold_in(self.key, t)
        k_chan, k_noise = jax.random.split(kr)
        with span("uplink", self._spans):
            chan = self._uplink_jit(k_chan, len(ids), self.nb)
            if self._collect:
                jax.block_until_ready(chan)
        mask = np.asarray(chan.mask)
        alive = (np.asarray(rho0) > 0) & (mask > 0)
        times = simulate_arrivals(self.stream, t, len(ids), alive)
        arrived = times <= self.stream.deadline
        # Raw (unnormalized) weights: scheduler rho x channel mask x arrival
        # x lateness discount; normalization happens at finalize (1/W).
        w_raw = (
            np.asarray(rho0, np.float64) * mask * arrived * late_discount(self.stream, times)
        ).astype(np.float32)
        # Channel outage OR missed deadline = failed participation: un-stamp.
        dead = ids[(mask == 0) | ~arrived]
        if len(dead):
            new_sched.last_round[dead] = prev_sched.last_round[dead]
        self.sched_state = new_sched
        jids = jnp.asarray(ids)
        jw = jnp.asarray(w_raw)
        # _prep_fn's normalization of the raw weights is exactly the nmse
        # reference weighting; the mask is already folded into w_raw.
        rhos_eff, keys = self._prep_jit(jw, jnp.ones_like(jw), jids, kr)

        with span("client_pass", self._spans):
            batch = self.data.cohort_batch(t, ids)
            res_c = self.residuals[jids]
            payloads, new_res = self._client_pass(
                self.params, batch, res_c, jw, keys, rhos_nmse=rhos_eff
            )
            if self._collect:
                jax.block_until_ready(payloads)

        fam = self._chan_family
        nu_chan = noise_keys = chan_real = chan_key = None
        if fam.multiple_access:
            # Each arrival batch is one superimposed sub-cohort reception
            # over this round's H (the aggregator-tree tiers fold exactly
            # that); the receiver noise key is per admitted batch.
            chan_real, chan_key = chan, k_noise
        elif not fam.exact_codes:
            nu_chan = fam.effective_noise(chan)
            noise_keys = self._noise_keys_jit(jids, k_noise)
        batches = batch_arrivals(times, self.stream.deadline, self.stream.batch_clients)
        with span("fold", self._spans):
            ghat_blocks, sinfo = stream_decode(
                self.codec, payloads["words"], payloads["alpha"], w_raw, batches,
                nu_chan=nu_chan, noise_keys=noise_keys,
                chan_real=chan_real, chan_key=chan_key, ps=self._stream_ps,
            )
            if self._collect:
                jax.block_until_ready(ghat_blocks)

        with span("apply", self._spans):
            self.residuals = self.residuals.at[jids].set(new_res)
            self.params, self.server_state = self._apply_jit(
                ghat_blocks, self.params, self.server_state, t
            )
            if self._collect:
                jax.block_until_ready(self.params)
        self.round = t + 1
        out = {
            k: float(v)
            for k, v in sinfo.items()
            if k not in ("participating",)  # recomputed below for parity
        }
        if self.cohort.record_nmse:
            if "true_sum" in payloads:
                out["nmse"] = float(
                    self._nmse_true_jit(ghat_blocks, payloads["true_sum"])
                )
            else:
                out["nmse"] = float(
                    self._nmse_jit(ghat_blocks, payloads["blocks"], rhos_eff)
                )
        out["cohort"] = len(ids)
        out["participating"] = float(np.sum(w_raw > 0))
        out["arrived"] = float(np.sum(arrived))
        if self._collect:
            if self._sat_jit is not None:
                out["clip_saturation"] = float(self._sat_jit(payloads["words"]))
            self._record_round(t, out, stale, ghat_blocks)
        return out

    def run(self, rounds: int) -> List[Dict[str, float]]:
        return [self.run_round() for _ in range(rounds)]


# ---------------------------------------------------------------------------
# Interleaved producer factory
# ---------------------------------------------------------------------------


def make_interleaved_segments(
    model_cfg: Any,
    layout: GradientLayout,
    grad_accum: int = 1,
    layer_chunks: int = 1,
):
    """``grad_segments_fn`` that interleaves encode with backprop
    (DESIGN.md #Interleave): yields each layout segment's ``(C, rows, N)``
    blocks as the corresponding layer cotangents are produced -- backward
    order -- so encode of layer L dispatches while L-1 backprops and the
    full gradient pytree never materializes.  Works for every staged
    registry family (transformer/moe/vlm/ssm/hybrid); build ``layout``
    with :func:`repro.models.segment_tap.interleaved_layout` (same
    ``layer_chunks``) and pass BOTH it and the returned producer to
    :class:`CohortEngine` with ``encode_stream=True``.  ``grad_accum``
    must mirror ``CohortConfig.grad_accum`` -- the producer microbatches
    each stage exactly like the one-pass tree fn.  The returned object
    also exposes ``grads_fn``/``peak_live_grad_bytes`` (the bit-identity
    oracle and the live-bytes bound the interleave bench records)."""
    from repro.models.segment_tap import InterleavedSegments

    return InterleavedSegments(
        model_cfg, layout, grad_accum=grad_accum, layer_chunks=layer_chunks
    )


# ---------------------------------------------------------------------------
# Smoke entry point (CI minimal-deps leg): a tiny synthetic cohort end to end.
#     PYTHONPATH=src python -m repro.fed.engine --clients 8 --rounds 2
# ---------------------------------------------------------------------------


def _smoke_main(argv=None):
    import argparse

    from repro.fed.partition import PartitionConfig, partition_indices
    from repro.fed.toy import toy_classification, toy_loss, toy_params

    ap = argparse.ArgumentParser(description="cohort engine smoke")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--sample-frac", type=float, default=1.0)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--snr-db", type=float, default=None)
    ap.add_argument(
        "--channel", default=None, choices=sorted(CHANNEL_FAMILIES),
        help="uplink family (default: awgn when --snr-db is set, else ideal)",
    )
    ap.add_argument("--n-rx", type=int, default=8, help="mimo_mac receive antennas")
    ap.add_argument("--csi-error", type=float, default=0.0,
                    help="mimo_mac CSI estimate error variance")
    ap.add_argument("--method", default="fedqcs-ae", choices=METHODS)
    ap.add_argument("--chunk", type=int, default=0)
    ap.add_argument(
        "--layout", default="monolithic", choices=("monolithic", "per_tensor"),
        help="gradient block layout (per_tensor = independently padded leaf segments)",
    )
    ap.add_argument(
        "--encode-stream", action="store_true",
        help="stream the client encode one layout segment at a time",
    )
    ap.add_argument(
        "--grad-accum", type=int, default=1,
        help="microbatches for the encode-stream gradient hook",
    )
    ap.add_argument(
        "--stream", type=int, default=0, metavar="BATCH",
        help="streaming PS mode: sub-cohort ingest batch size (0 = barrier round)",
    )
    ap.add_argument("--deadline", type=float, default=8.0)
    ap.add_argument(
        "--record", default=None, metavar="RUN_DIR",
        help="write events.jsonl + meta.json to this run dir (repro.obs)",
    )
    args = ap.parse_args(argv)

    recorder = None
    if args.record:
        from repro.obs import JsonlRecorder

        recorder = JsonlRecorder(args.record, config=vars(args))

    x, y = toy_classification()
    parts = partition_indices(
        y, args.clients, PartitionConfig(kind="dirichlet", alpha=args.alpha, min_size=4)
    )
    engine = CohortEngine(
        toy_params(),
        jax.grad(toy_loss),
        ArrayClientData(x, y, parts, batch_size=4),
        fed_cfg=FedQCSConfig(block_size=64, reduction_ratio=2, bits=3, gamp_iters=10),
        cohort=CohortConfig(
            method=args.method, chunk=args.chunk, layout=args.layout,
            encode_stream=args.encode_stream, grad_accum=args.grad_accum,
        ),
        sched=SchedulerConfig(
            kind="uniform" if args.sample_frac < 1.0 else "full",
            sample_frac=args.sample_frac,
        ),
        chan=ChannelConfig(
            kind=args.channel
            or ("awgn" if args.snr_db is not None else "ideal"),
            snr_db=args.snr_db if args.snr_db is not None else 20.0,
            n_rx=args.n_rx,
            csi_error=args.csi_error,
        ),
        server=ServerOptConfig(kind="fedadam", lr=0.01),
        stream=StreamConfig(batch_clients=args.stream, deadline=args.deadline)
        if args.stream > 0
        else None,
        obs=recorder,
    )
    for i, stats in enumerate(engine.run(args.rounds)):
        print("round", i, stats)
        assert all(np.isfinite(v) for v in stats.values()), stats
    if recorder is not None:
        recorder.close()
        print("recorded:", recorder.run_dir)
    print("smoke ok:", args.clients, "clients,", args.rounds, "rounds")


if __name__ == "__main__":
    _smoke_main()
