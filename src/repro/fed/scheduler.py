"""Client participation schedulers (DESIGN.md #Fed-engine).

A scheduler decides, per round, *which* clients compute and with *what*
aggregation weight — it is the sole producer of the ``rho_k`` vector the
reconstruction stack already consumes (``core/reconstruction.py``,
``runtime/collectives.py``): a scheduled-but-dropped client keeps its cohort
slot with ``rho_k = 0``, so stragglers degrade gradient quality instead of
changing any array shape (the same contract as pod failure in the
collectives).

Kinds:

  * ``full``     — every client, every round (the paper's Sec. VI setting).
  * ``uniform``  — ``ceil(sample_frac * K)`` clients drawn uniformly without
    replacement (FedAvg-style partial participation).
  * ``async``    — uniform sampling, but each selected client's weight is
    discounted by its staleness (rounds since it last participated) with the
    standard polynomial discount ``(1 + staleness) ** -staleness_decay``:
    clients returning after a long gap push a stale pseudo-gradient, so the
    server trusts them less.

Straggler/dropout model: after selection, each cohort member independently
fails with ``dropout_prob``.  Dropped members stay in the cohort arrays with
``rho_k = 0``; the engine then carries their *full* gradient forward in the
error-feedback residual (nothing of a straggler's work is lost — see
``engine.py`` and the matching collectives behavior).

Weights are data-size proportional (``rho_k ∝ |D_k|``, the paper's Sec. II
weighting) before the staleness discount, and renormalized to sum to 1 over
the surviving cohort.  All host-side numpy, deterministic in (seed, round).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["SchedulerConfig", "SchedulerState", "select_cohort", "staleness_discount"]


def staleness_discount(staleness: np.ndarray, decay: float) -> np.ndarray:
    """Polynomial trust discount ``(1 + staleness) ** -decay``.

    Shared between the async scheduler (staleness = rounds since last
    participation) and the streaming PS (staleness = soft-deadline overrun of
    a late arrival, see ``fed/stream.py``): both are "older information gets
    down-weighted" with the same knee.  Monotone non-increasing in staleness,
    identity at staleness 0 or decay 0; negative staleness clips to 0.
    """
    return (1.0 + np.maximum(np.asarray(staleness, np.float64), 0.0)) ** (-decay)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    kind: str = "full"  # full | uniform | async
    sample_frac: float = 1.0  # cohort fraction for uniform/async
    dropout_prob: float = 0.0  # per-round straggler probability
    staleness_decay: float = 0.5  # async polynomial discount exponent
    seed: int = 0


@dataclasses.dataclass
class SchedulerState:
    """``last_round[k]`` = round of client k's last successful participation
    (-1 = never).  Only the async scheduler reads it; all kinds update it."""

    last_round: np.ndarray

    @classmethod
    def init(cls, clients: int) -> "SchedulerState":
        return cls(last_round=np.full(clients, -1, np.int64))


def select_cohort(
    cfg: SchedulerConfig,
    state: SchedulerState,
    round_idx: int,
    counts: np.ndarray,  # (K,) per-client sample counts (rho ∝ counts)
) -> Tuple[np.ndarray, np.ndarray, SchedulerState]:
    """Returns (cohort client ids (C,), rhos (C,) summing to 1 (or all zero if
    the whole cohort dropped), updated state)."""
    k = len(counts)
    # 0x5EED namespaces this stream away from the data-sampling rng, which
    # may share the same user-facing seed (see ArrayClientData).
    rng = np.random.default_rng((cfg.seed, 0x5EED, round_idx))
    if cfg.kind == "full":
        ids = np.arange(k)
    elif cfg.kind in ("uniform", "async"):
        c = max(1, int(np.ceil(cfg.sample_frac * k)))
        ids = np.sort(rng.choice(k, size=min(c, k), replace=False))
    else:
        raise ValueError(f"unknown scheduler kind {cfg.kind!r}")

    alive = (
        rng.random(len(ids)) >= cfg.dropout_prob
        if cfg.dropout_prob > 0
        else np.ones(len(ids), bool)
    )
    w = np.asarray(counts, np.float64)[ids] * alive
    if cfg.kind == "async" and cfg.staleness_decay > 0:
        staleness = np.where(
            state.last_round[ids] < 0, 0, round_idx - 1 - state.last_round[ids]
        ).clip(min=0)
        w = w * staleness_discount(staleness, cfg.staleness_decay)
    total = w.sum()
    rhos = (w / total if total > 0 else w).astype(np.float32)

    new_state = SchedulerState(last_round=state.last_round.copy())
    new_state.last_round[ids[alive]] = round_idx
    return ids, rhos, new_state
