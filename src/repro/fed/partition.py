"""Client data partitioners (DESIGN.md #Fed-engine).

All partitioners map a label vector to per-client *index* arrays into the
underlying dataset — the data itself is never copied or reordered, so one
60k-sample MNIST array serves a 10,000-client federation.  Everything is
host-side numpy, deterministic in ``PartitionConfig.seed``.

Schemes:

  * ``iid``        — a random equal split (the homogeneous control).
  * ``shard``      — sort-by-label, cut into ``clients * shards_per_client``
    contiguous shards, deal ``shards_per_client`` to each client (McMahan et
    al.'s pathological non-IID split; ``shards_per_client=1`` gives every
    client a single label range).
  * ``dirichlet``  — per class c, draw p_c ~ Dir(alpha * 1_K) and deal that
    class's samples to clients by p_c (Hsu et al.); ``alpha -> 0`` is
    one-class clients, ``alpha -> inf`` recovers IID.  Clients that end up
    below ``min_size`` steal from the largest client so every client can
    draw a batch.
  * ``paper``      — the source paper's Sec. VI split: client k holds
    ``per_client`` samples, all labeled ``floor(k * n_classes / clients)``
    (the one-digit-per-device federation, generalized to any K).
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

__all__ = ["PartitionConfig", "partition_indices", "partition_stats"]


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    kind: str = "iid"  # iid | shard | dirichlet | paper
    alpha: float = 0.3  # dirichlet concentration
    shards_per_client: int = 2  # label-shard scheme
    per_client: int = 1000  # paper scheme sample cap per client
    min_size: int = 1  # dirichlet floor (so every client can draw a batch)
    seed: int = 0


def _iid(n: int, clients: int, rng: np.random.Generator) -> List[np.ndarray]:
    perm = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(perm, clients)]


def _shard(labels: np.ndarray, clients: int, per: int, rng: np.random.Generator):
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, clients * per)
    deal = rng.permutation(clients * per)
    return [
        np.sort(np.concatenate([shards[s] for s in deal[k * per : (k + 1) * per]]))
        for k in range(clients)
    ]


def _dirichlet(
    labels: np.ndarray, clients: int, alpha: float, min_size: int, rng: np.random.Generator
) -> List[np.ndarray]:
    classes = np.unique(labels)
    buckets: List[List[np.ndarray]] = [[] for _ in range(clients)]
    for c in classes:
        idx = rng.permutation(np.nonzero(labels == c)[0])
        p = rng.dirichlet(np.full(clients, alpha))
        # proportions -> contiguous cut points over this class's samples
        cuts = (np.cumsum(p) * len(idx)).astype(np.int64)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            buckets[k].append(part)
    parts = [np.sort(np.concatenate(b)) if b else np.empty(0, np.int64) for b in buckets]
    # Rebalance starved clients: move samples from the largest client until
    # every client holds >= min_size (bounded: at most clients iterations).
    for k in range(clients):
        while len(parts[k]) < min_size:
            donor = int(np.argmax([len(p) for p in parts]))
            if donor == k or len(parts[donor]) <= min_size:
                break
            take = min(min_size - len(parts[k]), len(parts[donor]) - min_size)
            moved, parts[donor] = parts[donor][:take], parts[donor][take:]
            parts[k] = np.sort(np.concatenate([parts[k], moved]))
    return parts


def _paper(labels: np.ndarray, clients: int, per_client: int, rng: np.random.Generator):
    n_classes = int(labels.max()) + 1
    parts = []
    for k in range(clients):
        digit = k * n_classes // clients
        idx = np.nonzero(labels == digit)[0]
        parts.append(np.sort(rng.choice(idx, size=min(per_client, idx.size), replace=False)))
    return parts


def partition_indices(labels: np.ndarray, clients: int, cfg: PartitionConfig) -> List[np.ndarray]:
    """Returns ``clients`` index arrays into the dataset ``labels`` indexes."""
    labels = np.asarray(labels)
    rng = np.random.default_rng(cfg.seed)
    if cfg.kind == "iid":
        return _iid(len(labels), clients, rng)
    if cfg.kind == "shard":
        return _shard(labels, clients, cfg.shards_per_client, rng)
    if cfg.kind == "dirichlet":
        return _dirichlet(labels, clients, cfg.alpha, cfg.min_size, rng)
    if cfg.kind == "paper":
        return _paper(labels, clients, cfg.per_client, rng)
    raise ValueError(f"unknown partition kind {cfg.kind!r}")


def partition_stats(parts: List[np.ndarray], labels: np.ndarray) -> np.ndarray:
    """(clients, n_classes) label-count matrix — the heterogeneity fingerprint
    (rows of a low-alpha Dirichlet split are near one-hot)."""
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    out = np.zeros((len(parts), n_classes), np.int64)
    for k, p in enumerate(parts):
        if len(p):
            out[k] = np.bincount(labels[p], minlength=n_classes)
    return out
