"""Deterministic synthetic data pipelines.

TokenDataset: a learnable synthetic "language" (noisy affine next-token rule)
keyed purely by (seed, step, shard) -- restart-deterministic by construction,
which is what makes exact checkpoint/resume verification possible.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def affine_rule_batch(k_start, k_noise, k_rand, batch, seq, vocab_size, noise, c=17):
    """The synthetic language's generator, shared by TokenDataset and the fed
    engine's dialect-skewed TokenClientData: sequences follow the noisy
    affine next-token rule ``(start * 31**(i%8) + c*i) % vocab``.  ``c`` may
    be a scalar or a (batch, 1) array (per-sequence "dialect" constants)."""
    start = jax.random.randint(k_start, (batch, 1), 0, vocab_size)
    idx = jnp.arange(seq + 1)
    seqs = (start * jnp.power(31, idx % 8) + c * idx) % vocab_size
    noise_mask = jax.random.bernoulli(k_noise, noise, seqs.shape)
    random_toks = jax.random.randint(k_rand, seqs.shape, 0, vocab_size)
    seqs = jnp.where(noise_mask, random_toks, seqs).astype(jnp.int32)
    return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


@dataclasses.dataclass(frozen=True)
class TokenDataset:
    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    noise: float = 0.2  # fraction of random next-tokens

    def get_batch(self, step: int, shard: int = 0, n_shards: int = 1):
        """Returns {"tokens", "labels"} for this step/shard.  Pure function of
        (seed, step, shard): re-running any step reproduces its batch."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), shard
        )
        k1, k2, k3 = jax.random.split(key, 3)
        return affine_rule_batch(
            k1, k2, k3, self.batch // n_shards, self.seq, self.vocab_size, self.noise
        )
