"""Deterministic synthetic data pipelines.

TokenDataset: a learnable synthetic "language" (noisy affine next-token rule)
keyed purely by (seed, step, shard) -- restart-deterministic by construction,
which is what makes exact checkpoint/resume verification possible.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenDataset:
    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    noise: float = 0.2  # fraction of random next-tokens

    def get_batch(self, step: int, shard: int = 0, n_shards: int = 1):
        """Returns {"tokens", "labels"} for this step/shard.  Pure function of
        (seed, step, shard): re-running any step reproduces its batch."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), shard
        )
        k1, k2, k3 = jax.random.split(key, 3)
        b = self.batch // n_shards
        start = jax.random.randint(k1, (b, 1), 0, self.vocab_size)
        # affine next-token rule: learnable structure
        a, c = 31, 17
        idx = jnp.arange(self.seq + 1)
        seqs = (start * jnp.power(a, idx % 8) + c * idx) % self.vocab_size
        noise_mask = jax.random.bernoulli(k2, self.noise, seqs.shape)
        random_toks = jax.random.randint(k3, seqs.shape, 0, self.vocab_size)
        seqs = jnp.where(noise_mask, random_toks, seqs).astype(jnp.int32)
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
