"""MNIST loader with an offline surrogate + the paper's non-IID federation.

If real MNIST IDX files exist under $MNIST_DIR (train-images-idx3-ubyte etc.,
optionally .gz), they are used.  Otherwise a deterministic class-conditional
surrogate ("synthMNIST") is generated: per-class Gaussian prototype images +
pixel noise, same shapes/splits (60k train / 10k test, 28x28 in [0,1]).
The paper's claims validated on the surrogate are *relative* (compressed vs
uncompressed accuracy; NMSE ordering across frameworks) -- see DESIGN.md.

Federation splits (incl. the paper's Sec. VI one-digit-per-device scheme)
live in repro.fed.partition and operate on the label vector returned here.
"""

from __future__ import annotations

import gzip
import os
import struct

import numpy as np

N_TRAIN, N_TEST, DIM, N_CLASSES = 60_000, 10_000, 784, 10


def _read_idx(path: str) -> np.ndarray:
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rb") as f:
        magic = struct.unpack(">HBB", f.read(4))
        ndim = magic[2]
        dims = struct.unpack(">" + "I" * ndim, f.read(4 * ndim))
        return np.frombuffer(f.read(), dtype=np.uint8).reshape(dims)


def _load_real(root: str):
    def find(stem):
        for suffix in ("", ".gz"):
            p = os.path.join(root, stem + suffix)
            if os.path.exists(p):
                return p
        raise FileNotFoundError(stem)

    xtr = _read_idx(find("train-images-idx3-ubyte")).reshape(-1, DIM) / 255.0
    ytr = _read_idx(find("train-labels-idx1-ubyte"))
    xte = _read_idx(find("t10k-images-idx3-ubyte")).reshape(-1, DIM) / 255.0
    yte = _read_idx(find("t10k-labels-idx1-ubyte"))
    return (xtr.astype(np.float32), ytr.astype(np.int32),
            xte.astype(np.float32), yte.astype(np.int32))


def _synth(seed: int = 0):
    """Class-conditional surrogate, tuned so a 784-20-10 MLP needs a few
    hundred Adam steps to separate the classes (like real MNIST) rather than
    a handful -- per-class signal lives in a low-dim subspace under heavy
    pixel noise."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.35, 0.18, (N_CLASSES, DIM)).clip(0, 1).astype(np.float32)

    def make(n):
        y = rng.integers(0, N_CLASSES, n).astype(np.int32)
        x = protos[y] + rng.normal(0, 0.45, (n, DIM)).astype(np.float32)
        return x.clip(0, 1).astype(np.float32), y

    xtr, ytr = make(N_TRAIN)
    xte, yte = make(N_TEST)
    return xtr, ytr, xte, yte


def load(seed: int = 0):
    """Returns (x_train, y_train, x_test, y_test), real data if available."""
    root = os.environ.get("MNIST_DIR", "")
    if root and os.path.isdir(root):
        try:
            return _load_real(root), True
        except FileNotFoundError:
            pass
    return _synth(seed), False
