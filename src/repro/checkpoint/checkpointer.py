"""Fault-tolerant checkpointing with elastic resharding.

Format: one .npz per checkpoint (flattened '/'-joined leaf paths) + a JSON
manifest (step, shapes, dtypes, QLeaf markers).  Saves go through a temp file
+ atomic rename so a crash mid-write never corrupts the latest checkpoint;
an optional background thread makes saves asynchronous (the training loop
only blocks on the previous save's completion -- standard double-buffering).

Restore accepts a *different* mesh/sharding than the save (elastic scale-up/
down): arrays are loaded on host and re-placed via device_put with the new
sharding.  jax.Array leaves are np.asarray'd at save time (fully-addressable
single-process case; in a true multi-host deployment the same layout is
written per-process with a shard manifest -- the format field is reserved).
"""

from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.optim.adam import QLeaf

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QLeaf)
    )[0]:
        name = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if isinstance(leaf, QLeaf):
            flat[name + ".q"] = np.asarray(leaf.q)
            flat[name + ".scale"] = np.asarray(leaf.scale)
        else:
            flat[name] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any):
        flat = _flatten(tree)  # host transfer happens here, synchronously
        if self._pending is not None:
            self._pending.join()  # double-buffer: wait for previous write
        if self.async_save:
            self._pending = threading.Thread(target=self._write, args=(step, flat))
            self._pending.start()
        else:
            self._write(step, flat)

    def _write(self, step: int, flat: dict):
        tmp = os.path.join(self.dir, f".tmp-{step}.npz")
        final = os.path.join(self.dir, f"ckpt-{step:08d}.npz")
        np.savez(tmp, **flat)
        os.replace(tmp, final)
        manifest = {
            "step": step,
            "format": "npz-v1",
            "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in flat.items()},
        }
        mtmp = os.path.join(self.dir, f".tmp-{step}.json")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, os.path.join(self.dir, f"ckpt-{step:08d}.json"))
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        ckpts = sorted(self.steps())
        for s in ckpts[: -self.keep]:
            for ext in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.dir, f"ckpt-{s:08d}{ext}"))
                except FileNotFoundError:
                    pass

    # -- restore --------------------------------------------------------------
    def steps(self):
        out = []
        for f in os.listdir(self.dir):
            m = re.match(r"ckpt-(\d+)\.npz$", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: Optional[int] = None, shardings: Any = None):
        """Loads into the structure of ``template``.  ``shardings`` (optional
        matching pytree of jax.sharding.Sharding) re-places every leaf --
        this is the elastic-resharding path: the saved mesh is irrelevant."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        self.wait()
        data = np.load(os.path.join(self.dir, f"ckpt-{step:08d}.npz"))

        flat_t, treedef = jax.tree_util.tree_flatten_with_path(
            template, is_leaf=lambda x: isinstance(x, QLeaf)
        )
        flat_s = (
            jax.tree_util.tree_leaves(shardings, is_leaf=lambda x: x is None)
            if shardings is not None
            else [None] * len(flat_t)
        )
        leaves = []
        for (path, tleaf), sh in zip(flat_t, flat_s):
            name = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            if isinstance(tleaf, QLeaf):
                leaves.append(QLeaf(q=data[name + ".q"], scale=data[name + ".scale"]))
            else:
                arr = data[name]
                if sh is not None:
                    arr = jax.device_put(arr, sh)
                leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), step
