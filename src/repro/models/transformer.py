"""Decoder-only transformer family: dense GQA, MoE, MLA (+MTP), and the
VLM backbone (M-RoPE with patch-embedding inputs).

Layers are *stacked* (leading L axis) and executed with jax.lax.scan +
configurable rematerialization -- the production pattern that keeps HLO size
and compile time independent of depth.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.common import (
    apply_attention,
    apply_mlp,
    dense_init,
    dtype_of,
    embed_tokens,
    init_attention,
    init_embed,
    init_mlp,
    logits_from,
    remat_policy,
    rms_norm,
    softmax_cross_entropy,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, moe: bool):
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
    }
    p["attn"] = mla_mod.init_mla(k1, cfg) if cfg.use_mla else init_attention(k1, cfg)
    if moe:
        p["ffn"] = moe_mod.init_moe(k2, cfg)
    else:
        p["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dt)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "tok": init_embed(ks[0], cfg),
        "final_norm": jnp.ones((cfg.d_model,), dtype_of(cfg)),
    }
    n_dense = cfg.first_dense_layers if cfg.is_moe else 0
    n_main = cfg.n_layers - n_dense
    if n_dense:
        keys = jax.random.split(ks[1], n_dense)
        p["layers_dense"] = jax.vmap(lambda k: _init_layer(k, cfg, moe=False))(keys)
    keys = jax.random.split(ks[2], n_main)
    p["layers"] = jax.vmap(lambda k: _init_layer(k, cfg, moe=cfg.is_moe))(keys)
    if cfg.mtp:
        km1, km2 = jax.random.split(ks[3])
        p["mtp"] = {
            "proj": dense_init(km1, (2 * cfg.d_model, cfg.d_model), dtype_of(cfg), 2 * cfg.d_model),
            "norm": jnp.ones((cfg.d_model,), dtype_of(cfg)),
            "layer": _init_layer(km2, cfg, moe=False),
        }
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _layer_fwd(lp, x, positions, cfg: ModelConfig, moe: bool):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        attn_out = mla_mod.apply_mla_train(lp["attn"], h, positions, cfg)
    else:
        attn_out, _ = apply_attention(lp["attn"], h, positions, cfg, causal=True)
    x = x + attn_out
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if moe:
        x = x + moe_mod.apply_moe(lp["ffn"], h, cfg)
    else:
        x = x + apply_mlp(lp["ffn"], h)
    return x


def _scan_stack(stack, x, positions, cfg: ModelConfig, moe: bool):
    policy = remat_policy(cfg)

    def body(carry, lp):
        return _layer_fwd(lp, carry, positions, cfg, moe), None

    if policy is not None:
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, stack, unroll=True if cfg.unroll_layers else 1)
    return x


def forward_hidden(params, x, positions, cfg: ModelConfig):
    if "layers_dense" in params:
        x = _scan_stack(params["layers_dense"], x, positions, cfg, moe=False)
    x = _scan_stack(params["layers"], x, positions, cfg, moe=cfg.is_moe)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


# -- train stages (the interleaved-producer protocol, DESIGN.md #Interleave) --
#
# ``train_loss`` is the composition of these stage functions, and
# models/segment_tap.py replays the SAME functions under per-stage jax.vjp
# to emit gradients layer-by-layer during the backward pass.  Each stage is
# (params-subtree, carry, ctx) -> carry'; ``train_ctx`` packs the
# non-parameter inputs every stage may read.


def train_ctx(batch, cfg: ModelConfig):
    """Stage context: tokens/labels/positions (+ patches/mask when present)."""
    tokens = batch["tokens"]  # (B, S)
    b, s = tokens.shape
    ctx = {"tokens": tokens, "labels": batch["labels"]}
    if "mask" in batch:
        ctx["mask"] = batch["mask"]
    if cfg.family == "vlm":
        ctx["patches"] = batch["patches"]
        ctx["positions"] = batch["positions"]  # (3, B, Sv+S) M-RoPE streams
    else:
        ctx["positions"] = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return ctx


def embed_stage(sp, ctx, cfg: ModelConfig):
    """Token embedding (+ VLM patch prefix).  sp = {"embed": ...}."""
    x = embed_tokens(sp, ctx["tokens"], cfg)
    if cfg.family == "vlm":
        x = jnp.concatenate([ctx["patches"].astype(x.dtype), x], axis=1)
    return x


def stack_stage(stack, x, ctx, cfg: ModelConfig, moe: bool):
    """One (chunk of a) stacked layer run -- sp is a (L', ...) slice."""
    return _scan_stack(stack, x, ctx["positions"], cfg, moe)


def head_params(params, cfg: ModelConfig):
    """Head-stage parameter subtree: final_norm + the token matrices the
    logits read (full ``tok`` when tied or under MTP -- MTP re-embeds the
    shifted tokens -- else just ``lm_head``) + the MTP block."""
    tok = params["tok"] if (cfg.tie_embeddings or cfg.mtp) else {
        "lm_head": params["tok"]["lm_head"]
    }
    hp = {"final_norm": params["final_norm"], "tok": tok}
    if cfg.mtp:
        hp["mtp"] = params["mtp"]
    return hp


def head_stage(hp, x, ctx, cfg: ModelConfig):
    """Final norm -> (VLM: text slice) -> logits -> CE (+ MTP aux loss)."""
    hidden = rms_norm(x, hp["final_norm"], cfg.norm_eps)
    if cfg.family == "vlm":
        hidden = hidden[:, -ctx["tokens"].shape[1]:]  # text positions only
    logits = logits_from(hp["tok"], hidden, cfg)
    loss = softmax_cross_entropy(logits, ctx["labels"], ctx.get("mask"))
    if cfg.mtp:
        loss = loss + 0.3 * _mtp_loss(
            hp, hidden, ctx["tokens"], ctx["labels"], ctx["positions"], cfg
        )
    return loss


def train_loss(params, batch, cfg: ModelConfig):
    ctx = train_ctx(batch, cfg)
    x = embed_stage({"embed": params["tok"]["embed"]}, ctx, cfg)
    if "layers_dense" in params:
        x = stack_stage(params["layers_dense"], x, ctx, cfg, moe=False)
    x = stack_stage(params["layers"], x, ctx, cfg, moe=cfg.is_moe)
    return head_stage(head_params(params, cfg), x, ctx, cfg)


def _mtp_loss(params, hidden, tokens, labels, positions, cfg: ModelConfig):
    """DeepSeek-V3 multi-token prediction: at position t, combine h_t with
    emb(token_{t+1}) and predict token_{t+2} through one extra layer."""
    mp = params["mtp"]
    emb_next = embed_tokens(params["tok"], tokens, cfg)[:, 1:]  # emb(t+1 .. )
    h = hidden[:, :-1]
    x = jnp.concatenate([rms_norm(h, mp["norm"], cfg.norm_eps), emb_next], axis=-1)
    x = x @ mp["proj"]
    pos = positions[..., :-1] if positions.ndim == 2 else positions[..., :-1]
    x = _layer_fwd(mp["layer"], x, pos, cfg, moe=False)
    logits = logits_from(params["tok"], x, cfg)
    return softmax_cross_entropy(logits, labels[:, 1:])


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, smax: int):
    dt = dtype_of(cfg)
    L = cfg.n_layers
    if cfg.use_mla:
        return {
            "ckv": jnp.zeros((L, batch, smax, cfg.kv_lora_rank), dt),
            "kr": jnp.zeros((L, batch, smax, cfg.qk_rope_head_dim), dt),
        }
    dh = cfg.head_dim
    return {
        "k": jnp.zeros((L, batch, smax, cfg.n_kv_heads, dh), dt),
        "v": jnp.zeros((L, batch, smax, cfg.n_kv_heads, dh), dt),
    }


def _layer_decode(lp, x, positions, cfg: ModelConfig, layer_cache, pos, moe: bool):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        attn_out, new_cache = mla_mod.apply_mla_decode(lp["attn"], h, positions, cfg, layer_cache, pos)
    else:
        attn_out, new_cache = apply_attention(
            lp["attn"], h, positions, cfg, causal=False, cache=layer_cache, cache_pos=pos
        )
    x = x + attn_out
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if moe:
        x = x + moe_mod.apply_moe(lp["ffn"], h, cfg)
    else:
        x = x + apply_mlp(lp["ffn"], h)
    return x, new_cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One-token decode.  tokens (B, 1); pos scalar int32 (next write slot).

    Returns (logits (B, 1, V), new_cache)."""
    b = tokens.shape[0]
    x = embed_tokens(params["tok"], tokens, cfg)
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(pos[None, None, None], (3, b, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)

    n_dense = cfg.first_dense_layers if cfg.is_moe else 0

    def split_cache(c, lo, hi):
        return jax.tree_util.tree_map(lambda v: v[lo:hi], c)

    new_caches = []
    offset = 0
    for name, moe in (("layers_dense", False), ("layers", cfg.is_moe)):
        if name not in params:
            continue
        stack = params[name]
        n = jax.tree_util.tree_leaves(stack)[0].shape[0]
        sub_cache = split_cache(cache, offset, offset + n)

        def body(carry, xs, moe=moe):
            lp, lc = xs
            out, nc = _layer_decode(lp, carry, positions, cfg, lc, pos, moe)
            return out, nc

        x, nc = jax.lax.scan(body, x, (stack, sub_cache), unroll=True if cfg.unroll_layers else 1)
        new_caches.append(nc)
        offset += n
    new_cache = jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs, 0), *new_caches) if len(new_caches) > 1 else new_caches[0]
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from(params["tok"], hidden, cfg)
    return logits, new_cache


def prefill(params, batch, cfg: ModelConfig):
    """Full-sequence prefill: returns (last-position logits, filled cache).

    The cache is rebuilt from the per-layer K/V projections of the forward
    pass (recomputed outside the scan to keep the train path untouched)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(params["tok"], tokens, cfg)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    # Capture K/V during the scan by extending the body to emit them.
    def capture_stack(stack, x, moe):
        policy = remat_policy(cfg)

        def body(carry, lp):
            h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                ckv = rms_norm(h @ lp["attn"]["w_dkv"], lp["attn"]["kv_norm_lr"], cfg.norm_eps)
                from repro.models.common import apply_rope

                kr = apply_rope((h @ lp["attn"]["w_kr"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
                kv = {"ckv": ckv, "kr": kr}
                attn_out = mla_mod.apply_mla_train(lp["attn"], h, positions, cfg)
            else:
                k = (h @ lp["attn"]["wk"]) + lp["attn"].get("bk", 0.0)
                v = (h @ lp["attn"]["wv"]) + lp["attn"].get("bv", 0.0)
                dh = cfg.head_dim
                k = k.reshape(b, x.shape[1], cfg.n_kv_heads, dh)
                v = v.reshape(b, x.shape[1], cfg.n_kv_heads, dh)
                if "k_norm" in lp["attn"]:
                    k = rms_norm(k, lp["attn"]["k_norm"], cfg.norm_eps)
                from repro.models.common import apply_mrope, apply_rope

                if cfg.mrope_sections is not None:
                    k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
                else:
                    k = apply_rope(k, positions, cfg.rope_theta)
                kv = {"k": k, "v": v}
                attn_out, _ = apply_attention(lp["attn"], h, positions, cfg, causal=True)
            xo = carry + attn_out
            h2 = rms_norm(xo, lp["ln2"], cfg.norm_eps)
            if moe:
                xo = xo + moe_mod.apply_moe(lp["ffn"], h2, cfg)
            else:
                xo = xo + apply_mlp(lp["ffn"], h2)
            return xo, kv

        if policy is not None:
            body = jax.checkpoint(body, policy=policy, prevent_cse=False)
        return jax.lax.scan(body, x, stack, unroll=True if cfg.unroll_layers else 1)

    caches = []
    if "layers_dense" in params:
        x, kv = capture_stack(params["layers_dense"], x, moe=False)
        caches.append(kv)
    x, kv = capture_stack(params["layers"], x, moe=cfg.is_moe)
    caches.append(kv)
    cache = (
        jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs, 0), *caches)
        if len(caches) > 1
        else caches[0]
    )
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from(params["tok"], hidden[:, -1:], cfg)
    return logits, cache
