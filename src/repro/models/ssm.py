"""Mamba-2 (SSD, state-space duality) blocks: chunked train scan + O(1) decode.

Implements the minimal SSD algorithm of the Mamba-2 paper (chunkwise:
intra-chunk quadratic term + inter-chunk state recurrence), with a single
B/C group (ngroups=1) broadcast over heads, a short causal depthwise conv on
(x|B|C), softplus dt with learned bias, and a gated RMSNorm before out_proj.

Decode carries (conv_state (B, k-1, C_conv), ssm_state (B, H, P, N)) and does
the exact single-step recurrence -- the sub-quadratic property that makes the
long_500k serving shape tractable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, dtype_of, rms_norm
from repro.models.sharding import cs


def _conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state  # x | B | C


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * n + h  # z | x | B | C | dt
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), dt, d),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_kernel, _conv_channels(cfg)), dt),
        "conv_b": jnp.zeros((_conv_channels(cfg),), dt),
        "a_log": jnp.zeros((h,), jnp.float32),  # A = -exp(a_log) in (-1, 0]
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[2], (di, d), dt, di),
    }


def _segsum(x):
    """(..., l) -> (..., l, l) with out[i,j] = sum_{j<k<=i} x[k]; -inf above diag."""
    l = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)
    seg = csum[..., :, None] - csum[..., None, :]
    mask = jnp.arange(l)[:, None] >= jnp.arange(l)[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_chunked(xh, dta, bm, cm, chunk: int):
    """Chunked SSD scan.

    xh  (B, T, H, P)   inputs (already dt-weighted)
    dta (B, T, H)      dt * A  (negative)
    bm  (B, T, N), cm (B, T, N)   single-group B/C
    Returns y (B, T, H, P) and final state (B, H, P, N).
    """
    b, t, h, p = xh.shape
    n = bm.shape[-1]
    t0 = t
    pad = (-t) % chunk
    if pad:  # zero-dt padding is a no-op on the recurrence (exp(0)=1, dB x=0)
        zf = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        xh, dta, bm, cm = zf(xh), zf(dta), zf(bm), zf(cm)
        t = t + pad
    c = t // chunk
    x_ = xh.reshape(b, c, chunk, h, p)
    a_ = dta.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # (B,H,C,L)
    b_ = bm.reshape(b, c, chunk, n)
    c__ = cm.reshape(b, c, chunk, n)

    a_cum = jnp.cumsum(a_, axis=-1)  # (B,H,C,L)
    # 1. intra-chunk (quadratic attention-like) term
    ll = jnp.exp(_segsum(a_))  # (B,H,C,L,L)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", c__, b_, ll, x_)
    # 2. per-chunk final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (B,H,C,L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", b_, decay_states, x_)
    # 3. inter-chunk recurrence over the C chunk axis
    init = jnp.zeros_like(states[:, :1])
    states = jnp.concatenate([init, states], axis=1)  # (B,C+1,H,P,N)
    a_last = jnp.pad(a_cum[..., -1], ((0, 0), (0, 0), (1, 0)))  # (B,H,C+1)
    decay_chunk = jnp.exp(_segsum(a_last))  # (B,H,C+1,C+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    states, final = new_states[:, :-1], new_states[:, -1]
    # 4. state -> output contribution
    out_decay = jnp.exp(a_cum)  # (B,H,C,L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", c__, states, out_decay)
    y = (y_diag + y_off).reshape(b, t, h, p)[:, :t0]
    return y, final


def _causal_conv(u, w, bias):
    """Depthwise causal conv along time.  u (B,T,C), w (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):
        out = out + pad[:, i : i + u.shape[1], :] * w[i]
    return out + bias


def _split_proj(zxbcdt, cfg: ModelConfig):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n :]
    return z, xbc, dt


def apply_mamba_train(p, x, cfg: ModelConfig):
    b, t, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, bm, cm = xbc[..., :di], xbc[..., di : di + n], xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    a = -jnp.exp(p["a_log"])  # (H,)
    xh = xs.reshape(b, t, h, ph)
    xh = cs(xh, "batch", "seq", "heads", None)
    y, _ = _ssd_chunked(
        (xh * dt[..., None]).astype(jnp.float32),
        dt * a,
        bm.astype(jnp.float32),
        cm.astype(jnp.float32),
        cfg.ssm_chunk,
    )
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, t, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return cs(y @ p["out_proj"], "batch", "seq", "dmodel")


def apply_mamba_prefill(p, x, cfg: ModelConfig):
    """Train-path forward that ALSO returns the decode cache (conv window +
    final SSD state) so serving can continue from position T."""
    b, t, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    k = cfg.ssm_conv_kernel
    zxbcdt = x @ p["in_proj"]
    z, xbc_pre, dt = _split_proj(zxbcdt, cfg)
    conv_state = xbc_pre[:, -(k - 1) :, :]  # last K-1 pre-conv inputs
    xbc = jax.nn.silu(_causal_conv(xbc_pre, p["conv_w"], p["conv_b"]))
    xs, bm, cm = xbc[..., :di], xbc[..., di : di + n], xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(b, t, h, ph)
    y, final_state = _ssd_chunked(
        (xh * dt[..., None]).astype(jnp.float32),
        dt * a,
        bm.astype(jnp.float32),
        cm.astype(jnp.float32),
        cfg.ssm_chunk,
    )
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, t, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = cs(y @ p["out_proj"], "batch", "seq", "dmodel")
    return out, {"conv": conv_state, "ssm": final_state}


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_kernel - 1, _conv_channels(cfg)), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }


def apply_mamba_decode(p, x, cfg: ModelConfig, cache):
    """x (B, 1, D); exact one-step recurrence.  Returns (y, new_cache)."""
    b, _, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    zxbcdt = x[:, 0] @ p["in_proj"]  # (B, proj)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]
    xs, bm, cm = xbc[..., :di], xbc[..., di : di + n], xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)  # (B,H)
    xh = xs.reshape(b, h, ph).astype(jnp.float32)
    ssm = cache["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xh, bm.astype(jnp.float32), dt
    )
    y = jnp.einsum("bhpn,bn->bhp", ssm, cm.astype(jnp.float32))
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "ssm": ssm}
