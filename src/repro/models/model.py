"""Unified model API: family dispatch + per-shape input specs.

Every architecture exposes the same five entry points regardless of family:
  init_params(cfg, key)           -- eval_shape-able (dry-run never allocates)
  train_loss(params, batch, cfg)  -- scalar loss
  prefill(params, batch, cfg)     -- (last logits, filled cache)
  decode_step(params, cache, tokens, pos, cfg)
  init_cache(cfg, batch, smax)

`input_specs(cfg, shape)` produces ShapeDtypeStruct stand-ins for every input
of the corresponding step -- the dry-run contract.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models.common import dtype_of


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

_VIS_FRAC = 4  # vlm: 1/4 of the sequence budget is patch embeddings
_AUDIO_TEXT_FRAC = 8  # audio: text tokens are 1/8 of the frame budget


def _module(cfg: ModelConfig):
    return {
        "dense": transformer,
        "moe": transformer,
        "vlm": transformer,
        "ssm": ssm_lm,
        "hybrid": hybrid,
        "audio": encdec,
    }[cfg.family]


def init_params(cfg: ModelConfig, key):
    return _module(cfg).init_params(cfg, key)


def train_loss(params, batch, cfg: ModelConfig):
    return _module(cfg).train_loss(params, batch, cfg)


def init_cache(cfg: ModelConfig, batch: int, smax: int):
    return _module(cfg).init_cache(cfg, batch, smax)


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    return _module(cfg).decode_step(params, cache, tokens, pos, cfg)


def prefill(params, batch, cfg: ModelConfig, smax: int | None = None):
    mod = _module(cfg)
    if cfg.family == "audio":
        return mod.prefill(params, batch, cfg, smax or batch["frames"].shape[1])
    return mod.prefill(params, batch, cfg)


def supports_cell(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Whether (arch x shape) is in contract; (ok, reason-if-not)."""
    cell = SHAPES[shape]
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k requires sub-quadratic attention (see DESIGN.md)"
    if cell.kind == "decode" and not cfg.supports_decode:
        return False, "architecture has no decode step"
    return True, ""


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """Returns the batch pytree (train/prefill) or decode inputs as specs."""
    cell = SHAPES[shape]
    b, s = cell.batch, cell.seq
    dt = dtype_of(cfg)
    if cell.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            sv = s // _VIS_FRAC
            st = s - sv
            specs = {
                "tokens": _i32((b, st)),
                "patches": jax.ShapeDtypeStruct((b, sv, cfg.d_model), dt),
                "positions": _i32((3, b, s)),
            }
            if cell.kind == "train":
                specs["labels"] = _i32((b, st))
            return specs
        if cfg.family == "audio":
            st = max(64, s // _AUDIO_TEXT_FRAC)
            specs = {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
            if cell.kind == "train":
                specs["tokens"] = _i32((b, st))
                specs["labels"] = _i32((b, st))
            return specs
        specs = {"tokens": _i32((b, s))}
        if cell.kind == "train":
            specs["labels"] = _i32((b, s))
        return specs
    # decode
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return {
        "tokens": _i32((b, 1)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }


def make_batch(cfg: ModelConfig, shape: str, key) -> Dict[str, Any]:
    """Materializes a random batch matching input_specs (smoke tests/bench)."""
    specs = input_specs(cfg, shape)

    def fill(spec):
        if spec.dtype == jnp.int32:
            if spec.shape and spec.shape[0] == 3 and len(spec.shape) == 3:
                return jnp.broadcast_to(
                    jnp.arange(spec.shape[-1], dtype=jnp.int32), spec.shape
                )
            return jax.random.randint(key, spec.shape, 0, max(2, cfg.vocab_size), jnp.int32) % cfg.vocab_size
        return jax.random.normal(key, spec.shape, jnp.float32).astype(spec.dtype) * 0.02

    return jax.tree_util.tree_map(fill, specs)
