"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP over 'model').

TPU-native dispatch (no per-token dynamic shapes): tokens are replicated k
times, argsorted by assigned expert, ranked within their expert group, and
scattered into an (E, C, D) buffer with capacity C = ceil(T*k/E * cf); the
expert GEMMs are then three dense (E, C, *) einsums that shard cleanly with
experts on the 'model' mesh axis.  Overflow tokens beyond capacity drop to a
trash slot (standard capacity-factor semantics); their combine weight is
simply lost, which upper-bounds the drop impact by the router entropy.

Router: softmax over E, top-k, renormalized (Qwen3 style).  Optional shared
experts (DeepSeek style) run as a plain dense MLP on every token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_mlp, dense_init, dtype_of, init_mlp
from repro.models.sharding import cs


def init_moe(key, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, d),
        "experts": {
            "wi": dense_init(ks[1], (e, d, f), dt, d),
            "wg": dense_init(ks[2], (e, d, f), dt, d),
            "wo": dense_init(ks[3], (e, f, d), dt, f),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(
            ks[4], d, cfg.n_shared_experts * (cfg.shared_d_ff or f), dt
        )
    return p


def apply_moe(p, x, cfg: ModelConfig):
    b, s, d = x.shape
    t = b * s
    k = cfg.n_experts_per_tok
    e = cfg.n_experts
    cap = int((t * k) / e * cfg.capacity_factor + 1)

    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32)) @ p["router"]  # (T, E) fp32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # (T, k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = topw.reshape(-1).astype(x.dtype)

    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(se, length=e)  # (E,)
    start = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - start[se]
    keep = pos < cap
    dest = jnp.where(keep, se * cap + pos, e * cap)  # overflow -> trash slot

    gathered = jnp.take(xt, st, axis=0)  # (T*k, D)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(gathered)
    h = buf[: e * cap].reshape(e, cap, d)
    h = cs(h, "experts", None, None)

    wi, wg, wo = p["experts"]["wi"], p["experts"]["wg"], p["experts"]["wo"]
    act = jnp.einsum("ecd,edf->ecf", h, wi) * jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", h, wg)
    )
    act = cs(act, "experts", None, None)
    out = jnp.einsum("ecf,efd->ecd", act, wo)
    out_buf = jnp.concatenate(
        [out.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)], axis=0
    )
    ys = jnp.take(out_buf, dest, axis=0) * sw[:, None]  # (T*k, D)
    y = jnp.zeros((t, d), x.dtype).at[st].add(ys)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x).reshape(t, d)
    return cs(y.reshape(b, s, d), "batch", "seq", "dmodel")
