"""Multi-head Latent Attention (DeepSeek-V3) with absorbed-matmul decoding.

Train/prefill: decompress latents into full per-head K/V (standard GEMMs).
Decode: the *compressed* latent c_kv (kv_lora_rank) + shared rope-key are the
KV cache -- (kv_rank + rope_dim) floats/token instead of 2*H*dh -- and the
up-projections are absorbed into the query/output transforms (the production
MLA trick), so decode attention contracts against the latent directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, dense_init, dtype_of, rms_norm
from repro.models.sharding import cs


def init_mla(key, cfg: ModelConfig):
    d = cfg.d_model
    dt = dtype_of(cfg)
    h = cfg.n_heads
    qk_nope, qk_rope, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, cfg.q_lora_rank), dt, d),
        "q_norm_lr": jnp.ones((cfg.q_lora_rank,), dt),
        "w_uq": dense_init(ks[1], (cfg.q_lora_rank, h * (qk_nope + qk_rope)), dt, cfg.q_lora_rank),
        "w_dkv": dense_init(ks[2], (d, cfg.kv_lora_rank), dt, d),
        "kv_norm_lr": jnp.ones((cfg.kv_lora_rank,), dt),
        "w_kr": dense_init(ks[3], (d, qk_rope), dt, d),
        "w_uk": dense_init(ks[4], (cfg.kv_lora_rank, h * qk_nope), dt, cfg.kv_lora_rank),
        "w_uv": dense_init(ks[5], (cfg.kv_lora_rank, h * dv), dt, cfg.kv_lora_rank),
        "wo": dense_init(ks[6], (h * dv, d), dt, h * dv),
    }


def _queries(p, x, positions, cfg: ModelConfig):
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_nope, qk_rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rms_norm(x @ p["w_dq"], p["q_norm_lr"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(b, s, h, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def apply_mla_train(p, x, positions, cfg: ModelConfig):
    """Full-sequence causal MLA (decompressed path)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_nope, qk_rope, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _queries(p, x, positions, cfg)
    ckv = rms_norm(x @ p["w_dkv"], p["kv_norm_lr"], cfg.norm_eps)  # (B,S,r)
    k_nope = (ckv @ p["w_uk"]).reshape(b, s, h, qk_nope)
    v = (ckv @ p["w_uv"]).reshape(b, s, h, dv)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta)
    k_rope = jnp.broadcast_to(k_rope, (b, s, h, qk_rope))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    kk = jnp.concatenate([k_nope, k_rope], axis=-1)
    q = cs(q, "batch", "seq", "heads", None)
    kk = cs(kk, "batch", "seq", "heads", None)
    scale = 1.0 / jnp.sqrt(jnp.float32(qk_nope + qk_rope))
    scores = jnp.einsum("bqhd,bshd->bhqs", q, kk).astype(jnp.float32) * scale
    mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v).reshape(b, s, h * dv)
    return cs(out @ p["wo"], "batch", "seq", "dmodel")


def apply_mla_decode(p, x, positions, cfg: ModelConfig, cache, cache_pos):
    """Absorbed decode: cache = {'ckv' (B,Smax,r), 'kr' (B,Smax,rope)}."""
    b, s, _ = x.shape  # s == 1
    h = cfg.n_heads
    qk_nope, qk_rope, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    q_nope, q_rope = _queries(p, x, positions, cfg)

    ckv_new = rms_norm(x @ p["w_dkv"], p["kv_norm_lr"], cfg.norm_eps)
    kr_new = apply_rope((x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, cache_pos, 0))
    kr = jax.lax.dynamic_update_slice(cache["kr"], kr_new.astype(cache["kr"].dtype), (0, cache_pos, 0))
    new_cache = {"ckv": ckv, "kr": kr}
    ckv = cs(ckv, "batch", "seq_kv", None)
    kr = cs(kr, "batch", "seq_kv", None)

    # Absorb W_uk into q:  q_abs (B,1,H,r)
    w_uk = p["w_uk"].reshape(r, h, qk_nope)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_abs, ckv.astype(x.dtype))
        + jnp.einsum("bqhd,bsd->bhqs", q_rope, kr.astype(x.dtype))
    ).astype(jnp.float32) / jnp.sqrt(jnp.float32(qk_nope + qk_rope))
    smax = ckv.shape[1]
    valid = jnp.arange(smax)[None, None, None, :] <= cache_pos
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqs,bsr->bqhr", probs, ckv.astype(x.dtype))  # latent ctx
    w_uv = p["w_uv"].reshape(r, h, dv)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv).reshape(b, s, h * dv)
    return cs(out @ p["wo"], "batch", "seq", "dmodel"), new_cache
