"""Shared building blocks for the model zoo (pure JAX, functional).

Parameters are plain nested dicts of jnp arrays; every block has an
``init_*(key, cfg) -> params`` and an ``apply`` function.  Compute runs in the
config dtype (bf16 by default) with fp32 softmax/norm accumulation; all
activation tensors pass through logical sharding constraints (sharding.cs).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import cs

_INIT_STD = 0.02


def dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    scale = _INIT_STD if fan_in is None else (1.0 / jnp.sqrt(fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def _rope_angles(positions, dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, dim//2), fp32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x (B, S, H, dh), positions (B, S) -> rotated x (interleaved halves)."""
    dh = x.shape[-1]
    cos, sin = _rope_angles(positions, dh, theta)  # (B, S, dh/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections: Tuple[int, int, int]):
    """Qwen2-VL multimodal RoPE.  positions (3, B, S) = (t, h, w) streams;
    ``sections`` partitions the *half*-dim; each section rotates with its own
    position stream."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    cos_parts, sin_parts = [], []
    off = 0
    for i, sec in enumerate(sections):
        freqs = 1.0 / (theta ** (jnp.arange(off, off + sec, dtype=jnp.float32) / half))
        ang = positions[i].astype(jnp.float32)[..., None] * freqs  # (B, S, sec)
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        off += sec
    cos = jnp.concatenate(cos_parts, axis=-1)[:, :, None, :]
    sin = jnp.concatenate(sin_parts, axis=-1)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / bias / M-RoPE; train + decode paths)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    dh = cfg.head_dim
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * dh), dt, d),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * dh), dt, d),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * dh), dt, d),
        "wo": dense_init(ks[3], (cfg.n_heads * dh, d), dt, cfg.n_heads * dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * dh,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * dh,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * dh,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _split_heads(x, n, dh):
    b, s, _ = x.shape
    return x.reshape(b, s, n, dh)


def _sdpa(q, k, v, causal: bool, q_offset=0, kv_len_mask=None):
    """q (B,Sq,H,dh), k/v (B,Sk,KVH,dh) -> (B,Sq,H,dh).

    Numerics (perf iteration #1, see EXPERIMENTS.md #Perf): the S x S score
    and probability tensors dominate the HBM term of long-sequence cells, so
    they are kept in bf16 with an fp32 row-max subtraction and an fp32
    probability-sum accumulation (flash-attention numerics) -- stable, and
    half the bytes of the fp32-softmax baseline.

    ``q_offset``: absolute position of q[0] (decode).  ``kv_len_mask``:
    (B, Sk) bool of valid cache slots (decode)."""
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) * scale.astype(q.dtype)
    neg = jnp.asarray(-jnp.inf, scores.dtype)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]  # (Sq, Sk)
        scores = jnp.where(mask[None, None, None], scores, neg)
    if kv_len_mask is not None:
        scores = jnp.where(kv_len_mask[:, None, None, None, :], scores, neg)
    # Iteration 1b: the WHOLE S x S chain stays in the compute dtype (bf16);
    # only the rank-reduced row max / row sum run fp32.  (Iteration 1a cast
    # to fp32 around exp and was measured byte-neutral -- see #Perf log.)
    m = jnp.max(scores, axis=-1, keepdims=True).astype(jnp.float32)
    m = jnp.maximum(m, -1e30)  # rows that are fully masked
    p = jnp.exp(scores - m.astype(scores.dtype))  # (b,kvh,g,sq,sk) bf16
    l = jnp.sum(p, axis=-1, dtype=jnp.float32)  # (b,kvh,g,sq) fp32 accum
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)  # (b,sq,kvh,g,dh)
    denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]  # (b,sq,kvh,g,1)
    out = out / denom.astype(out.dtype)
    return out.reshape(b, sq, h, dh)


def apply_attention(
    p,
    x,
    positions,
    cfg: ModelConfig,
    causal: bool = True,
    cache=None,
    cache_pos=None,
    cross_kv=None,
):
    """Returns (out, new_cache).

    Train/prefill: cache=None, full-sequence causal attention.
    Decode: cache = {'k','v'} (B, Smax, KVH, dh); cache_pos = scalar write idx.
    Cross-attn: cross_kv = (k, v) precomputed from the encoder.
    """
    b, s, d = x.shape
    dh = cfg.head_dim
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = _split_heads(q, cfg.n_heads, dh)
    if cross_kv is None:
        k = x @ p["wk"]
        v = x @ p["wv"]
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = _split_heads(k, cfg.n_kv_heads, dh)
        v = _split_heads(v, cfg.n_kv_heads, dh)
    else:
        k, v = cross_kv
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None and cross_kv is None:
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    q = cs(q, "batch", "seq", "heads", None)

    new_cache = None
    if cache is not None and cross_kv is None:
        # decode: write the new K/V at cache_pos, attend over valid slots.
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        smax = ck.shape[1]
        valid = jnp.arange(smax)[None, :] <= cache_pos  # (1, Smax)
        valid = jnp.broadcast_to(valid, (b, smax))
        ck = cs(ck, "batch", "seq_kv", "kv_heads", None)
        cv = cs(cv, "batch", "seq_kv", "kv_heads", None)
        out = _sdpa(q, ck.astype(x.dtype), cv.astype(x.dtype), causal=False, kv_len_mask=valid)
    else:
        k = cs(k, "batch", "seq", "kv_heads", None)
        v = cs(v, "batch", "seq", "kv_heads", None)
        out = _sdpa(q, k, v, causal=causal)
    out = out.reshape(b, s, cfg.n_heads * dh)
    out = out @ p["wo"]
    return cs(out, "batch", "seq", "dmodel"), new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU; plain GELU for whisper)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], (d, f), dtype, d),
        "wo": dense_init(ks[1], (f, d), dtype, f),
    }
    if gated:
        p["wg"] = dense_init(ks[2], (d, f), dtype, d)
    return p


def apply_mlp(p, x):
    h = x @ p["wi"]
    if "wg" in p:
        h = jax.nn.silu(x @ p["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    h = cs(h, "batch", "seq", "ff")
    return cs(h @ p["wo"], "batch", "seq", "dmodel")


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    p = {"embed": dense_init(key, (cfg.vocab_size, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size), dt, cfg.d_model
        )
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    x = jnp.take(p["embed"], tokens, axis=0)
    return cs(x, "batch", "seq", "dmodel")


def logits_from(p, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        out = x @ p["embed"].T
    else:
        out = x @ p["lm_head"]
    return cs(out, "batch", "seq", "vocab")


def softmax_cross_entropy(logits, labels, mask=None):
    """Mean token CE.  logits (B,S,V) any dtype; labels (B,S) int.

    Byte-diet numerics (perf iteration, EXPERIMENTS.md #Perf): the (B,S,V)
    logits tensor dominates the HBM term of big-vocab training cells, so the
    exp() intermediate stays in the logits dtype (bf16) and only the row max
    and the probability sum run fp32 -- same stable-LSE value, half the
    bytes of an fp32-upcast softmax."""
    m = jnp.max(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(logits - m[..., None].astype(logits.dtype))  # bf16 (B,S,V)
    lse = jnp.log(jnp.sum(p, axis=-1, dtype=jnp.float32)) + m
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold.astype(jnp.float32)
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def head_loss_params(params, cfg: ModelConfig):
    """Selects the parameter subtree the LM-head stage actually touches
    (the interleaved producer's head-stage `select`, DESIGN.md #Interleave):
    ``final_norm`` plus the token matrices the logits read -- the full
    ``tok`` when tied (logits reuse the embedding), just ``lm_head`` when
    untied, so the untied embedding's gradient flows exclusively through
    the embed stage and never needs a zero-add here."""
    tok = params["tok"] if cfg.tie_embeddings else {"lm_head": params["tok"]["lm_head"]}
    return {"final_norm": params["final_norm"], "tok": tok}


def head_loss(p, x, ctx, cfg: ModelConfig):
    """Shared LM-head stage: final RMS norm -> (tied) logits -> mean token
    CE.  ``p`` is :func:`head_loss_params`; ``ctx`` carries labels (+
    optional mask).  This is both the tail of the ssm/hybrid train_loss and
    the last backward stage of the interleaved gradient producer
    (models/segment_tap.py) -- one definition, so both paths trace the same
    ops."""
    hidden = rms_norm(x, p["final_norm"], cfg.norm_eps)
    logits = logits_from(p["tok"], hidden, cfg)
    return softmax_cross_entropy(logits, ctx["labels"], ctx.get("mask"))


def remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return None
    if cfg.remat_policy == "minimal":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable
