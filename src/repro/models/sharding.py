"""Logical-axis sharding rules for the model zoo.

The models annotate activations via :func:`cs` (constraint) with *logical*
axis names; a ShardingRules context maps those to mesh axes.  Parameters get
PartitionSpecs from name-based rules (:func:`param_specs`).  When no rules
context is installed (CPU smoke tests), everything is a no-op.

Layout ("2D FSDP x TP", MaxText-style):
  * batch            -> data            (pod is handled by the runtime layer)
  * heads / ff / experts / vocab -> model   (tensor / expert parallelism)
  * d_model of weight matrices   -> data    (ZeRO-3 weight sharding)
  * decode KV cache: batch -> data, seq -> model (split-KV flash-decoding)
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


class ShardingRules:
    """Maps logical activation axes -> mesh axes.  None mesh axis = unsharded."""

    # Values may be: a mesh axis, a tuple of mesh axes (combined), or a LIST
    # of candidates tried in dim-divisibility order (e.g. experts prefer the
    # full in-pod mesh -- expert parallelism -- falling back to 'model').
    DEFAULT = {
        "batch": "data",
        "seq": None,
        "seq_kv": "model",  # decode-time KV sequence (split-KV)
        "dmodel": None,
        "heads": "model",
        "kv_heads": None,
        "ff": "model",
        "vocab": "model",
        "experts": "model",  # [("data","model"), "model"] with full-EP weights
        "blocks": ("data", "model"),  # FedQCS (nblocks, N) views
    }

    def __init__(self, overrides: Optional[dict] = None, axis_sizes: Optional[dict] = None):
        self.table = dict(self.DEFAULT)
        if overrides:
            self.table.update(overrides)
        # mesh axis sizes, used to drop constraints that don't divide a dim
        self.axis_sizes = dict(axis_sizes or {})

    def _axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, (tuple, list)):
            n = 1
            for a in axes:
                n *= self.axis_sizes.get(a, 1)
            return n
        return self.axis_sizes.get(axes, 1)

    def _resolve(self, value, dim: Optional[int]):
        if isinstance(value, list):  # candidates, best-fit by divisibility
            for cand in value:
                if dim is None or not self.axis_sizes or dim % self._axis_size(cand) == 0:
                    return cand
            return None
        if dim is not None and self.axis_sizes and value is not None:
            if dim % self._axis_size(value) != 0:
                return None
        return value

    def spec(self, *logical: Optional[str], dims: Optional[Tuple[int, ...]] = None) -> P:
        raw = [self.table.get(l) if l else None for l in logical]
        if dims is None:
            dims = (None,) * len(raw)
        return P(*(self._resolve(a, d) for a, d in zip(raw, dims)))


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


def cs(x, *logical: Optional[str]):
    """with_sharding_constraint by logical axis names (no-op without rules).

    Constraints whose mesh-axis product doesn't divide the dim are dropped
    (e.g. 28 query heads on a 16-way model axis) -- GSPMD could pad, but a
    clean layout beats padded shards for both memory and collectives."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.spec(*logical, dims=x.shape))


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs by path-name rules.
# ---------------------------------------------------------------------------

# (regex on '/'-joined path, CANDIDATE specs for the *trailing* dims, tried
# in order -- the first whose sharded dims all divide evenly wins, when axis
# sizes are known).  Extra leading dims (layer stacking) are padded with None.
#
# Expert weights (perf iteration #2, EXPERIMENTS.md #Perf): the first
# candidate is FULL expert parallelism -- experts spread over the whole
# (data x model) in-pod mesh, one-or-more experts fully resident per chip --
# which turns per-step expert-WEIGHT all-gathers (O(params), the dominant
# collective term of the MoE baselines) into activation all-to-alls
# (O(tokens x d)).  Falls back to EP-over-model with the contraction dims
# unsharded when E doesn't divide the full mesh.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Tuple[Optional[str], ...], ...]], ...] = (
    (r"embed", (("model", "data"),)),  # (V, D)
    (r"lm_head|final_head", (("data", "model"),)),  # (D, V)
    (r"wqkv|wq$|wk$|wv$", (("data", "model"),)),  # (D, H*dh)
    (r"bq$|bk$|bv$", (("model",),)),  # qkv bias
    (r"wo$", (("model", "data"),)),  # (H*dh, D)
    (r"w_dkv|w_dq", (("data", None),)),  # MLA down-proj (D, r)
    (r"w_uk|w_uv|w_uq", ((None, "model"),)),  # MLA up-proj (r, H*dh)
    (r"w_kr", (("data", None),)),  # MLA rope key proj
    (r"router", (("data", None),)),  # (D, E)
    # Default: EP over 'model' + weight-FSDP over 'data' (the measured best
    # dominant-term layout on this container's metric).  The full-EP
    # candidate (experts over the whole in-pod mesh) was explored in #Perf
    # iteration 2: it cuts per-device FLOPs ~3x (kills redundant expert
    # compute) but XLA's auto-partitioning of the sort-based dispatch
    # replicates token activations, inflating the collective term; enable it
    # together with an explicit all-to-all dispatch (future work).
    (r"experts/w(i|g)", (("model", "data", None),)),
    (r"experts/wo", (("model", None, "data"),)),
    (r"mlp/w(i|g)|shared/w(i|g)", (("data", "model"),)),  # (D, F)
    (r"mlp/wo|shared/wo", (("model", "data"),)),  # (F, D)
    (r"in_proj", (("data", "model"),)),  # mamba (D, X)
    (r"out_proj", (("model", "data"),)),  # mamba (di, D)
    (r"conv_w", ((None, "model"),)),  # (K, C)
    (r"norm|scale|bias|a_log|d_skip|dt_bias", ((None,),)),  # vectors: replicated
)


def _fits(spec, shape, axis_sizes) -> bool:
    for ax, dim in zip(spec, shape):
        if ax is None:
            continue
        size = 1
        for a in ax if isinstance(ax, tuple) else (ax,):
            size *= axis_sizes.get(a, 1)
        if dim % size != 0:
            return False
    return True


def _spec_for(path: str, shape, axis_sizes) -> P:
    ndim = len(shape)
    for pattern, candidates in _PARAM_RULES:
        if re.search(pattern, path):
            for trailing in candidates:
                tr = trailing[-ndim:] if len(trailing) > ndim else trailing
                spec = (None,) * (ndim - len(tr)) + tuple(tr)
                if axis_sizes is None or _fits(spec, shape, axis_sizes):
                    return P(*spec)
            trailing = candidates[0]  # caller's sanitizer handles the rest
            tr = trailing[-ndim:] if len(trailing) > ndim else trailing
            return P(*((None,) * (ndim - len(tr)) + tuple(tr)))
    return P(*((None,) * ndim))


def param_specs(params, axis_sizes: Optional[dict] = None):
    """PartitionSpec pytree for a parameter pytree (by path-name rules).
    ``axis_sizes`` (mesh axis -> size) enables divisibility-aware candidate
    selection (e.g. full expert parallelism only when E % (data*model) == 0)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        ).lower()
        specs.append(_spec_for(name, leaf.shape, axis_sizes))
    return jax.tree_util.tree_unflatten(treedef, specs)
