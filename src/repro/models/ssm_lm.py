"""Pure Mamba-2 language model (attention-free; SSD blocks only)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.common import (
    dtype_of,
    embed_tokens,
    head_loss,
    head_loss_params,
    init_embed,
    logits_from,
    remat_policy,
    rms_norm,
)


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 2)
    keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "tok": init_embed(ks[1], cfg),
        "layers": jax.vmap(lambda k: ssm_mod.init_mamba(k, cfg))(keys),
        "final_norm": jnp.ones((cfg.d_model,), dtype_of(cfg)),
    }


# -- train stages (interleaved-producer protocol, DESIGN.md #Interleave) -----


def train_ctx(batch, cfg: ModelConfig):
    ctx = {"tokens": batch["tokens"], "labels": batch["labels"]}
    if "mask" in batch:
        ctx["mask"] = batch["mask"]
    return ctx


def embed_stage(sp, ctx, cfg: ModelConfig):
    return embed_tokens(sp, ctx["tokens"], cfg)


def stack_stage(layers, x, ctx, cfg: ModelConfig):
    """One (chunk of the) stacked Mamba run -- layers is a (L', ...) slice."""
    policy = remat_policy(cfg)

    def body(carry, lp):
        return carry + ssm_mod.apply_mamba_train(lp, carry, cfg), None

    if policy is not None:
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, layers, unroll=True if cfg.unroll_layers else 1)
    return x


def train_loss(params, batch, cfg: ModelConfig):
    ctx = train_ctx(batch, cfg)
    x = embed_stage({"embed": params["tok"]["embed"]}, ctx, cfg)
    x = stack_stage(params["layers"], x, ctx, cfg)
    return head_loss(head_loss_params(params, cfg), x, ctx, cfg)


def prefill(params, batch, cfg: ModelConfig):
    """Full-sequence prefill: (last-position logits, per-layer state cache)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["tok"], tokens, cfg)

    def body(carry, lp):
        out, lcache = ssm_mod.apply_mamba_prefill(lp, carry, cfg)
        return carry + out, lcache

    x, cache = jax.lax.scan(
        body, x, params["layers"], unroll=True if cfg.unroll_layers else 1
    )
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from(params["tok"], hidden[:, -1:], cfg)
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, smax: int):
    del smax  # state size is O(1) in sequence length -- the point of SSMs
    return jax.vmap(lambda _: ssm_mod.init_mamba_cache(cfg, batch, dtype_of(cfg)))(
        jnp.arange(cfg.n_layers)
    )


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    del pos  # state carries all history
    x = embed_tokens(params["tok"], tokens, cfg)

    def body(carry, xs):
        lp, lc = xs
        out, nc = ssm_mod.apply_mamba_decode(lp, carry, cfg, lc)
        return carry + out, nc

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache), unroll=True if cfg.unroll_layers else 1)
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from(params["tok"], hidden, cfg)
    return logits, new_cache
