"""Zamba2-style hybrid: Mamba-2 backbone with a weight-SHARED attention block
invoked after every ``attn_every`` SSM layers (the Zamba trick: one set of
transformer weights amortized over the depth).

Layer stacks are reshaped (groups, attn_every, ...) and run as a nested scan;
the shared block's params are closed over, so XLA sees true weight reuse.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.common import (
    apply_attention,
    apply_mlp,
    dtype_of,
    embed_tokens,
    head_loss,
    head_loss_params,
    init_attention,
    init_embed,
    init_mlp,
    logits_from,
    remat_policy,
    rms_norm,
)


def _n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 5)
    dt = dtype_of(cfg)
    keys = jax.random.split(ks[0], cfg.n_layers)
    mamba = jax.vmap(lambda k: ssm_mod.init_mamba(k, cfg))(keys)
    return {
        "tok": init_embed(ks[1], cfg),
        "mamba_layers": mamba,
        "shared": {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "attn": init_attention(ks[2], cfg),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "mlp": init_mlp(ks[3], cfg.d_model, cfg.d_ff, dt),
        },
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }


def _reshape_groups(stack, g, per):
    return jax.tree_util.tree_map(lambda v: v.reshape((g, per) + v.shape[1:]), stack)


def _shared_block(sp, x, positions, cfg, cache=None, cache_pos=None):
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    attn_out, new_cache = apply_attention(
        sp["attn"], h, positions, cfg,
        causal=cache is None, cache=cache, cache_pos=cache_pos,
    )
    x = x + attn_out
    h = rms_norm(x, sp["ln2"], cfg.norm_eps)
    return x + apply_mlp(sp["mlp"], h), new_cache


# -- train stages (interleaved-producer protocol, DESIGN.md #Interleave) -----
#
# The shared attention block is weight-tied across every group, so the whole
# nested scan is ONE stage: chunking it would re-associate the shared
# block's gradient sum and break bit-identity with train_loss.


def train_ctx(batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    b, s = tokens.shape
    ctx = {"tokens": tokens, "labels": batch["labels"],
           "positions": jnp.broadcast_to(jnp.arange(s)[None], (b, s))}
    if "mask" in batch:
        ctx["mask"] = batch["mask"]
    return ctx


def embed_stage(sp, ctx, cfg: ModelConfig):
    return embed_tokens(sp, ctx["tokens"], cfg)


def stack_stage(sp, x, ctx, cfg: ModelConfig):
    """The full nested scan.  sp = {"mamba_layers", "shared"} -- never a
    slice (see module note on the weight-shared attention block)."""
    g = _n_groups(cfg)
    stacks = _reshape_groups(sp["mamba_layers"], g, cfg.attn_every)
    positions = ctx["positions"]
    policy = remat_policy(cfg)

    def inner(carry, lp):
        return ssm_mod.apply_mamba_train(lp, carry, cfg) + carry, None

    def outer(carry, group_params):
        x, _ = jax.lax.scan(inner, carry, group_params, unroll=True if cfg.unroll_layers else 1)
        x, _ = _shared_block(sp["shared"], x, positions, cfg)
        return x, None

    if policy is not None:
        outer = jax.checkpoint(outer, policy=policy, prevent_cse=False)
    x, _ = jax.lax.scan(outer, x, stacks, unroll=True if cfg.unroll_layers else 1)
    return x


def train_loss(params, batch, cfg: ModelConfig):
    ctx = train_ctx(batch, cfg)
    x = embed_stage({"embed": params["tok"]["embed"]}, ctx, cfg)
    x = stack_stage(
        {"mamba_layers": params["mamba_layers"], "shared": params["shared"]},
        x, ctx, cfg,
    )
    return head_loss(head_loss_params(params, cfg), x, ctx, cfg)


def prefill(params, batch, cfg: ModelConfig):
    """Full-sequence prefill: SSD final states per mamba layer + shared-attn
    K/V per group invocation; returns (last-position logits, cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = embed_tokens(params["tok"], tokens, cfg)
    g = _n_groups(cfg)
    stacks = _reshape_groups(params["mamba_layers"], g, cfg.attn_every)
    sp = params["shared"]
    dh = cfg.head_dim
    from repro.models.common import apply_rope

    def inner(carry, lp):
        out, lcache = ssm_mod.apply_mamba_prefill(lp, carry, cfg)
        return carry + out, lcache

    def outer(carry, group_params):
        x, mcache = jax.lax.scan(
            inner, carry, group_params, unroll=True if cfg.unroll_layers else 1
        )
        # capture shared-attn K/V for this invocation
        h = rms_norm(x, sp["ln1"], cfg.norm_eps)
        k = (h @ sp["attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, dh)
        v = (h @ sp["attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, dh)
        k = apply_rope(k, positions, cfg.rope_theta)
        x, _ = _shared_block(sp, x, positions, cfg)
        return x, (mcache, {"k": k, "v": v})

    x, (mcache, ac) = jax.lax.scan(
        outer, x, stacks, unroll=True if cfg.unroll_layers else 1
    )
    cache = {
        "mamba": jax.tree_util.tree_map(
            lambda v: v.reshape((cfg.n_layers,) + v.shape[2:]), mcache
        ),
        "attn": ac,
    }
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from(params["tok"], hidden[:, -1:], cfg)
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, smax: int):
    g = _n_groups(cfg)
    dt = dtype_of(cfg)
    dh = cfg.head_dim
    mamba = jax.vmap(lambda _: ssm_mod.init_mamba_cache(cfg, batch, dt))(
        jnp.arange(cfg.n_layers)
    )
    return {
        "mamba": mamba,  # leaves (L, B, ...)
        "attn": {
            "k": jnp.zeros((g, batch, smax, cfg.n_kv_heads, dh), dt),
            "v": jnp.zeros((g, batch, smax, cfg.n_kv_heads, dh), dt),
        },
    }


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    b = tokens.shape[0]
    x = embed_tokens(params["tok"], tokens, cfg)
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    g = _n_groups(cfg)
    per = cfg.attn_every
    stacks = _reshape_groups(params["mamba_layers"], g, per)
    mcache = _reshape_groups(cache["mamba"], g, per)

    def inner(carry, xs):
        lp, lc = xs
        out, nc = ssm_mod.apply_mamba_decode(lp, carry, cfg, lc)
        return out + carry, nc

    def outer(carry, xs):
        group_params, group_mcache, ac = xs
        x, new_mcache = jax.lax.scan(inner, carry, (group_params, group_mcache), unroll=True if cfg.unroll_layers else 1)
        x, new_ac = _shared_block(params["shared"], x, positions, cfg, cache=ac, cache_pos=pos)
        return x, (new_mcache, new_ac)

    x, (new_mcache, new_ac) = jax.lax.scan(outer, x, (stacks, mcache, cache["attn"]), unroll=True if cfg.unroll_layers else 1)
    new_cache = {
        "mamba": jax.tree_util.tree_map(
            lambda v: v.reshape((cfg.n_layers,) + v.shape[2:]), new_mcache
        ),
        "attn": new_ac,
    }
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from(params["tok"], hidden, cfg)
    return logits, new_cache
