"""Whisper-style encoder-decoder backbone.

Per the assignment the conv frontend is a STUB: the model consumes
pre-computed frame embeddings (B, S_frames, d_model) directly (input_specs
provides them).  Sinusoidal absolute positions, bidirectional encoder
self-attention, causal decoder self-attention + cross-attention, GELU MLPs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    apply_attention,
    apply_mlp,
    dtype_of,
    embed_tokens,
    init_attention,
    init_embed,
    init_mlp,
    logits_from,
    remat_policy,
    rms_norm,
    softmax_cross_entropy,
)


def _sinusoid(s: int, d: int, dtype):
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _init_enc_layer(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "attn": init_attention(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dt, gated=False),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "self_attn": init_attention(k1, cfg),
        "ln_x": jnp.ones((cfg.d_model,), dt),
        "cross_attn": init_attention(k2, cfg),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dt, gated=False),
    }


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "tok": init_embed(ks[2], cfg),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), dtype_of(cfg)),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), dtype_of(cfg)),
    }


def encode(params, frames, cfg: ModelConfig):
    b, s, d = frames.shape
    x = frames.astype(dtype_of(cfg)) + _sinusoid(s, d, dtype_of(cfg))[None]
    policy = remat_policy(cfg)

    def body(carry, lp):
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        attn_out, _ = apply_attention(lp["attn"], h, None, cfg, causal=False)
        x = carry + attn_out
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + apply_mlp(lp["mlp"], h), None

    if policy is not None:
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"], unroll=True if cfg.unroll_layers else 1)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp, enc_out, cfg: ModelConfig):
    b, s, _ = enc_out.shape
    dh = cfg.head_dim
    k = (enc_out @ lp["cross_attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, dh)
    v = (enc_out @ lp["cross_attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, dh)
    return k, v


def _decoder(params, tokens, enc_out, cfg: ModelConfig):
    b, s = tokens.shape
    x = embed_tokens(params["tok"], tokens, cfg)
    x = x + _sinusoid(s, cfg.d_model, x.dtype)[None]
    policy = remat_policy(cfg)

    def body(carry, lp):
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        attn_out, _ = apply_attention(lp["self_attn"], h, None, cfg, causal=True)
        x = carry + attn_out
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        ck, cv = _cross_kv(lp, enc_out, cfg)
        cross_out, _ = apply_attention(lp["cross_attn"], h, None, cfg, causal=False, cross_kv=(ck, cv))
        x = x + cross_out
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + apply_mlp(lp["mlp"], h), None

    if policy is not None:
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"], unroll=True if cfg.unroll_layers else 1)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def train_loss(params, batch, cfg: ModelConfig):
    enc_out = encode(params, batch["frames"], cfg)
    hidden = _decoder(params, batch["tokens"], enc_out, cfg)
    logits = logits_from(params["tok"], hidden, cfg)
    return softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))


def init_cache(cfg: ModelConfig, batch: int, smax: int, enc_len: int = 1500):
    """Decoder self-attn KV cache + precomputed cross K/V (from prefill)."""
    dt = dtype_of(cfg)
    L, dh = cfg.n_layers, cfg.head_dim
    return {
        "k": jnp.zeros((L, batch, smax, cfg.n_kv_heads, dh), dt),
        "v": jnp.zeros((L, batch, smax, cfg.n_kv_heads, dh), dt),
        "cross_k": jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, dh), dt),
        "cross_v": jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, dh), dt),
    }


def prefill(params, batch, cfg: ModelConfig, smax: int):
    """Encoder pass -> cross K/V cache (+ empty self cache, BOS logits)."""
    enc_out = encode(params, batch["frames"], cfg)
    b = enc_out.shape[0]

    def per_layer(lp):
        return _cross_kv(lp, enc_out, cfg)

    cross_k, cross_v = jax.vmap(per_layer)(params["dec_layers"])  # (L,B,S,KVH,dh)
    cache = init_cache(cfg, b, smax, enc_len=enc_out.shape[1])
    cache["cross_k"], cache["cross_v"] = cross_k, cross_v
    bos = jnp.zeros((b, 1), jnp.int32)
    logits, cache = decode_step(params, cache, bos, jnp.int32(0), cfg)
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    b = tokens.shape[0]
    x = embed_tokens(params["tok"], tokens, cfg)
    # position pos sinusoid
    posv = jnp.asarray(pos, jnp.float32)
    d = cfg.d_model
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = posv / jnp.power(10000.0, 2.0 * dim / d)
    x = x + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(x.dtype)

    self_cache = {"k": cache["k"], "v": cache["v"]}

    def body(carry, xs):
        lp, lc, ck, cv = xs
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        attn_out, nc = apply_attention(
            lp["self_attn"], h, None, cfg, causal=False, cache=lc, cache_pos=pos
        )
        x = carry + attn_out
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        cross_out, _ = apply_attention(
            lp["cross_attn"], h, None, cfg, causal=False, cross_kv=(ck.astype(h.dtype), cv.astype(h.dtype))
        )
        x = x + cross_out
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + apply_mlp(lp["mlp"], h), nc

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], self_cache, cache["cross_k"], cache["cross_v"]),
        unroll=True if cfg.unroll_layers else 1,
    )
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from(params["tok"], hidden, cfg)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = new_self["k"], new_self["v"]
    return logits, new_cache
