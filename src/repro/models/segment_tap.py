"""Backward-interleaved gradient segments (DESIGN.md #Interleave).

The engine's default streamed producer (``CohortEngine._grad_segments``)
materializes the whole batched gradient tree before the first layout segment
reaches the encoder: peak client memory carries every gradient leaf plus the
encoder state.  Nothing forces that -- reverse-mode AD produces cotangents
LAYER BY LAYER, last layer first.  This module taps that order:

  * Every registry train_loss is (since this module landed) a composition of
    **stage functions** -- ``embed_stage -> stack_stage* -> head_stage`` --
    with signature ``(params-subtree, carry, ctx) -> carry'`` (see the
    per-family modules).  ``train_loss`` itself calls them, so the staged
    forward traces the same ops as the monolithic one.
  * :class:`InterleavedSegments` replays those stages under per-stage
    ``jax.vjp``: one forward sweep saves the stage-boundary activations, then
    the backward sweep walks the stages in reverse, emitting each stage's
    parameter gradients the moment its cotangents exist.  A static **plan**
    maps stage gradients onto layout-segment slots (a stacked layer chunk ->
    its sliced segment; the tied embedding -> a SUM of the embed and head
    stage contributions), and a segment is yielded as soon as its last
    contribution arrives -- backward order, i.e. out-of-order w.r.t. the
    layout, which the engine's ``grad_segments_fn`` contract already accepts.
    Encode of stage k's segments is dispatched (JAX async dispatch) while
    stage k-1's VJP runs; the full gradient pytree never exists.
  * Stage-boundary carries and cotangents are **donated** through the
    backward jits -- each is consumed exactly once -- so the live set at any
    instant is: the remaining boundary activations, one stage's gradients,
    the pending cross-stage accumulators (tied embeddings), and the
    in-flight encode buffers.  :meth:`peak_live_grad_bytes` computes that
    bound from the plan; the ``--only interleave`` bench measures against
    it.

**Bit-identity contract.** The wire produced through this producer is
bit-identical to the one-pass encode *of the gradients this producer
computes* (:meth:`grads_fn` -- same stage VJPs, tree materialized then
sliced): every segment's blocks are assembled from literally the same piece
arrays in both paths, and concat/slice/cast/pad are value-exact.  Staged
VJPs are NOT bitwise equal to the monolithic ``jax.jit(jax.grad(loss))`` --
XLA fuses the two programs differently, giving ~1e-8 relative differences --
so equivalence to the default engine path is pinned at allclose, and wire
bit-identity is pinned against :meth:`grads_fn` (same style as the PR-9
streamed-vs-one-pass test, which held the gradients fixed).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.layout import GradientLayout, _leaf_size

__all__ = [
    "Stage",
    "build_stages",
    "interleaved_layout",
    "InterleavedSegments",
]


@dataclasses.dataclass(frozen=True)
class Stage:
    """One link of the staged train loss.

    ``select(params)`` picks the parameter subtree this stage's forward
    reads; ``fwd(sp, carry, ctx)`` advances the activation carry (``carry``
    is ignored when ``has_carry`` is False -- the embed stage).  ``ranges``
    aligns ``jax.tree_util.tree_leaves(select(params))`` with the FULL
    parameter tree: entry i says stage-gradient leaf i is the flat scalar
    span ``[lo, hi)`` of the full-tree leaf named ``name`` (keystr path).
    A layer-chunk stage's spans cover only its chunk's rows; shared leaves
    (tied embedding) appear in several stages' ranges with identical spans
    and their gradients SUM.
    """

    name: str
    select: Callable[[Any], Any]
    fwd: Callable[[Any, Any, Dict[str, Any]], Any]
    ranges: Tuple[Tuple[str, int, int], ...]
    has_carry: bool = True


def _chunk_bounds(n_layers: int, chunks: int) -> List[Tuple[int, int]]:
    """Near-even [lo, hi) partition of the stacked layer axis."""
    chunks = max(1, min(int(chunks), n_layers))
    base, rem = divmod(n_layers, chunks)
    bounds, lo = [], 0
    for i in range(chunks):
        hi = lo + base + (1 if i < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def _abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct parameter tree -- geometry without allocating."""
    from repro.models import model as model_api

    return jax.eval_shape(lambda: model_api.init_params(cfg, jax.random.PRNGKey(0)))


def _subtree_ranges(
    subtree: Any,
    rename: Callable[[str], str],
    lo_hi: Optional[Tuple[int, int]] = None,
) -> Tuple[Tuple[str, int, int], ...]:
    """Ranges aligned with ``tree_leaves(subtree)``.  With ``lo_hi`` the
    subtree is the FULL stacked tree and each leaf's span is its
    ``[lo, hi)`` axis-0 slice."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(subtree)[0]:
        name = rename(jax.tree_util.keystr(path))
        size = _leaf_size(leaf.shape)
        if lo_hi is None:
            out.append((name, 0, size))
        else:
            lo, hi = lo_hi
            stride = size // leaf.shape[0]
            out.append((name, lo * stride, hi * stride))
    return tuple(out)


def _stack_chunk_stages(
    aparams_stack: Any,
    key: str,
    fwd_of_chunk: Callable[..., Any],
    layer_chunks: int,
) -> List[Stage]:
    """Per-chunk stages over one stacked (L, ...) parameter subtree."""
    n_layers = jax.tree_util.tree_leaves(aparams_stack)[0].shape[0]
    stages = []
    for lo, hi in _chunk_bounds(n_layers, layer_chunks):
        stages.append(
            Stage(
                name=f"{key}[{lo}:{hi}]",
                select=lambda p, lo=lo, hi=hi: jax.tree_util.tree_map(
                    lambda v: v[lo:hi], p[key]
                ),
                fwd=fwd_of_chunk,
                ranges=_subtree_ranges(
                    aparams_stack, lambda s: f"['{key}']" + s, (lo, hi)
                ),
            )
        )
    return stages


def build_stages(
    cfg: ModelConfig, aparams: Any, layer_chunks: int = 1
) -> Tuple[List[Stage], Callable[[Any, ModelConfig], Dict[str, Any]]]:
    """(forward-order stages, train_ctx fn) for one registry family.

    ``layer_chunks`` splits the main stacked run into that many stages so
    gradients stream out mid-stack; the hybrid family's weight-shared
    attention block ties every group together, so its stack is always ONE
    stage (chunking would re-associate the shared block's gradient sum and
    break bit-identity with train_loss).
    """
    fam = cfg.family
    embed_size = _leaf_size(aparams["tok"]["embed"].shape)
    if fam in ("dense", "moe", "vlm"):
        from repro.models import transformer as tf

        stages = [
            Stage(
                name="embed",
                select=lambda p: {"embed": p["tok"]["embed"]},
                fwd=lambda sp, x, ctx: tf.embed_stage(sp, ctx, cfg),
                ranges=(("['tok']['embed']", 0, embed_size),),
                has_carry=False,
            )
        ]
        if "layers_dense" in aparams:
            stages.append(
                Stage(
                    name="layers_dense",
                    select=lambda p: p["layers_dense"],
                    fwd=lambda sp, x, ctx: tf.stack_stage(sp, x, ctx, cfg, moe=False),
                    ranges=_subtree_ranges(
                        aparams["layers_dense"], lambda s: "['layers_dense']" + s
                    ),
                )
            )
        stages += _stack_chunk_stages(
            aparams["layers"],
            "layers",
            lambda sp, x, ctx: tf.stack_stage(sp, x, ctx, cfg, moe=cfg.is_moe),
            layer_chunks,
        )
        stages.append(
            Stage(
                name="head",
                select=lambda p: tf.head_params(p, cfg),
                fwd=lambda sp, x, ctx: tf.head_stage(sp, x, ctx, cfg),
                ranges=_subtree_ranges(tf.head_params(aparams, cfg), lambda s: s),
            )
        )
        return stages, tf.train_ctx
    if fam == "ssm":
        from repro.models import ssm_lm as sm
        from repro.models.common import head_loss, head_loss_params

        stages = [
            Stage(
                name="embed",
                select=lambda p: {"embed": p["tok"]["embed"]},
                fwd=lambda sp, x, ctx: sm.embed_stage(sp, ctx, cfg),
                ranges=(("['tok']['embed']", 0, embed_size),),
                has_carry=False,
            )
        ]
        stages += _stack_chunk_stages(
            aparams["layers"],
            "layers",
            lambda sp, x, ctx: sm.stack_stage(sp, x, ctx, cfg),
            layer_chunks,
        )
        stages.append(
            Stage(
                name="head",
                select=lambda p: head_loss_params(p, cfg),
                fwd=lambda sp, x, ctx: head_loss(sp, x, ctx, cfg),
                ranges=_subtree_ranges(head_loss_params(aparams, cfg), lambda s: s),
            )
        )
        return stages, sm.train_ctx
    if fam == "hybrid":
        if layer_chunks > 1:
            raise ValueError(
                "hybrid stacks cannot be chunked: the weight-shared attention "
                "block ties every group, so chunking would re-associate its "
                "gradient sum (layer_chunks must be 1)"
            )
        from repro.models import hybrid as hy
        from repro.models.common import head_loss, head_loss_params

        stages = [
            Stage(
                name="embed",
                select=lambda p: {"embed": p["tok"]["embed"]},
                fwd=lambda sp, x, ctx: hy.embed_stage(sp, ctx, cfg),
                ranges=(("['tok']['embed']", 0, embed_size),),
                has_carry=False,
            ),
            Stage(
                name="stack",
                select=lambda p: {
                    "mamba_layers": p["mamba_layers"], "shared": p["shared"]
                },
                fwd=lambda sp, x, ctx: hy.stack_stage(sp, x, ctx, cfg),
                ranges=_subtree_ranges(
                    {"mamba_layers": aparams["mamba_layers"],
                     "shared": aparams["shared"]},
                    lambda s: s,
                ),
            ),
            Stage(
                name="head",
                select=lambda p: head_loss_params(p, cfg),
                fwd=lambda sp, x, ctx: head_loss(sp, x, ctx, cfg),
                ranges=_subtree_ranges(head_loss_params(aparams, cfg), lambda s: s),
            ),
        ]
        return stages, hy.train_ctx
    raise NotImplementedError(
        f"no interleaved stage decomposition for family {fam!r} "
        "(the encoder-decoder audio family has no staged train loss)"
    )


def interleaved_layout(
    cfg: ModelConfig,
    n: int,
    layer_chunks: int = 1,
    row_multiple: int = 1,
    s_ratio: Optional[Callable[[str, Tuple[int, ...]], Optional[float]]] = None,
    group_scalars: int = 0,
) -> GradientLayout:
    """Per-tensor layout whose stacked-layer leaves are split at the
    producer's chunk boundaries, so every chunk stage completes whole
    segments (an unsplit (L, ...) leaf's single segment would only finish
    when the LAST chunk backprops, killing the interleave)."""
    aparams = _abstract_params(cfg)
    bounds: List[Tuple[int, int]] = []
    if layer_chunks > 1 and cfg.family in ("dense", "moe", "vlm", "ssm"):
        n_layers = jax.tree_util.tree_leaves(aparams["layers"])[0].shape[0]
        bounds = _chunk_bounds(n_layers, layer_chunks)
    parts = [hi - lo for lo, hi in bounds]

    def split(name: str, shape: Tuple[int, ...]):
        # every leaf under the main stack ("['layers']['attn']['wq']", ...);
        # "['layers_dense']..." does not share the prefix
        if name.startswith("['layers']"):
            return parts
        return None

    leaves_with_path = jax.tree_util.tree_flatten_with_path(aparams)[0]
    treedef = jax.tree_util.tree_structure(aparams)
    shapes = tuple((tuple(l.shape), l.dtype) for _, l in leaves_with_path)
    names = [jax.tree_util.keystr(p) for p, _ in leaves_with_path]
    return GradientLayout.from_shapes_per_tensor(
        treedef, shapes, n, row_multiple=row_multiple, names=names,
        s_ratio=s_ratio, group_scalars=group_scalars,
        split=split if parts else None,
    )


# ---------------------------------------------------------------------------
# The producer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Contrib:
    """One stage-gradient fragment -> segment-slot destination."""

    gleaf: int  # index into tree_leaves(stage gradients)
    a: int  # slice [a, b) within the stage leaf's flat span
    b: int
    seg: int  # destination segment index
    slot: int  # position within the segment (leaf slot j)
    dst: int  # offset within the slot


class InterleavedSegments:
    """``grad_segments_fn`` that yields layout segments in backward order.

    Engine hook signature: ``producer(params, batch, layout)`` yields
    ``(segment index, (C, rows, N) blocks)``.  ``grads_fn(params, batch)``
    materializes the matching batched gradient TREE from the same stage
    gradients -- the one-pass reference the wire bit-identity tests pin
    against.  Construct via :func:`repro.fed.engine.make_interleaved_segments`.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        layout: GradientLayout,
        grad_accum: int = 1,
        layer_chunks: int = 1,
    ):
        if grad_accum < 1:
            raise ValueError(f"grad_accum must be >= 1, got {grad_accum}")
        if grad_accum > 1 and cfg.family == "vlm":
            raise ValueError(
                "grad_accum microbatching splits the per-client sample axis, "
                "which the VLM batch's positions tensor does not carry "
                "(use grad_accum=1)"
            )
        self.cfg = cfg
        self.layout = layout
        self.grad_accum = int(grad_accum)
        self._aparams = _abstract_params(cfg)
        self.stages, self._ctx_fn = build_stages(cfg, self._aparams, layer_chunks)
        self._check_layout(layout)
        self._build_plan()
        self._build_jits()

    # -- construction --------------------------------------------------------

    def _check_layout(self, layout: GradientLayout) -> None:
        leaves = jax.tree_util.tree_flatten_with_path(self._aparams)[0]
        self._leaf_names = [jax.tree_util.keystr(p) for p, _ in leaves]
        want = tuple(tuple(l.shape) for _, l in leaves)
        got = tuple(s for s, _ in layout.shapes)
        if want != got or layout.treedef != jax.tree_util.tree_structure(self._aparams):
            raise ValueError(
                f"layout does not describe {self.cfg.name!r}'s parameter tree "
                "(build it with interleaved_layout / GradientLayout.per_tensor "
                "over the model params)"
            )

    def _build_plan(self) -> None:
        """Static fold plan: stage-gradient fragments -> segment slots.

        Per slot, contributions with IDENTICAL spans sum (shared leaves: the
        tied embedding accumulates embed + head stage gradients, in backward
        arrival order -- the same order :meth:`grads_fn` uses, so both paths
        add the same arrays in the same order); DISJOINT spans concatenate by
        offset (a split leaf's chunks).  Anything else is a plan bug and
        raises here, as does an uncovered slot (a leaf no stage produces).
        """
        name2id = {n: i for i, n in enumerate(self._leaf_names)}
        slots_by_leaf: Dict[int, List[Tuple[int, int, int, int]]] = {}
        for seg in self.layout.segments:
            for j, (lid, size, off) in enumerate(
                zip(seg.leaf_ids, seg.sizes, seg.leaf_offsets)
            ):
                slots_by_leaf.setdefault(lid, []).append(
                    (seg.index, j, off, off + size)
                )
        self._stage_contribs: List[List[_Contrib]] = []
        self._stage_scalars: List[int] = []
        spans: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        for st in self.stages:
            contribs = []
            for gi, (nm, lo, hi) in enumerate(st.ranges):
                if nm not in name2id:
                    raise ValueError(
                        f"stage {st.name!r} produces unknown leaf {nm} "
                        "(stage protocol drifted from the parameter tree)"
                    )
                for sidx, j, slo, shi in slots_by_leaf[name2id[nm]]:
                    ov_lo, ov_hi = max(lo, slo), min(hi, shi)
                    if ov_lo < ov_hi:
                        contribs.append(
                            _Contrib(gi, ov_lo - lo, ov_hi - lo,
                                     sidx, j, ov_lo - slo)
                        )
                        spans.setdefault((sidx, j), []).append(
                            (ov_lo - slo, ov_hi - ov_lo)
                        )
            self._stage_contribs.append(contribs)
            self._stage_scalars.append(sum(hi - lo for _, lo, hi in st.ranges))
        self._pending = [0] * len(self.layout.segments)
        for contribs in self._stage_contribs:
            for cb in contribs:
                self._pending[cb.seg] += 1
        # validate: every slot exactly tiled (identical spans = sums, fine)
        for seg in self.layout.segments:
            for j, size in enumerate(seg.sizes):
                sl = spans.get((seg.index, j))
                if not sl:
                    raise ValueError(
                        f"segment {seg.name!r} slot {j} (leaf "
                        f"{self._leaf_names[seg.leaf_ids[j]]}) is produced by "
                        "no stage"
                    )
                cursor = 0
                for dst, ln in sorted(set(sl)):
                    if dst != cursor:
                        raise ValueError(
                            f"segment {seg.name!r} slot {j}: stage spans "
                            f"overlap or leave a gap at offset {cursor}"
                        )
                    cursor += ln
                if cursor != size:
                    raise ValueError(
                        f"segment {seg.name!r} slot {j}: stages cover "
                        f"{cursor} of {size} scalars"
                    )
        # emit order within a segment = flat scalar order (slot, then offset)
        self._seg_piece_keys: List[List[Tuple[int, int]]] = []
        self._seg_piece_info: List[List[Tuple[int, int]]] = []
        for seg in self.layout.segments:
            keys = sorted({
                (cb.slot, cb.dst)
                for contribs in self._stage_contribs
                for cb in contribs
                if cb.seg == seg.index
            })
            self._seg_piece_keys.append(keys)
            self._seg_piece_info.append([
                (seg.leaf_ids[slot], seg.leaf_offsets[slot] + dst)
                for slot, dst in keys
            ])

    def _build_jits(self) -> None:
        self._ctx_jit = jax.jit(jax.vmap(lambda b: self._ctx_fn(b, self.cfg)))
        self._fwd_jits, self._bwd_jits = [], []
        for st in self.stages:
            fwd = st.fwd
            if st.has_carry:
                self._fwd_jits.append(
                    jax.jit(jax.vmap(fwd, in_axes=(None, 0, 0)))
                )

                def one(sp, x, ct, c, _fwd=fwd):
                    _, vjp = jax.vjp(lambda p, xi: _fwd(p, xi, c), sp, x)
                    return vjp(ct)  # (gp, gx)

                # the boundary carry is consumed exactly once and the carry
                # cotangent gx has its shape: donate it so XLA writes gx in
                # place (donating ct too would be unusable -- only one output
                # matches the shape -- and just warns)
                self._bwd_jits.append(
                    jax.jit(jax.vmap(one, in_axes=(None, 0, 0, 0)),
                            donate_argnums=(1,))
                )
            else:
                self._fwd_jits.append(
                    jax.jit(jax.vmap(lambda sp, c, _fwd=fwd: _fwd(sp, None, c),
                                     in_axes=(None, 0)))
                )

                def one0(sp, ct, c, _fwd=fwd):
                    _, vjp = jax.vjp(lambda p: _fwd(p, None, c), sp)
                    (gp,) = vjp(ct)
                    return gp

                # no donation: the embed gradient (vocab, d) cannot alias the
                # sequence-shaped cotangent
                self._bwd_jits.append(
                    jax.jit(jax.vmap(one0, in_axes=(None, 0, 0)))
                )
        self._add_jit = jax.jit(jnp.add)
        self._asm_jits: Dict[int, Any] = {}

    def _assemble(self, seg_index: int):
        """Pieces -> (C, rows, N) blocks for one segment, matching
        ``GradientLayout._segment_flat`` value-exactly (concat in flat
        order, cast f32, zero-pad, reshape)."""
        jit = self._asm_jits.get(seg_index)
        if jit is None:
            seg = self.layout.segments[seg_index]
            rows, n, pad = seg.rows, self.layout.n, seg.pad

            def asm(*pieces):
                flat = pieces[0] if len(pieces) == 1 else jnp.concatenate(
                    pieces, axis=-1
                )
                flat = flat.astype(jnp.float32)
                if pad:
                    flat = jnp.concatenate(
                        [flat, jnp.zeros(flat.shape[:-1] + (pad,), jnp.float32)],
                        axis=-1,
                    )
                return flat.reshape(flat.shape[0], rows, n)

            jit = self._asm_jits[seg_index] = jax.jit(asm)
        return jit

    # -- the backward sweep --------------------------------------------------

    def _microbatches(self, batch: Any) -> List[Any]:
        acc = self.grad_accum
        if acc == 1:
            return [batch]
        leaves = jax.tree_util.tree_leaves(batch)
        bsz = leaves[0].shape[1]
        if bsz % acc:
            raise ValueError(
                f"grad_accum={acc} must divide the per-client batch size {bsz}"
            )
        mb = bsz // acc
        return [
            jax.tree_util.tree_map(lambda x: x[:, m * mb:(m + 1) * mb], batch)
            for m in range(acc)
        ]

    def _run(self, params: Any, batch: Any) -> Iterator[Tuple[int, List[Any]]]:
        """Yields ``(segment index, pieces)`` in backward completion order;
        ``pieces`` aligns with ``self._seg_piece_info[segment index]``."""
        stages = self.stages
        ns = len(stages)
        sel = [st.select(params) for st in stages]
        batches = self._microbatches(batch)
        acc = len(batches)
        ctxs = [self._ctx_jit(b) for b in batches]
        c = jax.tree_util.tree_leaves(batch)[0].shape[0]
        # forward: save the carry INTO each stage (the last stage's output --
        # the loss -- is never needed for its own VJP)
        carries: List[Optional[List[Any]]] = [[None] * acc for _ in range(ns)]
        for m in range(acc):
            x = None
            for k in range(ns - 1):
                carries[k][m] = x
                st = stages[k]
                x = (self._fwd_jits[k](sel[k], x, ctxs[m]) if st.has_carry
                     else self._fwd_jits[k](sel[k], ctxs[m]))
            carries[ns - 1][m] = x
        cts: List[Any] = [jnp.ones((c,), jnp.float32) for _ in range(acc)]
        pending = list(self._pending)
        accbuf: Dict[Tuple[int, int, int], Any] = {}
        for k in reversed(range(ns)):
            st = stages[k]
            g = None
            for m in range(acc):
                if st.has_carry:
                    gm, ct_m = self._bwd_jits[k](sel[k], carries[k][m],
                                                 cts[m], ctxs[m])
                    cts[m] = ct_m
                else:
                    gm = self._bwd_jits[k](sel[k], cts[m], ctxs[m])
                g = gm if g is None else jax.tree_util.tree_map(jnp.add, g, gm)
            carries[k] = None  # boundary activations freed as we walk back
            if acc > 1:
                g = jax.tree_util.tree_map(lambda v: v / acc, g)
            flats = [v.reshape(c, -1) for v in jax.tree_util.tree_leaves(g)]
            for cb in self._stage_contribs[k]:
                flat = flats[cb.gleaf]
                piece = (flat if cb.a == 0 and cb.b == flat.shape[1]
                         else jax.lax.slice_in_dim(flat, cb.a, cb.b, axis=1))
                key = (cb.seg, cb.slot, cb.dst)
                prev = accbuf.get(key)
                accbuf[key] = piece if prev is None else self._add_jit(prev, piece)
                pending[cb.seg] -= 1
                if pending[cb.seg] == 0:
                    yield cb.seg, [
                        accbuf.pop((cb.seg,) + pk)
                        for pk in self._seg_piece_keys[cb.seg]
                    ]

    # -- public faces --------------------------------------------------------

    def __call__(
        self, params: Any, batch: Any, layout: GradientLayout
    ) -> Iterator[Tuple[int, jnp.ndarray]]:
        """The engine's ``grad_segments_fn`` hook: backward-ordered
        ``(segment index, (C, rows, N) blocks)``."""
        if layout is not self.layout and layout != self.layout:
            raise ValueError(
                "engine layout differs from the producer's -- pass the same "
                "GradientLayout to CohortEngine(layout=) and "
                "make_interleaved_segments"
            )
        for seg_idx, pieces in self._run(params, batch):
            yield seg_idx, self._assemble(seg_idx)(*pieces)

    def grads_fn(self, params: Any, batch: Any) -> Any:
        """One-pass reference: the batched gradient TREE assembled from the
        SAME stage-gradient arrays the segment stream emits (leaf pieces
        concatenated in offset order).  Slicing this tree through the layout
        reproduces the streamed wire bit-for-bit -- the producer's
        correctness oracle."""
        c = jax.tree_util.tree_leaves(batch)[0].shape[0]
        by_leaf: Dict[int, List[Tuple[int, jnp.ndarray]]] = {}
        for seg_idx, pieces in self._run(params, batch):
            for (lid, abs_off), arr in zip(self._seg_piece_info[seg_idx], pieces):
                by_leaf.setdefault(lid, []).append((abs_off, arr))
        leaves = []
        for lid, (shape, dtype) in enumerate(self.layout.shapes):
            plist = sorted(by_leaf[lid], key=lambda t: t[0])
            flat = plist[0][1] if len(plist) == 1 else jnp.concatenate(
                [p for _, p in plist], axis=-1
            )
            leaves.append(flat.reshape((c,) + shape).astype(dtype))
        return jax.tree_util.tree_unflatten(self.layout.treedef, leaves)

    # -- accounting ----------------------------------------------------------

    @property
    def stage_names(self) -> List[str]:
        return [st.name for st in self.stages]

    def peak_live_grad_bytes(self, clients: int) -> int:
        """Analytic peak of GRADIENT + ENCODER bytes held live at once by the
        interleaved client pass (f32 scalars x clients): walks the fold plan
        backward tracking one stage's gradients plus the pending cross-stage
        accumulators, then adds a double-buffered largest-segment encode
        working set (async dispatch keeps at most the in-flight and the
        just-enqueued segment's encoder state alive).  Stage-boundary
        activations and the packed wire accumulation are accounted by the
        bench on top, per model geometry.  This is the bound
        ``BENCH_interleave.json`` records and CI validates."""
        peak = live = 0
        pending = list(self._pending)
        buf: Dict[Tuple[int, int, int], int] = {}
        for k in reversed(range(len(self.stages))):
            for cb in self._stage_contribs[k]:
                key = (cb.seg, cb.slot, cb.dst)
                if key not in buf:
                    buf[key] = cb.b - cb.a
                    live += cb.b - cb.a
                peak = max(peak, self._stage_scalars[k] + live)
                pending[cb.seg] -= 1
                if pending[cb.seg] == 0:
                    for pk in self._seg_piece_keys[cb.seg]:
                        live -= buf.pop((cb.seg,) + pk)
            peak = max(peak, self._stage_scalars[k] + live)
        return clients * (
            4 * peak + 2 * self.layout.encoder_live_bytes(streamed=True)
        )
