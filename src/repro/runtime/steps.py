"""Train / serve step builders: where model, optimizer, FedQCS, and the mesh
meet.

train step (FedQCS enabled) = shard_map with ONE manual axis ('pod'):
  - fwd/bwd auto-partitions over (data, model) inside each pod (ICI traffic);
  - the only cross-pod (DCN) communication is the FedQCS payload exchange in
    runtime/collectives.py -- in wire_mode="gather_codes" that payload is the
    bit-packed uint32 words the fused encoder emits (true Q/R bits per entry,
    CompressedGradient.wire_bits), unpacked only after the gather;
  - every pod runs the (deterministic) reconstruction + optimizer redundantly,
    so parameters stay bit-identical across pods without a broadcast.

train step (baseline, FedQCS disabled) = plain jit; XLA inserts the full
uncompressed gradient all-reduce across ('pod','data') -- this is the
reference point the roofline section compares against.

serve steps (prefill / decode) are plain jit.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.compression import (
    BQCSCodec,
    FedQCSConfig,
    blocks_to_tree,
    flatten_to_blocks,
    flatten_to_blocks_batched,
)
from repro.models import model as model_api
from repro.models.sharding import ShardingRules, cs, param_specs, use_rules
from repro.optim import adam
from repro import jax_compat
from repro.runtime.collectives import fedqcs_pod_allreduce, fedqcs_vmapped_allreduce

_ROW_MULTIPLE = 512  # pad FedQCS block rows so (data, model) sharding is even


class _with_mesh:
    """Wraps a jitted callable so every call (and .lower) traces under the
    mesh context that PartitionSpec sharding constraints require."""

    def __init__(self, mesh, fn):
        self._mesh = mesh
        self._fn = fn

    def __call__(self, *args, **kwargs):
        with jax_compat.set_mesh(self._mesh):
            return self._fn(*args, **kwargs)

    def lower(self, *args, **kwargs):
        with jax_compat.set_mesh(self._mesh):
            return self._fn.lower(*args, **kwargs)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------


def make_rules(mesh) -> ShardingRules:
    return ShardingRules(axis_sizes={k: v for k, v in mesh.shape.items()})


def abstract_params(cfg: ModelConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: model_api.init_params(cfg, k), key)


def _axis_factor(spec, mesh) -> int:
    f = 1
    for entry in spec:
        if entry is None:
            continue
        for a in entry if isinstance(entry, tuple) else (entry,):
            f *= mesh.shape.get(a, 1)
    return f


def shard_block_geometry(cfg: ModelConfig, fed_cfg: FedQCSConfig, mesh):
    """Per-device FedQCS blocking (impl='auto_sharded'): returns
    (nb_local, nbar_local, local_shapes, specs) for the gradient tree."""
    params = abstract_params(cfg)
    specs = jax.tree_util.tree_map(
        lambda s, p: sanitize_spec(s, p.shape, mesh),
        param_specs(params, axis_sizes=dict(mesh.shape)),
        params,
    )
    leaves = jax.tree_util.tree_leaves(params)
    spec_leaves = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    local_shapes, total = [], 0
    for leaf, spec in zip(leaves, spec_leaves):
        shape = list(leaf.shape)
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            size = 1
            for a in entry if isinstance(entry, tuple) else (entry,):
                size *= mesh.shape.get(a, 1)
            shape[i] //= size
        local_shapes.append(tuple(shape))
        total += int(np_prod(shape))
    n = fed_cfg.block_size
    nb_local = -(-total // n)
    return nb_local, total, local_shapes, specs


def np_prod(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out


def init_train_state(
    cfg: ModelConfig,
    opt_cfg: adam.OptConfig,
    fed_cfg: Optional[FedQCSConfig],
    key,
    n_pods: int = 1,
    abstract: bool = False,
    mesh=None,
    impl: str = "auto",
):
    """Builds (or eval_shapes) the full train state pytree.

    impl='auto_sharded' (needs mesh): the error-feedback residual is blocked
    per device shard -- global shape (pods, nb_local * n_devices_per_pod, N)."""

    def build(k):
        params = model_api.init_params(cfg, k)
        state = {
            "params": params,
            "opt": adam.init_state(opt_cfg, params),
            "step": jnp.zeros((), jnp.int32),
        }
        if fed_cfg is not None:
            if impl == "auto_sharded":
                assert mesh is not None, "auto_sharded needs the mesh"
                nb_local, _, _, _ = shard_block_geometry(cfg, fed_cfg, mesh)
                dm = mesh.shape.get("data", 1) * mesh.shape.get("model", 1)
                state["residual"] = jnp.zeros(
                    (n_pods, nb_local * dm, fed_cfg.block_size), jnp.float32
                )
            else:
                blocks, _, _ = flatten_to_blocks(
                    params, fed_cfg.block_size, row_multiple=_ROW_MULTIPLE
                )
                state["residual"] = jnp.zeros((n_pods,) + blocks.shape, jnp.float32)
            state["participating"] = jnp.ones((n_pods,), jnp.float32)
        return state

    if abstract:
        return jax.eval_shape(build, key)
    return build(key)


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drops PartitionSpec axes whose mesh-size doesn't divide the dim (e.g.
    vocab 50280 on a 16-way axis) -- clean layouts over padded shards."""
    axes = []
    for i, a in enumerate(spec):
        if a is None:
            axes.append(None)
            continue
        size = 1
        for ax in (a if isinstance(a, tuple) else (a,)):
            size *= mesh.shape.get(ax, 1)
        axes.append(a if (i < len(shape) and shape[i] % size == 0) else None)
    axes += [None] * (len(shape) - len(axes))
    return P(*axes)


def sane_param_shardings(params, mesh):
    """NamedSharding pytree for a parameter pytree, divisibility-checked."""
    specs = param_specs(params, axis_sizes=dict(mesh.shape))
    return jax.tree_util.tree_map(
        lambda s, p: NamedSharding(mesh, sanitize_spec(s, p.shape, mesh)), specs, params
    )


def train_state_shardings(state, mesh, fed: bool):
    """NamedSharding pytree for the train state (params by name rules; opt
    moments follow their parameter; FedQCS residual over pod x (data,model))."""
    pspecs = jax.tree_util.tree_map(
        lambda s, p: sanitize_spec(s, p.shape, mesh),
        param_specs(state["params"], axis_sizes=dict(mesh.shape)),
        state["params"],
    )
    ns = lambda spec: NamedSharding(mesh, spec)
    shardings = {
        "params": jax.tree_util.tree_map(lambda s: ns(s), pspecs),
        "step": ns(P()),
    }

    def opt_leaf(spec):
        return adam.QLeaf(q=ns(spec), scale=ns(P()))

    def map_opt(tree):
        flat_specs = jax.tree_util.tree_leaves(pspecs)
        flat, treedef = jax.tree_util.tree_flatten(
            tree, is_leaf=lambda x: isinstance(x, adam.QLeaf)
        )
        out = [
            opt_leaf(s) if isinstance(l, adam.QLeaf) else ns(s)
            for l, s in zip(flat, flat_specs)
        ]
        return jax.tree_util.tree_unflatten(treedef, out)

    shardings["opt"] = {k: map_opt(v) for k, v in state["opt"].items()}
    if fed:
        shardings["residual"] = ns(P("pod", ("data", "model"), None))
        shardings["participating"] = ns(P())
    return shardings


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def _batch_pod_in_specs(batch):
    """shard_map in_specs: split the batch dim across pods (positions carry
    the batch dim second)."""

    def spec_for(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if "positions" in name:
            return P(None, "pod")
        return P("pod")

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adam.OptConfig,
    fed_cfg: Optional[FedQCSConfig],
    mesh,
    donate: bool = True,
    impl: str = "auto",  # "auto" (vmap over pods) | "shard_map" (manual pod)
):
    """Returns step_fn(state, batch) -> (state, metrics), jitted on ``mesh``.

    impl="auto" expresses the per-pod structure with vmap and lets XLA place
    the cross-pod all-reduce of Bussgang-dequantized codes (psum_dequant
    wire); impl="shard_map" uses a manual 'pod' axis with an explicit
    all_gather of bit-packed codes (true Q/R-bit wire).  The shard_map
    variant trips an XLA GSPMD CHECK-failure on large meshes (upstream bug,
    see EXPERIMENTS.md #Dry-run), so "auto" is the default.
    """
    rules = make_rules(mesh)
    codec = BQCSCodec(fed_cfg) if fed_cfg is not None else None

    def loss_fn(params, batch):
        return model_api.train_loss(params, batch, cfg)

    if codec is None:
        # Baseline: plain jit; XLA all-reduces grads over ('pod','data').
        def step_fn(state, batch):
            with use_rules(rules):
                loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
                new_params, new_opt = adam.update(
                    opt_cfg, grads, state["opt"], state["params"], state["step"]
                )
            new_state = {
                "params": new_params,
                "opt": new_opt,
                "step": state["step"] + 1,
            }
            return new_state, {"loss": loss}

        return _with_mesh(mesh, jax.jit(step_fn, donate_argnums=(0,) if donate else ()))

    n = fed_cfg.block_size

    def to_pods(path, leaf, pods):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if "positions" in name:  # (3, B, S) -> (pods, 3, B/p, S)
            r = leaf.reshape(leaf.shape[0], pods, -1, *leaf.shape[2:])
            return jnp.moveaxis(r, 1, 0)
        return leaf.reshape((pods, -1) + leaf.shape[1:])

    if impl == "auto_sharded":
        from repro.runtime.collectives import make_sharded_allreduce

        nb_local, nbar_local, local_shapes, pspecs = shard_block_geometry(
            cfg, fed_cfg, mesh
        )
        spec_leaves = jax.tree_util.tree_leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P)
        )
        body = make_sharded_allreduce(codec, mesh, local_shapes, nbar_local)
        res_spec = P(None, ("data", "model"), None)
        grad_in_specs = tuple(P(None, *s) for s in spec_leaves)
        smap = jax_compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(res_spec, P(), *grad_in_specs),
            out_specs=(res_spec, *spec_leaves),
            axis_names={"data", "model"},
            check_vma=False,
        )

        def step_fn(state, batch):
            pods = state["residual"].shape[0]
            pb = jax.tree_util.tree_map_with_path(
                lambda p, l: to_pods(p, l, pods), batch
            )
            with use_rules(rules):
                losses, grads_pp = jax.vmap(
                    jax.value_and_grad(loss_fn), in_axes=(None, 0)
                )(state["params"], pb)
                part = state["participating"]
                rhos = part / jnp.maximum(jnp.sum(part), 1.0)
                grad_leaves = jax.tree_util.tree_leaves(grads_pp)
                # Perf iteration 3d (measured NEUTRAL -- kept as layout
                # documentation): pinning per-pod grads to P('pod', *spec)
                # did not move the remaining pod-spanning backward reduce;
                # analysis suggests XLA merges that reduction across pods
                # deliberately because the post-exchange state is provably
                # pod-identical (it de-duplicates our redundant per-pod
                # reconstruction work).  See EXPERIMENTS.md #Perf.
                grad_leaves = [
                    jax.lax.with_sharding_constraint(g, P("pod", *s))
                    for g, s in zip(grad_leaves, spec_leaves)
                ]
                new_residual, *ghat_leaves = smap(
                    state["residual"], rhos, *grad_leaves
                )
                treedef = jax.tree_util.tree_structure(state["params"])
                grads = jax.tree_util.tree_unflatten(treedef, ghat_leaves)
                new_params, new_opt = adam.update(
                    opt_cfg, grads, state["opt"], state["params"], state["step"]
                )
            new_state = {
                "params": new_params,
                "opt": new_opt,
                "step": state["step"] + 1,
                "residual": new_residual,
                "participating": state["participating"],
            }
            return new_state, {"loss": jnp.mean(losses)}

        return _with_mesh(mesh, jax.jit(step_fn, donate_argnums=(0,) if donate else ()))

    if impl == "auto":

        def step_fn(state, batch):
            pods = state["residual"].shape[0]
            pb = jax.tree_util.tree_map_with_path(
                lambda p, l: to_pods(p, l, pods), batch
            )
            with use_rules(rules):
                losses, grads_pp = jax.vmap(
                    jax.value_and_grad(loss_fn), in_axes=(None, 0)
                )(state["params"], pb)
                # the spec IS a GradientLayout now (core/layout.py); it owns
                # its own unpadding, so no separate nbar threads through
                blocks_pp, layout, _ = flatten_to_blocks_batched(
                    grads_pp, n, row_multiple=_ROW_MULTIPLE
                )
                ghat, new_residual = fedqcs_vmapped_allreduce(
                    blocks_pp, state["residual"], codec, state["participating"]
                )
                grads = blocks_to_tree(ghat, layout)
                new_params, new_opt = adam.update(
                    opt_cfg, grads, state["opt"], state["params"], state["step"]
                )
            new_state = {
                "params": new_params,
                "opt": new_opt,
                "step": state["step"] + 1,
                "residual": new_residual,
                "participating": state["participating"],
            }
            return new_state, {"loss": jnp.mean(losses)}

        return _with_mesh(mesh, jax.jit(step_fn, donate_argnums=(0,) if donate else ()))

    def pod_body(params, opt, step, residual, participating, batch):
        residual = residual[0]  # (1, nb, N) -> (nb, N) pod-local view
        participating = participating[0]
        with use_rules(rules):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            blocks, layout, _ = flatten_to_blocks(grads, n, row_multiple=_ROW_MULTIPLE)
            blocks = cs(blocks, "blocks", None)
            ghat, new_residual = fedqcs_pod_allreduce(
                blocks, residual, codec, axis_name="pod", participating=participating
            )
            grads = blocks_to_tree(ghat, layout)
            new_params, new_opt = adam.update(opt_cfg, grads, opt, params, step)
        loss_mean = jax.lax.pmean(loss, "pod")
        return new_params, new_opt, new_residual[None], loss_mean

    def step_fn(state, batch):
        smap = jax_compat.shard_map(
            pod_body,
            mesh=mesh,
            in_specs=(P(), P(), P(), P("pod"), P("pod"), _batch_pod_in_specs(batch)),
            out_specs=(P(), P(), P("pod"), P()),
            axis_names={"pod"},
            check_vma=False,
        )
        new_params, new_opt, new_residual, loss = smap(
            state["params"],
            state["opt"],
            state["step"],
            state["residual"],
            state["participating"],
            batch,
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
            "residual": new_residual,
            "participating": state["participating"],
        }
        return new_state, {"loss": loss}

    return _with_mesh(mesh, jax.jit(step_fn, donate_argnums=(0,) if donate else ()))


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, mesh):
    rules = make_rules(mesh)

    def prefill_fn(params, batch):
        with use_rules(rules):
            smax = None
            if cfg.family == "audio":
                smax = batch["frames"].shape[1]
            return model_api.prefill(params, batch, cfg, smax)

    return _with_mesh(mesh, jax.jit(prefill_fn))


def make_decode_step(cfg: ModelConfig, mesh, donate: bool = True):
    rules = make_rules(mesh)

    def decode_fn(params, cache, tokens, pos):
        with use_rules(rules):
            logits, new_cache = model_api.decode_step(params, cache, tokens, pos, cfg)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, new_cache

    return _with_mesh(mesh, jax.jit(decode_fn, donate_argnums=(1,) if donate else ()))


# ---------------------------------------------------------------------------
# input shardings (dry-run + drivers)
# ---------------------------------------------------------------------------


def _bd(mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _even(dim, mesh, axes):
    size = 1
    for a in axes if isinstance(axes, tuple) else (axes,):
        size *= mesh.shape.get(a, 1)
    return dim % size == 0 and dim >= size


def batch_shardings(cfg: ModelConfig, shape: str, mesh):
    """NamedSharding pytree matching model_api.input_specs(cfg, shape)."""
    specs = model_api.input_specs(cfg, shape)
    bd = _bd(mesh)

    def shard_for(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path).lower()
        shp = leaf.shape
        if not shp:
            return NamedSharding(mesh, P())
        if "positions" in name:
            ax = bd if _even(shp[1], mesh, bd) else None
            return NamedSharding(mesh, P(None, ax, *(None,) * (len(shp) - 2)))
        if name.startswith("cache"):
            return NamedSharding(mesh, _cache_spec(name, shp, mesh))
        ax = bd if _even(shp[0], mesh, bd) else None
        return NamedSharding(mesh, P(ax, *(None,) * (len(shp) - 1)))

    return jax.tree_util.tree_map_with_path(shard_for, specs)


def _cache_spec(name: str, shp, mesh) -> P:
    """KV/state cache layout: batch->data, seq->model (split-KV decode)."""
    data_ok = lambda d: _even(d, mesh, ("data",))
    model_ok = lambda d: _even(d, mesh, ("model",))
    if any(k in name for k in ("ckv", "kr")):  # (L, B, S, r)
        return P(
            None,
            "data" if data_ok(shp[1]) else None,
            "model" if model_ok(shp[2]) else None,
            None,
        )
    if "conv" in name:  # (L, B, K, C)
        return P(
            None,
            "data" if data_ok(shp[1]) else None,
            None,
            "model" if model_ok(shp[3]) else None,
        )
    if "ssm" in name:  # (L, B, H, P, N)
        return P(
            None,
            "data" if data_ok(shp[1]) else None,
            "model" if model_ok(shp[2]) else None,
            None,
            None,
        )
    if len(shp) == 5:  # (L, B, S, KVH, dh)
        return P(
            None,
            "data" if data_ok(shp[1]) else None,
            "model" if model_ok(shp[2]) else None,
            None,
            None,
        )
    return P(*(None,) * len(shp))
