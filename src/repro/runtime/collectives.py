"""Compressed cross-pod gradient reduction (the paper's technique as a
collective).

Runs inside a shard_map body whose ONLY manual axis is 'pod' (data/model stay
auto-sharded, so everything here is also transparently sharded over the
in-pod mesh).  Two wire modes:

  * gather_codes (paper-faithful): all_gather the *bit-packed* Q-bit codes +
    the f32 alphas across pods -> every pod Bussgang-aggregates and runs
    EM-GAMP redundantly.  The packed uint32 words come straight out of the
    (fused) encoder -- nothing wider than the wire format crosses the pod
    axis, and the PS decode consumes the words directly: the EA branch feeds
    them to the packed reconstruction engine (fused-kernel in-VMEM unpack /
    per-chunk XLA unpack, DESIGN.md #Recon-engine) and the AE branch
    Bussgang-aggregates via the packed level lookup, so the (K, nb, M) uint8
    index view never materializes on the PS side either.  Cross-pod
    bytes/step = pods * nb * (W*4 + 4), W = ceil(M*Q/32).
  * psum_dequant (scales to many pods): each pod locally dequantizes and
    Bussgang-weights its codes; a single psum over 'pod' produces the
    aggregate observation directly.  Under use_kernels the dequantization
    reads the fused encoder's packed words straight through
    (dequantize_packed) instead of round-tripping pack -> unpack -> gather.
    Cross-pod bytes ~ nb * M * 4 (ring), independent of pod count.

Partial participation: a pod whose ``participating`` flag is 0 contributes
rho_k = 0 -- its payload is exactly ignored (Sec. IV weighting), so node
failure/straggling degrades gradient quality instead of failing the step.
The dead pod's error-feedback residual absorbs its FULL carry (blocks +
residual), not just the sparsification remainder: the top-S portion of a
straggler's gradient would otherwise be silently dropped (encoded but never
aggregated); carrying it forward re-transmits it once the pod rejoins.  The
fed cohort engine (repro.fed.engine) applies the same contract per client.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import bussgang
from repro.core.compression import BQCSCodec
from repro.core.gamp import GampConfig, em_gamp
from repro.core.layout import GradientLayout
from repro.core.recon_engine import ReconSpec
from repro.core.reconstruction import estimate_and_aggregate_packed
from repro.models.sharding import cs

__all__ = ["fedqcs_pod_allreduce", "fedqcs_partial_fold", "fedqcs_partial_finalize"]


def fedqcs_pod_allreduce(
    blocks: jnp.ndarray,  # (nb, N) pod-local gradient blocks
    residual: jnp.ndarray,  # (nb, N) error-feedback state
    codec: BQCSCodec,
    axis_name: str = "pod",
    participating: jnp.ndarray | None = None,  # scalar bool/f32, this pod
    recon: ReconSpec | None = None,  # overrides cfg.recon_mode / recon_chunk
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (reconstructed aggregated blocks, new residual).  ``recon``
    (core ReconSpec) overrides the codec config's recon_mode/recon_chunk for
    this call; default derives the spec from the config."""
    cfg = codec.cfg
    n, m = cfg.block_size, cfg.m
    if recon is None:
        recon = ReconSpec(mode=cfg.recon_mode)
    recon = recon.resolve(cfg)
    if participating is None:
        participating = jnp.float32(1.0)
    part = jnp.asarray(participating, jnp.float32)

    alive = jax.lax.all_gather(part, axis_name)  # (K,)
    total = jnp.maximum(jnp.sum(alive), 1.0)
    rhos = alive / total  # (K,) server-side weights
    rho_self = part / total

    if recon.mode == "ea" and cfg.wire_mode != "gather_codes":
        raise ValueError(
            "recon_mode='ea' needs the per-worker codes on the PS side, i.e. "
            "wire_mode='gather_codes' (see DESIGN.md)"
        )

    if cfg.wire_mode == "gather_codes":
        # The encoder emits the packed uint32 wire words directly (one fused
        # Pallas pass when cfg.use_kernels); no separate pack stage.
        words, alpha, new_residual = codec.compress_blocks_packed(blocks + 0.0, residual)
        # Dead pod: nothing it encoded reaches the aggregate, so its residual
        # keeps the full carry for re-transmission on rejoin.
        new_residual = jnp.where(part > 0, new_residual, blocks + residual)
        words = cs(words, "blocks", None)
        new_residual = cs(new_residual, "blocks", None)
        all_words = jax.lax.all_gather(words, axis_name)  # (K, nb, W)
        all_alpha = jax.lax.all_gather(alpha, axis_name)  # (K, nb)
        if recon.mode == "ea":
            # Estimate-and-aggregate: per-worker Q-EM-GAMP (fused kernel when
            # cfg.use_kernels), then rho-weighted sum -- every pod solves the
            # full K-batch redundantly, exactly like the AE branch below.
            # The words pass STRAIGHT THROUGH to the packed reconstruction
            # engine (chunked per the resolved spec); no uint8 view exists.
            ghat = estimate_and_aggregate_packed(
                codec, all_words, all_alpha, rhos,
                use_pallas=recon.use_pallas, chunk=recon.chunk,
            )
            return cs(ghat, "blocks", None), new_residual
        # AE: Bussgang-aggregate via the packed level lookup -- the only
        # index-domain consumer left, and it reads the words directly too.
        y = bussgang.aggregate_packed(all_words, all_alpha, rhos, codec.codebook, m)
        nu = bussgang.effective_noise_var(all_alpha, rhos, codec.codebook)
        energy = bussgang.signal_energy(all_alpha, rhos, m, n)
    else:  # psum_dequant: codes never cross the wire, only dequantized sums
        if cfg.use_kernels:
            # The fused encoder emits packed words; dequantize straight from
            # them (no pack -> unpack round trip, no uint8 index view).
            words, alpha, new_residual = codec.compress_blocks_packed(
                blocks + 0.0, residual
            )
            words = cs(words, "blocks", None)
            deq = codec.dequantize_packed(words)
        else:
            codes, alpha, new_residual = codec.compress_blocks(blocks + 0.0, residual)
            codes = cs(codes, "blocks", None)
            deq = codec.dequantize(codes)
        new_residual = jnp.where(part > 0, new_residual, blocks + residual)
        new_residual = cs(new_residual, "blocks", None)
        w = bussgang.bussgang_weight(rho_self, alpha, codec.codebook)  # (nb,)
        y_local = w[:, None] * deq
        y = jax.lax.psum(y_local, axis_name)
        safe = jnp.where(alpha > 0, alpha, 1.0)
        nu_local = codec.codebook.kappa * jnp.where(
            alpha > 0, (rho_self / safe) ** 2, 0.0
        )
        nu = jax.lax.psum(nu_local, axis_name)
        en_local = jnp.where(alpha > 0, rho_self**2 * m / jnp.square(safe), 0.0) / n
        energy = jax.lax.psum(en_local, axis_name)

    y = cs(y, "blocks", None)
    return _reconstruct(y, nu, energy, codec), new_residual


def fedqcs_vmapped_allreduce(
    blocks_pp: jnp.ndarray,  # (pods, nb, N) per-pod gradient blocks
    residual_pp: jnp.ndarray,  # (pods, nb, N)
    codec: BQCSCodec,
    participating: jnp.ndarray,  # (pods,)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Auto-SPMD variant: no manual axes, no shard_map.

    Per-pod compression runs under vmap; the Bussgang aggregation is a plain
    sum over the pod-sharded leading axis, which XLA lowers to the cross-pod
    all-reduce of the *dequantized* projections (psum_dequant wire mode,
    32/R bits per gradient entry, pod-count independent).

    This is the production default: it sidesteps an XLA GSPMD CHECK-failure
    when gathers are partitioned inside manual-axis subgroups on large meshes
    (see DESIGN.md / EXPERIMENTS.md #Dry-run).  The shard_map variant above
    (true Q/R-bit wire via packed-code all_gather) remains available via
    FedQCSConfig.wire_mode='gather_codes' + impl='shard_map'.
    """
    cfg = codec.cfg
    n, m = cfg.block_size, cfg.m
    part = jnp.asarray(participating, jnp.float32)
    rhos = part / jnp.maximum(jnp.sum(part), 1.0)  # (pods,)

    if cfg.recon_mode == "ea":
        # Estimate-and-aggregate over the pod-sharded payload batch: XLA
        # lowers the (pods*nb)-row GAMP batch like any other auto-sharded
        # compute.  Note this trades away the psum_dequant wire advantage --
        # the per-pod payloads are replicated across pods (see DESIGN.md) --
        # but they stay PACKED: the words feed the reconstruction engine
        # directly (chunked per cfg.recon_chunk) and the uint8 view never
        # materializes.
        words, alpha, new_residual = jax.vmap(codec.compress_blocks_packed)(
            blocks_pp, residual_pp
        )
        new_residual = jnp.where(
            part[:, None, None] > 0, new_residual, blocks_pp + residual_pp
        )
        words = cs(words, None, "blocks", None)
        new_residual = cs(new_residual, None, "blocks", None)
        ghat = estimate_and_aggregate_packed(codec, words, alpha, rhos)
        return cs(ghat, "blocks", None), new_residual

    codes, alpha, new_residual = jax.vmap(codec.compress_blocks)(blocks_pp, residual_pp)
    # Dead pods keep the full carry in their residual (see module docstring).
    new_residual = jnp.where(
        part[:, None, None] > 0, new_residual, blocks_pp + residual_pp
    )
    codes = cs(codes, None, "blocks", None)
    new_residual = cs(new_residual, None, "blocks", None)

    # Bussgang-weighted sum over pods -> all-reduce over the pod axis.
    y = bussgang.aggregate_codes(codes, alpha, rhos, codec.codebook)
    nu = bussgang.effective_noise_var(alpha, rhos, codec.codebook)
    energy = bussgang.signal_energy(alpha, rhos, m, n)
    y = cs(y, "blocks", None)
    return _reconstruct(y, nu, energy, codec), new_residual


def make_sharded_allreduce(codec: BQCSCodec, mesh, local_shapes, nbar_local: int):
    """Per-SHARD FedQCS (perf iteration 3b, EXPERIMENTS.md #Perf): every
    device compresses its own contiguous local shard of the gradient tree --
    the coordinate blocking is a (fixed) permutation of the paper's global
    blocking, to which the sensing/quantization theory is invariant -- so the
    gradient pytree never changes layout.  Measured motivation: the
    global-flatten path spends ~154 GB/device/step on all-gather resharding
    (qwen2-7b, 2x16x16); this path's only added collective is the pod-axis
    all-reduce of the (nb_local, M) Bussgang aggregate.

    Returns a function (grads_pp_leaves, residual, rhos) -> (ghat_leaves,
    new_residual), built as a shard_map manual over ('data','model') with the
    pod dimension left auto (no gathers inside => avoids the GSPMD
    manual-subgroup bug).

    local_shapes: per-leaf LOCAL shard shapes (excl. the pods dim);
    nbar_local: sum of local sizes (pre-padding).
    """

    from repro.models.sharding import use_rules

    cfg = codec.cfg
    if cfg.recon_mode == "ea":
        raise ValueError(
            "recon_mode='ea' is not supported by the per-shard (auto_sharded) "
            "path: it Bussgang-aggregates over the auto pod axis and never "
            "materializes per-worker codes; use impl='auto' or 'shard_map' "
            "with wire_mode='gather_codes' (see DESIGN.md)"
        )
    n = cfg.block_size
    # The per-shard block geometry as an explicit GradientLayout over the
    # LOCAL leaf shards (abstract specs, no arrays needed) -- this replaces
    # the manual flatten/pad/unflatten index math that used to live in the
    # body, and gets the int32 span guard + Python-int offsets for free.
    layout = GradientLayout.from_shapes(
        jax.tree_util.tree_structure([0] * len(local_shapes)),
        [(tuple(s), jnp.float32) for s in local_shapes],
        n,
    )
    if layout.nbar != nbar_local:
        raise ValueError(
            f"local_shapes sum to {layout.nbar} scalars, caller says {nbar_local}"
        )

    def body(residual, rhos, *grad_leaves):
        with use_rules(None):  # no auto-axis constraints inside manual body
            blocks = layout.to_blocks_batched(list(grad_leaves))
            codes, alpha, new_res = jax.vmap(codec.compress_blocks)(blocks, residual)
            # rho == 0 pods are dead: full carry stays in the residual.
            new_res = jnp.where(rhos[:, None, None] > 0, new_res, blocks + residual)
            # Bussgang-weighted sum over the (auto) pod axis -> cross-pod
            # all-reduce of the dequantized projections; everything else local.
            y = bussgang.aggregate_codes(codes, alpha, rhos, codec.codebook)
            nu = bussgang.effective_noise_var(alpha, rhos, codec.codebook)
            energy = bussgang.signal_energy(alpha, rhos, cfg.m, n)
            ghat = _reconstruct(y, nu, energy, codec)
            return (new_res, *layout.tree_from_blocks(ghat))

    return body  # steps.py wraps this with jax.shard_map (needs param specs)


def fedqcs_partial_fold(
    stats,  # core.aggregator.PartialStats or None (None starts a round)
    words: jnp.ndarray,  # (B, nb, W) packed wire words of one payload batch
    alphas: jnp.ndarray,  # (B, nb)
    weights: jnp.ndarray,  # (B,) RAW (unnormalized) aggregation weights
    codec: BQCSCodec,
    nu_chan: jnp.ndarray | None = None,  # (B, nb) channel variance
    noise: jnp.ndarray | None = None,  # (B, nb, M) sampled channel noise
):
    """Partial-aggregation entry point beside gather_codes/psum_dequant
    (DESIGN.md #Streaming-PS): folds one gathered sub-cohort payload batch
    into running AE sufficient statistics and returns the new running stats.

    This is the third wire shape: where gather_codes ships every payload to
    every pod and psum_dequant all-reduces one dequantized sum, partial folds
    let arrival-ordered SUBSETS of the cohort aggregate early -- the building
    block for the streaming PS (fed/stream.py) and for MIMO-MAC partial
    aggregation, where a superimposed sub-cohort reception IS a partial stat.
    Weights are RAW; finalize renormalizes (aggregator.normalized_stats).
    Jit-safe and associative: fold order changes nothing beyond f32
    reassociation.
    """
    from repro.core import aggregator  # deferred: keep collectives import-light

    batch = aggregator.ae_batch_stats(codec, words, alphas, weights, nu_chan, noise)
    return batch if stats is None else aggregator.stats_add(stats, batch)


def fedqcs_partial_finalize(stats, codec: BQCSCodec, gamp: GampConfig | None = None):
    """Decodes the round from folded partial stats -> (nb, N) aggregated
    blocks: the streaming counterpart of `_reconstruct` (one EM-GAMP on the
    renormalized Bussgang observation)."""
    from repro.core import recon_engine  # deferred: keep collectives import-light

    return recon_engine.decode_from_stats(
        codec, stats, gamp, use_pallas=codec.cfg.use_kernels
    )


def _reconstruct(y, nu, energy, codec: BQCSCodec) -> jnp.ndarray:
    cfg = codec.cfg
    gcfg = GampConfig(
        n_components=cfg.gamp_components,
        iters=cfg.gamp_iters,
        variance_mode=cfg.gamp_variance_mode,
        tol=0.0,  # static work inside the step
    )
    # em_gamp owns the kernel-dispatch rule (scalar variance, undamped).
    ghat = em_gamp(y, nu, codec.a, gcfg, init_var=energy, use_pallas=cfg.use_kernels)
    return cs(ghat, "blocks", None)
