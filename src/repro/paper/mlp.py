"""The paper's own experimental setup (Sec. VI): a 784-20-10 MLP trained by
K=30 non-IID devices with Adam at the PS, minibatch 1 per device per round.

This module is the e2e substrate for the Fig. 2-6 benchmarks and the
examples/federated_mnist.py driver.  It simulates every device faithfully:
per-device error feedback, per-device minibatch draws, PS-side
reconstruction via any of {fedqcs-ea, fedqcs-ae, qcs-qiht, qcs-dither,
signsgd, none}.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.compression import BQCSCodec, FedQCSConfig, blocks_to_tree, flatten_to_blocks
from repro.core.gamp import GampConfig, qem_gamp
from repro.data import mnist
from repro.optim.adam import OptConfig, init_state, update

N_IN, N_HID, N_OUT = 784, 20, 10  # N_bar = 15,910


def init_mlp(key) -> Dict[str, jnp.ndarray]:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (N_IN, N_HID), jnp.float32) * (1.0 / np.sqrt(N_IN)),
        "b1": jnp.zeros((N_HID,), jnp.float32),
        "w2": jax.random.normal(k2, (N_HID, N_OUT), jnp.float32) * (1.0 / np.sqrt(N_HID)),
        "b2": jnp.zeros((N_OUT,), jnp.float32),
    }


def mlp_logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, x, y):
    logits = mlp_logits(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@jax.jit
def device_grad(params, x, y):
    return jax.grad(mlp_loss)(params, x, y)


@jax.jit
def accuracy(params, x, y):
    return jnp.mean(jnp.argmax(mlp_logits(params, x), axis=-1) == y)


@dataclasses.dataclass
class RunResult:
    accs: List[float]
    nmses: List[float]
    losses: List[float]
    bits_per_entry: float
    wall_s: float


def run_federated(
    method: str,  # fedqcs-ea | fedqcs-ae | qcs-qiht | qcs-dither | signsgd | none
    steps: int = 300,
    k_devices: int = 30,
    fed_cfg: Optional[FedQCSConfig] = None,
    lr: float = 0.003,
    eval_every: int = 25,
    seed: int = 0,
    batch_per_device: int = 1,  # paper: |D_k^(t)| = 1
    groups: int = 1,  # AE: G
    record_nmse: bool = True,
) -> RunResult:
    """Runs the paper's federated loop and returns accuracy/NMSE traces."""
    (xtr, ytr, xte, yte), _ = mnist.load(seed)
    shards = mnist.federated_split(xtr, ytr, k=k_devices, seed=seed)
    fed_cfg = fed_cfg or FedQCSConfig(
        block_size=N_IN * N_HID // 8 + 1,  # ~B=10 blocks over N_bar=15910
        reduction_ratio=3,
        bits=3,
        s_ratio=0.1,
        gamp_iters=25,
    )
    # Paper blocking: B=10 blocks -> N = ceil(15910/10) = 1591.
    n_block = 1591
    fed_cfg = dataclasses.replace(fed_cfg, block_size=n_block)
    codec = BQCSCodec(fed_cfg)
    gamp = GampConfig(
        n_components=fed_cfg.gamp_components,
        iters=fed_cfg.gamp_iters,
        variance_mode=fed_cfg.gamp_variance_mode,
    )

    key = jax.random.PRNGKey(seed)
    params = init_mlp(key)
    opt_cfg = OptConfig(lr=lr, b1=0.9, b2=0.999, eps=1e-8, grad_clip=0.0,
                        warmup_steps=0, decay_steps=10**9, min_lr_frac=1.0)
    opt_state = init_state(opt_cfg, params)
    blocks0, spec, nbar = flatten_to_blocks(params, n_block)
    nb = blocks0.shape[0]
    residuals = [jnp.zeros((nb, n_block), jnp.float32) for _ in range(k_devices)]
    dither = baselines.DitherCodec(n=2048, m=2048 // fed_cfg.reduction_ratio, bits=fed_cfg.bits)
    rng = np.random.default_rng(seed)

    accs, nmses, losses = [], [], []
    rhos = jnp.full((k_devices,), 1.0 / k_devices)
    t0 = time.time()
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

    encode_jit = jax.jit(codec.compress_blocks)
    ea_jit = jax.jit(
        lambda c, a: qem_gamp(c.reshape(-1, fed_cfg.m), a.reshape(-1), codec.a, codec.quantizer, gamp)
    )

    for t in range(steps):
        # ---- device side -------------------------------------------------
        grads, blocks_k = [], []
        for k in range(k_devices):
            xk, yk = shards[k]
            idx = rng.integers(0, xk.shape[0], batch_per_device)
            g = device_grad(params, jnp.asarray(xk[idx]), jnp.asarray(yk[idx]))
            blocks, _, _ = flatten_to_blocks(g, n_block)
            grads.append(g)
            blocks_k.append(blocks)
        true_blocks = sum(rhos[k] * blocks_k[k] for k in range(k_devices))

        # ---- compression + PS reconstruction -----------------------------
        if method == "none":
            ghat_blocks = true_blocks
        elif method == "signsgd":
            signs = jnp.stack([baselines.signsgd_compress(b) for b in blocks_k])
            scale = float(jnp.mean(jnp.abs(true_blocks)))
            ghat_blocks = baselines.signsgd_aggregate(signs, lr_scale=scale)
        elif method == "qcs-dither":
            nb2 = (nbar + dither.n - 1) // dither.n
            acc = jnp.zeros((nb2, dither.n), jnp.float32)
            for k in range(k_devices):
                carry = blocks_k[k].reshape(-1)[:nbar]
                carry = jnp.pad(carry, (0, nb2 * dither.n - nbar)).reshape(nb2, dither.n)
                dkey = jax.random.fold_in(jax.random.PRNGKey(seed + 99), t * k_devices + k)
                q, delta, dith = dither.compress(carry, dkey)
                acc = acc + rhos[k] * dither.reconstruct(q, delta, dith)
            ghat_blocks = acc.reshape(-1)[:nbar]
            ghat_blocks = jnp.pad(ghat_blocks, (0, nb * n_block - nbar)).reshape(nb, n_block)
        else:
            codes_k, alpha_k = [], []
            for k in range(k_devices):
                c, a, new_res = encode_jit(blocks_k[k], residuals[k])
                residuals[k] = new_res
                codes_k.append(c)
                alpha_k.append(a)
            codes = jnp.stack(codes_k)
            alphas = jnp.stack(alpha_k)
            if method == "fedqcs-ea":
                ghat = ea_jit(codes, alphas).reshape(k_devices, nb, n_block)
                ghat_blocks = jnp.sum(rhos[:, None, None] * ghat, axis=0)
            elif method == "fedqcs-ae":
                from repro.core.reconstruction import aggregate_and_estimate

                ghat_blocks = aggregate_and_estimate(
                    codec, codes, alphas, rhos, groups=groups, gamp=gamp
                )
            elif method == "qcs-qiht":
                parts = [
                    baselines.qiht_reconstruct(
                        codes[k], alphas[k], codec.a, codec.quantizer, fed_cfg.s
                    )
                    for k in range(k_devices)
                ]
                ghat_blocks = sum(rhos[k] * parts[k] for k in range(k_devices))
            else:
                raise ValueError(method)

        if record_nmse:
            num = float(jnp.sum((ghat_blocks - true_blocks) ** 2))
            den = float(jnp.sum(true_blocks**2)) + 1e-30
            nmses.append(num / den)

        # ---- PS update (Adam, paper Sec. VI) ------------------------------
        ghat_tree = blocks_to_tree(ghat_blocks, spec, nbar)
        params, opt_state = update(opt_cfg, ghat_tree, opt_state, params, t)

        if t % eval_every == 0 or t == steps - 1:
            accs.append(float(accuracy(params, xte_j, yte_j)))
            losses.append(float(mlp_loss(params, xte_j, yte_j)))

    bits = (
        32.0
        if method == "none"
        else 1.0
        if method == "signsgd"
        else fed_cfg.bits_per_entry
    )
    return RunResult(accs, nmses, losses, bits, time.time() - t0)
