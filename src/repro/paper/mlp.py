"""The paper's own experimental setup (Sec. VI): a 784-20-10 MLP trained by
K=30 non-IID devices with Adam at the PS, minibatch 1 per device per round.

This module is the e2e substrate for the Fig. 2-6 benchmarks and the
examples/federated_mnist.py driver.  The round loop itself lives in the
cohort engine (``repro.fed.engine``, DESIGN.md #Fed-engine):
:func:`run_federated` wires the paper's partition (one digit per device),
full participation, ideal uplink, and server-side Adam into the engine —
and exposes the engine's scenario axes (client count, Dirichlet alpha,
sampling fraction, SNR) so the same driver scales from the paper's K=30 to
thousands of heterogeneous clients on a fading channel.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import FedQCSConfig
from repro.data import mnist
from repro.fed.channel import ChannelConfig
from repro.fed.engine import ArrayClientData, CohortConfig, CohortEngine
from repro.fed.partition import PartitionConfig, partition_indices
from repro.fed.scheduler import SchedulerConfig
from repro.fed.server_opt import ServerOptConfig

N_IN, N_HID, N_OUT = 784, 20, 10  # N_bar = 15,910


def init_mlp(key) -> Dict[str, jnp.ndarray]:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (N_IN, N_HID), jnp.float32) * (1.0 / np.sqrt(N_IN)),
        "b1": jnp.zeros((N_HID,), jnp.float32),
        "w2": jax.random.normal(k2, (N_HID, N_OUT), jnp.float32) * (1.0 / np.sqrt(N_HID)),
        "b2": jnp.zeros((N_OUT,), jnp.float32),
    }


def mlp_logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, x, y):
    logits = mlp_logits(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@jax.jit
def device_grad(params, x, y):
    return jax.grad(mlp_loss)(params, x, y)


@jax.jit
def accuracy(params, x, y):
    return jnp.mean(jnp.argmax(mlp_logits(params, x), axis=-1) == y)


def mlp_grad_fn(params, batch):
    """Engine-facing gradient: batch is the ArrayClientData {"x", "y"} dict."""
    return jax.grad(mlp_loss)(params, batch["x"], batch["y"])


@dataclasses.dataclass
class RunResult:
    accs: List[float]
    nmses: List[float]
    losses: List[float]
    bits_per_entry: float
    wall_s: float


def run_federated(
    method: str,  # fedqcs-ea | fedqcs-ae | qcs-qiht | qcs-dither | signsgd | none
    steps: int = 300,
    k_devices: int = 30,
    fed_cfg: Optional[FedQCSConfig] = None,
    lr: float = 0.003,
    eval_every: int = 25,
    seed: int = 0,
    batch_per_device: int = 1,  # paper: |D_k^(t)| = 1
    groups: int = 1,  # AE: G
    record_nmse: bool = True,
    # --- cohort scenario axes (defaults = the paper's Sec. VI setting) -----
    partition: str = "paper",  # paper | iid | shard | dirichlet
    alpha: float = 0.1,  # dirichlet concentration
    scheduler: str = "full",  # full | uniform | async
    sample_frac: float = 1.0,
    dropout: float = 0.0,
    channel: str = "ideal",  # any registered family: ideal | awgn | rayleigh | mimo_mac
    snr_db: float = 20.0,
    n_rx: int = 8,  # mimo_mac receive antennas
    csi_error: float = 0.0,  # mimo_mac CSI estimate error variance
    server: str = "fedadam",  # fedadam | fedavg | fedavgm
    chunk: int = 0,
    impl: str = "vmap",  # vmap | loop (the per-client oracle)
    obs: Any = None,  # repro.obs MetricsRecorder (None = null recorder)
) -> RunResult:
    """Runs the federated loop on the cohort engine; returns accuracy/NMSE
    traces.  The default arguments reproduce the paper's experiment exactly;
    the scenario axes open the FedVQCS-style wireless cohort settings.  The
    quantizer codebook is a ``fed_cfg`` axis (``FedQCSConfig.codebook`` /
    ``vq_dim``, DESIGN.md #Codebooks), passed through untouched.

    ``obs`` (a recorder from ``repro.obs``) threads into the engine: round
    events flow to its sink, and eval checkpoints are recorded as ``eval``
    events, so ``python -m repro.obs summarize <run_dir>`` renders the run.
    """
    (xtr, ytr, xte, yte), _ = mnist.load(seed)
    parts = partition_indices(
        ytr, k_devices, PartitionConfig(kind=partition, alpha=alpha, seed=seed)
    )
    fed_cfg = fed_cfg or FedQCSConfig(
        reduction_ratio=3, bits=3, s_ratio=0.1, gamp_iters=25
    )
    # Paper blocking: B=10 blocks -> N = ceil(15910/10) = 1591.
    # M = 1591 // R; the vq codebook needs vq_dim | M (checked at design).
    fed_cfg = dataclasses.replace(fed_cfg, block_size=1591)

    params = init_mlp(jax.random.PRNGKey(seed))
    engine = CohortEngine(
        params,
        mlp_grad_fn,
        ArrayClientData(xtr, ytr, parts, batch_size=batch_per_device, seed=seed),
        fed_cfg=fed_cfg,
        cohort=CohortConfig(
            method=method, groups=groups, record_nmse=record_nmse,
            chunk=chunk, impl=impl, seed=seed,
        ),
        sched=SchedulerConfig(
            kind=scheduler, sample_frac=sample_frac, dropout_prob=dropout, seed=seed
        ),
        chan=ChannelConfig(kind=channel, snr_db=snr_db, n_rx=n_rx, csi_error=csi_error),
        server=ServerOptConfig(kind=server, lr=lr, b1=0.9, b2=0.999, eps=1e-8),
        obs=obs,
    )

    accs, nmses, losses = [], [], []
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)
    t0 = time.time()
    for t in range(steps):
        stats = engine.run_round()
        if record_nmse and "nmse" in stats:
            nmses.append(stats["nmse"])
        if t % eval_every == 0 or t == steps - 1:
            acc = float(accuracy(engine.params, xte_j, yte_j))
            loss = float(mlp_loss(engine.params, xte_j, yte_j))
            accs.append(acc)
            losses.append(loss)
            engine.obs.record("eval", {"round": t, "accuracy": acc, "loss": loss})

    bits = (
        32.0
        if method == "none"
        else 1.0
        if method == "signsgd"
        else fed_cfg.bits_per_entry
    )
    return RunResult(accs, nmses, losses, bits, time.time() - t0)
